"""End-to-end driver: train a MoE LM with expert-parallel dispatch running
over the paper's factorized all-to-all, on a (pod=2, data=2, model=2)
debug mesh (8 virtual devices) — the EP group spans (data, pod), so every
MoE layer executes the d=2 hierarchical schedule each step, forward and
backward.

Shows: sharded init, factorized-A2A MoE, fault-tolerant trainer with
checkpointing, and loss decreasing on a learnable task.

  PYTHONPATH=src python examples/train_moe_ep.py [--steps 150]
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse                                                 # noqa: E402
import tempfile                                                 # noqa: E402

import jax                                                      # noqa: E402

from repro.data import CopyTaskConfig, SyntheticLM              # noqa: E402
from repro.models import ModelConfig, build_model, make_train_step  # noqa: E402
from repro.models.common import param_shardings                 # noqa: E402
from repro.optim import AdamW, AdamWConfig, cosine_with_warmup  # noqa: E402
from repro.parallel.sharding import ShardingRules               # noqa: E402
from repro.runtime import Trainer, TrainerConfig                # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = ShardingRules()
    cfg = ModelConfig(
        name="moe-ep-demo", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=64, n_experts=4,
        top_k=2, capacity_factor=2.0, param_dtype="float32",
        compute_dtype="float32", remat=False)

    model = build_model(cfg)
    shardings = param_shardings(model.specs(), mesh, rules)
    params = jax.jit(model.init, out_shardings=shardings)(
        jax.random.PRNGKey(0))
    opt = AdamW(AdamWConfig(lr=cosine_with_warmup(3e-3, 20, args.steps),
                            weight_decay=0.0))
    step_fn = jax.jit(make_train_step(model, opt, mesh, rules))

    data = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=32,
                                      global_batch=16), mesh=mesh,
                       task="copy")
    ckpt = tempfile.mkdtemp(prefix="moe_ep_")
    tr = Trainer(TrainerConfig(total_steps=args.steps, checkpoint_dir=ckpt,
                               checkpoint_every=50, log_every=25),
                 step_fn, data, params, jax.jit(opt.init)(params))
    tr.run()
    first, last = tr.metrics_log[0], tr.metrics_log[-1]
    print(f"\nEP over (data, pod): d=2 factorized all-to-all per MoE layer")
    print(f"step {first['step']}: ce={first['ce_loss']:.3f}  ->  "
          f"step {last['step']}: ce={last['ce_loss']:.3f}  "
          f"(aux={last['aux_loss']:.3f})")
    assert last["ce_loss"] < first["ce_loss"], "loss did not decrease"
    print("checkpoints at:", ckpt)


if __name__ == "__main__":
    main()
