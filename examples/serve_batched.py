"""Batched serving example: prefill + greedy decode with KV caches for a
dense GQA model, plus a sliding-window (ring-buffer) variant showing
O(window) state.

  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, build_model, make_serve_step


def run(cfg, label, batch=4, prompt_len=12, gen=12):
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(model))
    caches = model.init_caches(batch, prompt_len + gen)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab)
    for t in range(prompt_len):
        nxt, _, caches = serve(params, caches, prompts[:, t:t + 1])
    toks = [nxt[:, None]]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        nxt, _, caches = serve(params, caches, toks[-1])
        toks.append(nxt[:, None])
    jax.block_until_ready(toks[-1])
    dt = (time.perf_counter() - t0) / max(1, gen - 1)
    kv_slots = jax.tree.leaves(caches["states"])[0].shape
    out = jnp.concatenate(toks, axis=1)
    print(f"{label:24s} decode {dt * 1e3:6.2f} ms/tok  "
          f"cache-leaf shape {tuple(kv_slots)}  sample {out[0][:8].tolist()}")


def main():
    base = dict(family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=128,
                param_dtype="float32", compute_dtype="float32",
                remat=False)
    run(ModelConfig(name="dense-gqa", **base), "dense GQA")
    run(ModelConfig(name="swa-ring", window=8, **base),
        "SWA ring-buffer (W=8)")
    run(ModelConfig(name="moe-serve", **{**base, "family": "moe",
                                         "n_experts": 4}), "MoE top-2")


if __name__ == "__main__":
    main()
