"""Quickstart: the paper's factorized zero-copy all-to-all in 60 seconds.

Runs on 12 virtual CPU devices: builds a 2x3x2 torus (Cartesian
communicator), runs the d=3 round schedule, checks it against the direct
collective, and shows the tuning model's algorithm choice — the three
viewpoints of the paper in one script.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=12")

import jax                                                      # noqa: E402
import jax.numpy as jnp                                         # noqa: E402
import numpy as np                                              # noqa: E402

from repro.core import (ICI, DCN, cart_create, choose_algorithm,   # noqa: E402
                        dims_create, example_index_table,
                        get_factorization, torus_comm)

# 1. MPI_Dims_create analogue: balanced factorizations (paper Table 1)
p = 12
for d in (1, 2, 3):
    print(f"dims_create({p}, {d}) = {dims_create(p, d)}")
print(f"dims_create(1152, 2) = {dims_create(1152, 2)}  "
      f"(the paper's 36x32; OpenMPI wrongly returns 48x24)")

# 2. The round-k derived datatype (paper §3 worked example, 2x3x4)
print("\nRound-0 composite blocks for the 2x3x4 example (paper table):")
for j, idx in enumerate(example_index_table((2, 3, 4), 0)):
    print(f"  R'[{j}] = {idx}")

# 3. Cartesian communicator + cached factorization (Listings 1-2)
mesh = cart_create(12, (2, 3, 2), ("x", "y", "z"))
desc = get_factorization(mesh, ("x", "y", "z"))
print(f"\ncached factorization: dims={desc.dims} sigma={desc.sigma} "
      f"blocks/device (Thm 1) = {desc.blocks_sent_per_device()} "
      f"vs direct {desc.p - 1}")

# 4. The collective itself (Listing 3, zero-copy), through the
#    communicator — the API root every collective hangs off:
comm = torus_comm(mesh, ("x", "y", "z"))
x = jnp.arange(12 * 12 * 4, dtype=jnp.float32).reshape(12, 12, 4)
fact = comm.all_to_all((4,), jnp.float32, backend="factorized").host_fn()
direct = comm.all_to_all((4,), jnp.float32, backend="direct").host_fn()
np.testing.assert_array_equal(np.asarray(fact(x)), np.asarray(direct(x)))
print("factorized(d=3) == direct all-to-all ✓  (12 devices)")

# 4b. The dimension-wise family on the same communicator: a sub-comm
#     over two of the axes, and the d-stage all-gather
sub = comm.sub(("x", "y"))
g = jnp.arange(12 * 3, dtype=jnp.int32).reshape(12, 3)
gathered = comm.all_gather((3,), jnp.int32, backend="factorized").host_fn()
np.testing.assert_array_equal(np.asarray(gathered(g))[0], np.asarray(g))
print(f"sub-comm over {sub.axis_names} dims={sub.dims}; "
      f"d-stage all_gather ✓")

# 5. Tuning: the paper's small-block/large-block crossover
for nbytes in (4, 400, 4_000_000):
    s = choose_algorithm((16, 16), (ICI, ICI), nbytes)
    print(f"block {nbytes:>9} B -> {s.kind:10s} dims={s.dims} "
          f"predicted {s.predicted_seconds * 1e6:.1f} us")
s = choose_algorithm((16, 2), (ICI, DCN), 4096)
print(f"cross-pod 4 KiB blocks -> {s.kind} dims={s.dims} "
      f"(hierarchical: ICI round + DCN round)")
