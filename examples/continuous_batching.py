"""Serving demo: colocated continuous batching, then the same requests
through a disaggregated prefill/decode topology — identical outputs.

Part 1: 8 requests of mixed lengths through 3 slots — finished requests
are replaced without stalling the batch.  Part 2: one 6-rank torus
partitioned into prefill and decode domains; prompts ingest on the
prefill workers, KV caches migrate to the decode batcher through one
``KVMigrationPlan`` collective per tick (per-sequence lengths = the
Alltoallv send counts), multi-tenant admission throttled by free decode
slots.

  PYTHONPATH=src python examples/continuous_batching.py
"""

import jax

from repro.core import torus_comm
from repro.models import ModelConfig, build_model
from repro.runtime.serving import (ContinuousBatcher, DisaggregatedServer,
                                   Request)


def make_requests():
    prompts = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20], [30, 31, 32],
               [40, 41], [50], [60, 61, 62]]
    gens = [6, 4, 5, 8, 3, 7, 4, 5]
    return prompts, gens, [
        Request(i, list(p), g, tenant=f"tenant{i % 2}")
        for i, (p, g) in enumerate(zip(prompts, gens))]


def main():
    cfg = ModelConfig(name="demo", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      param_dtype="float32", compute_dtype="float32",
                      remat=False)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    # -- colocated: one batcher owns prefill and decode ------------------
    prompts, gens, reqs = make_requests()
    b = ContinuousBatcher(model, params, max_batch=3, max_seq=64)
    for r in reqs:
        b.submit(r)
    done = b.run()

    seq_ticks = sum(len(p) + g - 1 for p, g in zip(prompts, gens))
    print(f"served {len(done)} requests in {b.ticks} ticks "
          f"(sequential would be {seq_ticks}; "
          f"{seq_ticks / b.ticks:.1f}x overlap)")
    for rid in sorted(done):
        print(f"  req {rid}: prompt={prompts[rid]} -> {done[rid]}")

    # -- disaggregated: same requests, prefill/decode split torus --------
    _, _, reqs2 = make_requests()
    comm = torus_comm((2, 3), ("x", "y"))
    srv = DisaggregatedServer(model, params, comm, max_seq=64,
                              decode_batch=3, prefill_batch=2,
                              default_quota=3)
    for r in reqs2:
        srv.submit(r)
    done2 = srv.run()

    topo = srv.topology
    print(f"disaggregated: {topo.n_prefill} prefill + {topo.n_decode} "
          f"decode ranks, {topo.migrations} migration collectives moved "
          f"{topo.migrated_rows} KV rows "
          f"(inner plan: {topo.plan.inner_kind})")
    match = all(done2[rid] == done[rid] for rid in done)
    print(f"outputs identical to colocated: {match}")
    comm.free()


if __name__ == "__main__":
    main()
