"""Continuous-batching serving demo: 8 requests of mixed lengths through
3 slots — finished requests are replaced without stalling the batch.

  PYTHONPATH=src python examples/continuous_batching.py
"""

import jax

from repro.models import ModelConfig, build_model
from repro.runtime.serving import ContinuousBatcher, Request


def main():
    cfg = ModelConfig(name="demo", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      param_dtype="float32", compute_dtype="float32",
                      remat=False)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    b = ContinuousBatcher(model, params, max_batch=3, max_seq=64)
    prompts = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20], [30, 31, 32],
               [40, 41], [50], [60, 61, 62]]
    gens = [6, 4, 5, 8, 3, 7, 4, 5]
    for i, (p, g) in enumerate(zip(prompts, gens)):
        b.submit(Request(i, p, g))
    done = b.run()

    seq_ticks = sum(len(p) + g - 1 for p, g in zip(prompts, gens))
    print(f"served {len(done)} requests in {b.ticks} ticks "
          f"(sequential would be {seq_ticks}; "
          f"{seq_ticks / b.ticks:.1f}x overlap)")
    for rid in sorted(done):
        print(f"  req {rid}: prompt={prompts[rid]} -> {done[rid]}")


if __name__ == "__main__":
    main()
