"""Optimizers, schedules, gradient transforms (no optax — pure JAX)."""

from .adamw import AdamW, AdamWConfig
from .schedules import constant, cosine_with_warmup, linear_warmup
from .transforms import (clip_by_global_norm, compress_dequantize,
                         compressed_psum, global_norm,
                         tie_expert_replica_grads)

__all__ = ["AdamW", "AdamWConfig", "clip_by_global_norm",
           "compress_dequantize", "compressed_psum", "constant",
           "cosine_with_warmup", "global_norm", "linear_warmup",
           "tie_expert_replica_grads"]
