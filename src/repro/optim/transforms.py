"""Gradient transforms: clipping, int8-compressed all-reduce, replica tying.

``compressed_psum`` is the distributed-optimization trick for slow (DCN)
data-parallel axes: gradients are blockwise int8-quantized before the
cross-pod reduction, cutting DP all-reduce bytes 4x (bf16) at the cost of
quantization noise.  It runs inside ``shard_map`` (explicit-collective
training path); the jit/GSPMD path can apply ``compress_dequantize`` as a
numerical-effect simulation of the same trick.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float, gnorm=None):
    gnorm = global_norm(tree) if gnorm is None else gnorm
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale)
                        .astype(x.dtype), tree)


# ---------------------------------------------------------------------------
# int8 block-quantized gradient compression
# ---------------------------------------------------------------------------

def _quantize_int8(x, block: int = 256):
    """Blockwise symmetric int8 quantization; returns (q, scales, shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, x.shape


def _dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_dequantize(tree, block: int = 256):
    """Quantize->dequantize round trip (models compressed all-reduce noise
    in the GSPMD path, where the collective itself is compiler-inserted)."""
    def f(x):
        if x.ndim == 0 or x.size < block:
            return x
        q, s, sh = _quantize_int8(x, block)
        return _dequantize_int8(q, s, sh).astype(x.dtype)
    return jax.tree.map(f, tree)


def compressed_psum(tree, axis_name, block: int = 256):
    """int8-compressed gradient all-reduce over ``axis_name`` (shard_map).

    Each rank quantizes locally (int8 + per-block f32 scales), the int8
    payloads and scales are ``all_gather``-ed (int8 stays int8 on the
    wire), and the sum is reconstructed locally — the result is the exact
    sum of the per-rank quantized gradients, i.e. the only error is each
    rank's own int8 rounding.

    Wire bytes: ``n*(size + 4*size/block)`` int8 vs ``~4*size`` for a ring
    bf16 all-reduce — a ~2x cut for n=2 (the cross-pod DCN axis, where it
    matters); for large n prefer a reduce-scatter formulation.
    """
    def f(x):
        if x.ndim == 0 or x.size < block:
            return jax.lax.psum(x, axis_name)
        q, scale, shape = _quantize_int8(x, block)
        q_all = jax.lax.all_gather(q, axis_name)          # (n, nb, block) i8
        s_all = jax.lax.all_gather(scale, axis_name)      # (n, nb, 1) f32
        total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
        return total.reshape(-1)[: x.size].reshape(shape).astype(x.dtype)
    return jax.tree.map(f, tree)


def tie_expert_replica_grads(grads_tree, n_replicas: int, keys=("w1", "w3",
                                                                "w2")):
    """Average gradients across tiled expert replicas (used only by the
    *stored-virtual* MoE variant; the default tile-at-compute variant ties
    replicas automatically through the ``jnp.tile`` pullback)."""
    if n_replicas <= 1:
        return grads_tree

    def f(path, g):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name not in keys or g.ndim < 1 or g.shape[0] % n_replicas:
            return g
        E = g.shape[0] // n_replicas
        avg = g.reshape(n_replicas, E, *g.shape[1:]).mean(0)
        return jnp.tile(avg, (n_replicas,) + (1,) * (g.ndim - 1))
    return jax.tree_util.tree_map_with_path(f, grads_tree)
