"""AdamW with sharded moments, global-norm clipping, and schedules.

Moments inherit the parameter sharding automatically (they are tree-mapped
from the params), so FSDP-sharded params get FSDP-sharded optimizer state
— the ZeRO-1 memory layout falls out of GSPMD.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .transforms import clip_by_global_norm, global_norm


@dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moment_dtype: str = "float32"

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)


@dataclass(frozen=True)
class AdamW:
    config: AdamWConfig = field(default_factory=AdamWConfig)

    def init(self, params):
        mdt = jnp.dtype(self.config.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, params, grads, state):
        cfg = self.config
        step = state["step"] + 1
        gnorm = global_norm(grads)
        if cfg.clip_norm is not None:
            grads = clip_by_global_norm(grads, cfg.clip_norm, gnorm)
        lr = cfg.lr_at(step)
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(mu.dtype)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * (g32 * g32)
            mhat = mu / bc1
            nhat = nu / bc2
            delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
            if cfg.weight_decay and p.ndim >= 2:   # no decay on norms/bias
                delta = delta + cfg.weight_decay * p.astype(mu.dtype)
            newp = p.astype(mu.dtype) - lr * delta
            return newp.astype(p.dtype), mu, nu

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_mu = tdef.flatten_up_to(state["mu"])
        flat_nu = tdef.flatten_up_to(state["nu"])
        out = [upd(p, g, m, n)
               for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_mu = tdef.unflatten([o[1] for o in out])
        new_nu = tdef.unflatten([o[2] for o in out])
        return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm
