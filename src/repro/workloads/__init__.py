"""Distributed workloads built on the torus collectives.

The first resident is the pencil-decomposition FFT (``workloads.fft``):
every global transpose of the multidimensional FFT is a cached
:class:`~repro.core.plan.TransposePlan` — the paper's factorized
zero-copy all-to-all carrying one contiguous pencil chunk per peer.
"""

from .fft import PencilFFT, pencil_fft

__all__ = ["PencilFFT", "pencil_fft"]
