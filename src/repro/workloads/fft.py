"""Pencil-decomposition multidimensional FFT on the torus transpose.

The classic distributed-memory FFT (Dalcin et al., "Fast parallel
multidimensional FFT using advanced MPI", arXiv 1804.09536) keeps each
array axis *either* fully local *or* sharded: local axes are transformed
with the on-device FFT, then a **global transpose** re-shards the array
so the next axis becomes local.  Every transpose is an all-to-all of one
contiguous pencil chunk per peer — exactly the paper's factorized
zero-copy collective — so here each transpose is a cached
:class:`~repro.core.plan.TransposePlan` resolved through any dense
backend (``direct`` / ``factorized`` / ``pipelined`` / ``overlap`` /
``tuned`` / ``autotune``).

Decomposition model
-------------------

A rank-``m`` global array on a rank-``d`` torus.  The torus axes are
partitioned into ``g`` *groups* (``grid``); group ``k`` (size ``q_k``,
the product of its axis dims) shards array axis ``k`` of the input.
``g = d`` with singleton groups is the pencil decomposition;
``g = 1`` with every torus axis in one group is the slab decomposition
(the only option for 2-D arrays, where a single axis must absorb the
whole torus).  Array axes ``g..m-1`` start local.

Forward: transform the local axes, then for ``k = g-1 .. 0`` transpose
over group ``k`` (axis ``k+1`` becomes sharded, axis ``k`` becomes
local) and transform axis ``k``.  The output is sharded on axes
``1..g``; axis 0 is local.  Inverse mirrors the chain exactly, and each
inverse transpose is the *same* plan's drain direction
(``inverse_apply``), so a forward/inverse pair resolves one plan per
stage.

The whole data path is one ``jax.jit(jax.shard_map(...))`` per
direction — zero host round-trips between stages.  With the telemetry
tracer enabled the pipeline switches to a stepped per-stage path so
every transpose round gets a measured span and a drift observation
(same contract as ``A2APlan.host_fn``).

Correctness oracle: ``core.simulator.simulate_pencil_transpose``; the
full pipeline is validated against ``numpy.fft`` at 12 devices in
``tests/device_scripts/check_fft.py``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core import telemetry

__all__ = ["PencilFFT", "pencil_fft"]

_COMPLEX = {"float32": "complex64", "float64": "complex128",
            "complex64": "complex64", "complex128": "complex128"}


def _normalize_axes(axes, m: int) -> tuple[int, ...]:
    if axes is None:
        return tuple(range(m))
    out = []
    for ax in axes:
        ax = int(ax)
        if ax < 0:
            ax += m
        if not 0 <= ax < m:
            raise ValueError(f"fft axis {ax} outside array rank {m}")
        out.append(ax)
    if len(set(out)) != len(out):
        raise ValueError(f"duplicate fft axes {axes}")
    return tuple(sorted(out))


class PencilFFT:
    """A resolved pencil/slab-decomposed FFT over a :class:`TorusComm`.

    Parameters
    ----------
    comm:
        Torus communicator; its mesh hosts the data path.
    global_shape:
        Global (unsharded) array shape, rank ``m >= 2``.
    axes:
        Array axes to transform (default: all).  The transpose chain is
        fixed by the decomposition — axes outside ``axes`` still ride
        the re-shard, they just skip the local transform.
    grid:
        Tuple of tuples of torus axis names — group ``k`` shards array
        axis ``k``.  Default: one singleton group per torus axis when
        ``m - 1 >= d`` (pencil), else one group of all axes (slab).
    real:
        Real-input transform: ``rfft`` along the last array axis (which
        must be in ``axes``), complex transforms elsewhere; the inverse
        ends in ``irfft`` and returns a real array.
    dtype:
        Input dtype (default ``float32`` when ``real`` else
        ``complex64``); transposes run in the matching complex dtype.
    backend, links, db, **plan_kw:
        Forwarded to :meth:`TorusComm.transpose` for every stage plan.
    """

    def __init__(self, comm, global_shape, *, axes=None, grid=None,
                 real: bool = False, dtype=None, backend: str = "tuned",
                 links=None, db=None, **plan_kw):
        self.comm = comm
        self.global_shape = tuple(int(n) for n in global_shape)
        m = len(self.global_shape)
        if m < 2:
            raise ValueError("pencil FFT needs a rank >= 2 array")
        self.fft_axes = _normalize_axes(axes, m)
        if grid is None:
            grid = tuple((name,) for name in comm.axis_names) \
                if m - 1 >= comm.d else (tuple(comm.axis_names),)
        self.grid = tuple(tuple(group) for group in grid)
        g = len(self.grid)
        if not 1 <= g <= m - 1:
            raise ValueError(f"{g} torus groups need an array of rank "
                             f">= {g + 1}, got {m}")
        flat = [name for group in self.grid for name in group]
        if sorted(flat) != sorted(comm.axis_names):
            raise ValueError(f"grid {self.grid} must partition the comm "
                             f"axes {comm.axis_names}")
        self.real = bool(real)
        if self.real and m - 1 not in self.fft_axes:
            raise ValueError("real transform requires the last array "
                             "axis in `axes` (the rfft axis)")
        self.dtype = str(dtype) if dtype is not None else \
            ("float32" if self.real else "complex64")
        if self.dtype not in _COMPLEX:
            raise ValueError(f"unsupported dtype {self.dtype!r}")
        if self.real and self.dtype.startswith("complex"):
            raise ValueError("real transform takes a float input dtype")
        self.cdtype = _COMPLEX[self.dtype]
        self.backend = backend

        dim_of = dict(zip(comm.axis_names, comm.dims))
        self.group_sizes = tuple(
            math.prod(dim_of[name] for name in group)
            for group in self.grid)
        for k, q in enumerate(self.group_sizes):
            if self.global_shape[k] % q:
                raise ValueError(
                    f"array axis {k} (size {self.global_shape[k]}) not "
                    f"divisible by group {self.grid[k]} size {q}")
        self._gspecs = tuple(tuple(reversed(group)) for group in self.grid)

        # Shape the transposes see: rfft halves the last axis up front.
        work = list(self.global_shape)
        if self.real:
            work[m - 1] = work[m - 1] // 2 + 1
        cur = [work[k] // self.group_sizes[k] if k < g else work[k]
               for k in range(m)]
        self._comms = tuple(
            comm if group == tuple(comm.axis_names) else comm.sub(group)
            for group in self.grid)
        plans = [None] * g
        for k in range(g - 1, -1, -1):
            plans[k] = self._comms[k].transpose(
                tuple(cur), self.cdtype, split_axis=k + 1, concat_axis=k,
                backend=backend, links=links, db=db, **plan_kw)
            cur[k + 1] //= self.group_sizes[k]
            cur[k] *= self.group_sizes[k]
        self.plans = tuple(plans)
        self.out_local_shape = tuple(cur)

        self.in_spec = P(*[self._gspecs[k] if k < g else None
                           for k in range(m)])
        out = [None] * m
        for k in range(g):
            out[k + 1] = self._gspecs[k]
        self.out_spec = P(*out)
        self._fns: dict = {}
        self._stage_fns: dict = {}

    # -- geometry ----------------------------------------------------------

    @property
    def m(self) -> int:
        return len(self.global_shape)

    @property
    def g(self) -> int:
        return len(self.grid)

    @property
    def mesh(self) -> Mesh | None:
        return self.comm.mesh

    def _local_fft_axes(self) -> tuple[int, ...]:
        """The transformed axes that never need a transpose (local from
        the start), rfft axis excluded."""
        hi = self.m - 1 if self.real else self.m
        return tuple(ax for ax in self.fft_axes if self.g <= ax < hi)

    # -- per-shard pipeline (inside shard_map over the full mesh) ----------

    def forward_local(self, x):
        """Forward transform of this device's input pencil — local FFTs
        interleaved with :meth:`TransposePlan.apply` collectives.  Runs
        inside ``jax.shard_map`` over the comm's torus axes."""
        if self.real:
            x = jnp.fft.rfft(x, axis=self.m - 1)
        else:
            x = x.astype(self.cdtype)
        for ax in self._local_fft_axes():
            x = jnp.fft.fft(x, axis=ax)
        for k in range(self.g - 1, -1, -1):
            x = self.plans[k].apply(x)
            if k in self.fft_axes:
                x = jnp.fft.fft(x, axis=k)
        return x

    def inverse_local(self, y):
        """Exact inverse of :meth:`forward_local`: each re-shard is the
        same stage plan's drain direction, so the transpose round-trip
        is bit-identical and only the FFT pair introduces float error."""
        for k in range(self.g):
            if k in self.fft_axes:
                y = jnp.fft.ifft(y, axis=k)
            y = self.plans[k].inverse_apply(y)
        for ax in reversed(self._local_fft_axes()):
            y = jnp.fft.ifft(y, axis=ax)
        if self.real:
            y = jnp.fft.irfft(y, n=self.global_shape[self.m - 1],
                              axis=self.m - 1)
            y = y.astype(self.dtype)
        return y

    # -- host-level entry points -------------------------------------------

    def _host_fn(self, direction: str, mesh: Mesh | None):
        mesh = self.mesh if mesh is None else mesh
        if mesh is None:
            raise ValueError("comm carries no Mesh; pass one")
        local = self.forward_local if direction == "forward" \
            else self.inverse_local
        in_spec = self.in_spec if direction == "forward" else self.out_spec
        out_spec = self.out_spec if direction == "forward" else self.in_spec
        fkey = (direction, mesh)
        if fkey not in self._fns:
            self._fns[fkey] = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=in_spec, out_specs=out_spec))
        fast = self._fns[fkey]
        tr = telemetry.get_tracer()

        def run(x):
            if not tr.enabled:
                return fast(x)
            return self._traced(tr, direction, mesh, x)

        run.jitted = fast
        return run

    def forward_fn(self, mesh: Mesh | None = None):
        """Jitted forward FFT over the global array (sharded per
        ``in_spec``; result sharded per ``out_spec``).  One fused jit
        when tracing is off — the zero-host-round-trip data path
        (exposed as ``fn.jitted`` for HLO inspection); stepped per-stage
        spans when the tracer is on."""
        return self._host_fn("forward", mesh)

    def inverse_fn(self, mesh: Mesh | None = None):
        """Jitted inverse FFT — see :meth:`forward_fn`."""
        return self._host_fn("inverse", mesh)

    # -- telemetry-traced stepped path -------------------------------------

    def _spec_of(self, dist: dict) -> P:
        return P(*[self._gspecs[dist[a]] if a in dist else None
                   for a in range(self.m)])

    def _stages(self, direction: str, mesh: Mesh):
        """``(kind, label, host_fn)`` per pipeline stage; transpose
        stages delegate to :meth:`TransposePlan.host_fn` (their own
        stepped/fused round spans), FFT stages get one jitted fn each."""
        skey = (direction, mesh)
        if skey in self._stage_fns:
            return self._stage_fns[skey]

        def fft_stage(axes_, spec, ifft=False, rfft=False, irfft=False):
            def local(x, _axes=tuple(axes_)):
                if rfft:
                    x = jnp.fft.rfft(x, axis=self.m - 1)
                if not rfft and not irfft and not ifft:
                    x = x.astype(self.cdtype)
                for ax in _axes:
                    x = (jnp.fft.ifft if ifft else jnp.fft.fft)(x, axis=ax)
                if irfft:
                    x = jnp.fft.irfft(x, n=self.global_shape[self.m - 1],
                                      axis=self.m - 1).astype(self.dtype)
                return x
            return jax.jit(jax.shard_map(local, mesh=mesh, in_specs=spec,
                                         out_specs=spec))

        stages = []
        if direction == "forward":
            dist = {k: k for k in range(self.g)}
            spec = self._spec_of(dist)
            stages.append(("fft", "fft[local]", fft_stage(
                self._local_fft_axes(), spec, rfft=self.real)))
            for k in range(self.g - 1, -1, -1):
                s_in = self._spec_of(dist)
                del dist[k]
                dist[k + 1] = k
                s_out = self._spec_of(dist)
                stages.append(("transpose", f"transpose[{k}]",
                               self.plans[k].host_fn(
                                   mesh, in_spec=s_in, out_spec=s_out)))
                if k in self.fft_axes:
                    stages.append(("fft", f"fft[axis={k}]",
                                   fft_stage((k,), s_out)))
        else:
            dist = {k + 1: k for k in range(self.g)}
            for k in range(self.g):
                s_in = self._spec_of(dist)
                if k in self.fft_axes:
                    stages.append(("fft", f"ifft[axis={k}]",
                                   fft_stage((k,), s_in, ifft=True)))
                del dist[k + 1]
                dist[k] = k
                s_out = self._spec_of(dist)
                stages.append(("transpose", f"transpose[{k}]",
                               self.plans[k].host_fn(
                                   mesh, in_spec=s_in, out_spec=s_out)))
            stages.append(("fft", "ifft[local]", fft_stage(
                tuple(reversed(self._local_fft_axes())), self._spec_of(dist),
                ifft=True, irfft=self.real)))
        self._stage_fns[skey] = stages
        return stages

    def _traced(self, tr, direction: str, mesh: Mesh, x):
        import time
        with tr.span(f"fft.{direction}", cat="workload",
                     shape="x".join(str(n) for n in self.global_shape),
                     grid="|".join(",".join(g) for g in self.grid),
                     axes=",".join(str(a) for a in self.fft_axes),
                     real=self.real, backend=self.backend) as sp:
            t0 = time.perf_counter()
            for kind, label, fn in self._stages(direction, mesh):
                if kind == "transpose":
                    x = fn(x)      # TransposePlan.host_fn emits its spans
                else:
                    with tr.span("fft.stage", cat="workload", stage=label):
                        x = jax.block_until_ready(fn(x))
            sp.set(measured_seconds=time.perf_counter() - t0)
        return x

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary: decomposition geometry +
        every stage plan's describe."""
        preds = [p.describe()["predicted_seconds"] for p in self.plans]
        return {
            "kind": "pencil_fft",
            "global_shape": list(self.global_shape),
            "fft_axes": list(self.fft_axes),
            "grid": [list(g) for g in self.grid],
            "group_sizes": list(self.group_sizes),
            "decomposition": "slab" if self.g == 1 else "pencil",
            "real": self.real,
            "dtype": self.dtype,
            "cdtype": self.cdtype,
            "backend": self.backend,
            "out_local_shape": list(self.out_local_shape),
            "transposes": [p.describe() for p in self.plans],
            "predicted_transpose_seconds":
                None if any(t is None for t in preds) else sum(preds),
        }

    def __repr__(self):
        return (f"PencilFFT(shape={self.global_shape}, grid={self.grid}, "
                f"real={self.real}, backend={self.backend!r})")


def pencil_fft(comm, global_shape, axes=None, **kw) -> PencilFFT:
    """Build (or re-resolve — every transpose plan is registry-cached) a
    :class:`PencilFFT` over ``comm``; see the class for the knobs."""
    return PencilFFT(comm, global_shape, axes=axes, **kw)
