"""JAX version compatibility shims.

The codebase targets the modern JAX API surface (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``, ``jax.make_mesh(axis_types=...)``).
On older runtimes (e.g. 0.4.x, where ``shard_map`` still lives in
``jax.experimental`` and takes ``check_rep``) this module installs
equivalent aliases at import time so the rest of the package is written
against one API.  Imported for its side effects from ``repro.__init__``;
every shim is a no-op when the runtime already provides the modern name.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

    jax.shard_map = shard_map


def _install_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return
    from jax._src import core as _core

    def axis_size(axis_name) -> int:
        names = axis_name if isinstance(axis_name, tuple) else (axis_name,)
        size = 1
        for n in names:
            size *= _core.axis_frame(n)   # returns the int size on 0.4.x
        return size

    jax.lax.axis_size = axis_size


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    if not hasattr(jax, "make_mesh"):
        import math

        import numpy as np
        from jax.sharding import Mesh

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            devs = list(devices) if devices is not None else jax.devices()
            n = math.prod(axis_shapes)
            arr = np.array(devs[:n], dtype=object).reshape(axis_shapes)
            return Mesh(arr, tuple(axis_names))

        jax.make_mesh = make_mesh
        return
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params:
        return
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
        # old runtimes have no axis-type concept: every axis behaves as
        # Auto, which is what the callers request
        return _make_mesh(axis_shapes, axis_names, *args, **kw)

    jax.make_mesh = make_mesh


def install() -> None:
    _install_shard_map()
    _install_axis_size()
    _install_axis_type()
    _install_make_mesh()


install()
