"""Fault-tolerant training runtime."""

from .trainer import Trainer, TrainerConfig
from .watchdog import Action, EscalationPolicy, StragglerWatchdog

__all__ = ["Action", "EscalationPolicy", "Trainer", "TrainerConfig",
           "StragglerWatchdog"]
