"""Fault-tolerant training runtime."""

from .trainer import Trainer, TrainerConfig
from .watchdog import StragglerWatchdog

__all__ = ["Trainer", "TrainerConfig", "StragglerWatchdog"]
