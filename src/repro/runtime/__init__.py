"""Fault-tolerant training and serving runtime."""

from .serving import (
    AdmissionController,
    ContinuousBatcher,
    DisaggregatedServer,
    KVRowCodec,
    PrefillWorker,
    Request,
    ServingTopology,
)
from .trainer import Trainer, TrainerConfig
from .watchdog import Action, EscalationPolicy, StragglerWatchdog

__all__ = ["Action", "AdmissionController", "ContinuousBatcher",
           "DisaggregatedServer", "EscalationPolicy", "KVRowCodec",
           "PrefillWorker", "Request", "ServingTopology",
           "StragglerWatchdog", "Trainer", "TrainerConfig"]
