"""Continuous batching: slot-based serving loop (vLLM-style scheduling,
dense slots).

One jitted ``decode_step`` advances every active slot one token per tick;
slots in *prefill* phase consume their next prompt token (logits ignored),
slots in *decode* phase consume their previously generated token.
Finished slots are reset (per-slot cache re-init) and refilled from the
queue — no global pipeline stall when one request ends, which is the
whole point vs static batching.

Works with any model exposing ``init_caches`` / ``decode_step`` with
per-slot positions (all decoder archs in this repo, incl. ring-buffer SWA
caches and SSM states).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    eos_id: int | None = None
    generated: list[int] = field(default_factory=list)
    # how many generated tokens are already folded into ``prompt`` by
    # ``requeue_inflight`` — keeps a second requeue from re-folding them
    folded: int = 0


def _reset_slot(caches, fresh, b: int):
    """Copy slot b's state from a freshly initialized cache tree.
    Layer-state leaves carry batch on axis 1 (stacked layers first);
    the position vector carries it on axis 0."""
    def f(cur, new):
        if cur.ndim >= 2:
            return cur.at[:, b].set(new[:, b])
        return cur.at[b].set(new[b])
    states = jax.tree.map(f, caches["states"], fresh["states"])
    pos = caches["pos"].at[b].set(0)
    return {"states": states, "pos": pos}


class ContinuousBatcher:
    def __init__(self, model, params, *, max_batch: int, max_seq: int,
                 serve_step=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.caches = model.init_caches(max_batch, max_seq)
        self._fresh = self.caches
        self.slots: list[Request | None] = [None] * max_batch
        self.prefill_cursor = [0] * max_batch
        self.queue: list[Request] = []
        self.done: dict[int, list[int]] = {}
        if serve_step is None:
            def serve_step(params, toks, caches):
                return model.decode_step(params, toks, caches)
            serve_step = jax.jit(serve_step)
        self._step = serve_step
        self.ticks = 0

    # ---- scheduling ----
    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests not yet finished: queued plus in-flight."""
        return len(self.queue) + sum(s is not None for s in self.slots)

    # ---- elasticity ----
    def requeue_inflight(self) -> int:
        """Pull every in-flight request back to the front of the queue
        for deterministic replay after a device loss: the tokens already
        generated are folded into the prompt, so re-admission replays
        the exact token feed (prompt, then prior generations) through
        prefill and resumes decoding where the request left off —
        nothing is dropped, outputs are unchanged.  Returns how many
        requests were requeued."""
        moved = []
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            req.prompt = list(req.prompt) + list(req.generated[req.folded:])
            req.folded = len(req.generated)
            moved.append(req)
            self.slots[b] = None
            self.prefill_cursor[b] = 0
        self.queue[:0] = moved
        return len(moved)

    def rebuild(self, *, model=None, params=None, serve_step=None) -> int:
        """After device loss: requeue all in-flight requests, then
        rebuild the slot caches (and optionally swap model / resharded
        params / jitted step) on the surviving device set.  The queue —
        including the requeued in-flight work — drains on the next
        ``step()``/``run()``; no request is dropped."""
        n = self.requeue_inflight()
        if model is not None:
            self.model = model
        if params is not None:
            self.params = params
        self.caches = self.model.init_caches(self.max_batch, self.max_seq)
        self._fresh = self.caches
        self.prefill_cursor = [0] * self.max_batch
        if serve_step is not None:
            self._step = serve_step
        elif model is not None or params is not None:
            model_ = self.model

            def default_step(params, toks, caches):
                return model_.decode_step(params, toks, caches)
            self._step = jax.jit(default_step)
        return n

    def _admit(self):
        for b in range(self.max_batch):
            if self.slots[b] is None and self.queue:
                req = self.queue.pop(0)
                self.caches = _reset_slot(self.caches, self._fresh, b)
                self.slots[b] = req
                self.prefill_cursor[b] = 0

    def _next_tokens(self) -> np.ndarray:
        toks = np.zeros((self.max_batch, 1), np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            c = self.prefill_cursor[b]
            if c < len(req.prompt):
                toks[b, 0] = req.prompt[c]
            else:
                toks[b, 0] = req.generated[-1]
        return toks

    # ---- main loop ----
    def step(self):
        self._admit()
        if all(s is None for s in self.slots):
            return False
        toks = jnp.asarray(self._next_tokens())
        logits, self.caches = self._step(self.params, toks, self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            c = self.prefill_cursor[b]
            if c < len(req.prompt) - 1:
                self.prefill_cursor[b] = c + 1         # still prefilling
                continue
            if c == len(req.prompt) - 1:
                self.prefill_cursor[b] = c + 1         # first generation
            req.generated.append(int(nxt[b]))
            if len(req.generated) >= req.max_new or \
                    (req.eos_id is not None
                     and req.generated[-1] == req.eos_id):
                self.done[req.rid] = list(req.generated)
                self.slots[b] = None                   # free -> re-admit
        self.ticks += 1
        return True

    def run(self, max_ticks: int = 100_000):
        while self.step() and self.ticks < max_ticks:
            pass
        return self.done
