"""Serving spine: continuous batching plus prefill/decode disaggregation.

Two serving modes share one model contract (``init_caches`` /
``decode_step`` with per-slot positions — all decoder archs in this
repo, incl. ring-buffer SWA caches):

* **Colocated** — :class:`ContinuousBatcher`: slot-based serving loop
  (vLLM-style scheduling, dense slots).  One jitted ``decode_step``
  advances every active slot one token per tick; slots in *prefill*
  phase consume their next prompt token (logits ignored), slots in
  *decode* phase consume their previously generated token.  Finished
  slots are reset (per-slot cache re-init) and refilled from the queue —
  no global pipeline stall when one request ends, which is the whole
  point vs static batching.

* **Disaggregated** — :class:`DisaggregatedServer`: one
  :class:`~repro.core.comm.TorusComm` partitioned into a prefill domain
  and a decode domain (:class:`ServingTopology`, via
  ``TorusComm.partition``), prompt ingestion chunked through
  :class:`PrefillWorker` instances, the same :class:`ContinuousBatcher`
  as the decode side, and the KV-cache handoff between the domains
  expressed as a :class:`~repro.core.plan.KVMigrationPlan` — per-slot KV
  rows are the Alltoallv elements (:class:`KVRowCodec`), per-sequence
  variable lengths the send counts, the scheduler's placement the
  router.  A multi-tenant :class:`AdmissionController` applies
  per-tenant quotas and FIFO-within-tenant ordering, and free decode
  slots backpressure prompt admission.  Elasticity composes with PR 6:
  ``DisaggregatedServer.rebuild`` re-partitions both domains over the
  survivors and replays every in-flight request (``requeue_inflight``
  token folding) — nothing dropped, outputs unchanged.

Because ``decode_step`` advances each batch row independently, a
request's generated tokens depend only on its own token feed and cache
rows — so disaggregated serving is bit-exact with the colocated
reference under any scheduling (device-tested, incl. across a
mid-stream rebuild).
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core import telemetry


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    eos_id: int | None = None
    tenant: str = "default"
    generated: list[int] = field(default_factory=list)
    # how many generated tokens are already folded into ``prompt`` by
    # ``requeue_inflight`` — keeps a second requeue from re-folding them
    folded: int = 0


def _finished(req: Request) -> bool:
    return len(req.generated) >= req.max_new or (
        req.eos_id is not None and bool(req.generated)
        and req.generated[-1] == req.eos_id)


def _reset_slot(caches, fresh, b: int):
    """Copy slot b's state from a freshly initialized cache tree.
    Layer-state leaves carry batch on axis 1 (stacked layers first);
    the position vector carries it on axis 0."""
    def f(cur, new):
        if cur.ndim >= 2:
            return cur.at[:, b].set(new[:, b])
        return cur.at[b].set(new[b])
    states = jax.tree.map(f, caches["states"], fresh["states"])
    pos = caches["pos"].at[b].set(0)
    return {"states": states, "pos": pos}


# ---------------------------------------------------------------------------
# The KV-row datatype: per-slot cache rows <-> flat Alltoallv elements
# ---------------------------------------------------------------------------


class KVRowCodec:
    """The derived-datatype layer of the KV handoff: one *row* per
    sequence slot of the cache, across every layer-state leaf.

    Built from ``cache_logical_axes`` — each state leaf with a
    ``"seq_sp"`` logical axis contributes its per-slot features
    (``slot_pos`` included, so ring-buffer SWA caches migrate exactly).
    ``pack`` flattens one batch slot's first ``n_rows`` sequence slots to
    an ``(n_rows, row_features)`` float32 array — the element type of
    the :class:`~repro.core.plan.KVMigrationPlan`; ``unpack`` is the
    exact inverse into a freshly reset destination slot.

    Families whose recurrent state has no sequence axis (SSM / xLSTM)
    cannot split a sequence between domains; construction fails with a
    clear error rather than migrating silently-wrong state.
    """

    def __init__(self, model, max_seq: int):
        from ..models.transformer import cache_logical_axes
        logical = cache_logical_axes(model.cfg)["states"]
        shapes = jax.eval_shape(
            lambda: model.init_caches(1, int(max_seq)))["states"]
        axes_leaves = jax.tree.leaves(
            logical, is_leaf=lambda x: isinstance(x, tuple))
        shape_leaves = jax.tree.leaves(shapes)
        if len(axes_leaves) != len(shape_leaves):
            raise ValueError("cache_logical_axes does not match "
                             "init_caches structure")
        self._specs: list[tuple[int, int, int]] = []
        seq = None
        feats = 0
        for ax, sh in zip(axes_leaves, shape_leaves):
            if "seq_sp" not in ax or "batch" not in ax:
                raise ValueError(
                    "disaggregated serving needs per-slot sequence-sliced "
                    f"caches; a state leaf with logical axes {ax} has no "
                    "seq_sp axis (recurrent-state family, e.g. SSM/xLSTM "
                    "— its state cannot be split into KV rows)")
            bi, si = ax.index("batch"), ax.index("seq_sp")
            if seq is None:
                seq = int(sh.shape[si])
            elif int(sh.shape[si]) != seq:
                raise ValueError(f"unequal sequence extents across state "
                                 f"leaves: {sh.shape[si]} != {seq}")
            feat = 1
            for i, s in enumerate(sh.shape):
                if i not in (bi, si):
                    feat *= int(s)
            self._specs.append((bi, si, feat))
            feats += feat
        self.seq_slots = int(seq)
        self.row_features = int(feats)

    @property
    def row_shape(self) -> tuple[int, ...]:
        return (self.row_features,)

    def rows_for(self, prompt_len: int) -> int:
        """Sequence slots holding live state after prefilling
        ``prompt_len`` tokens — the per-sequence send count (ring-buffer
        SWA caps it at the window)."""
        return min(int(prompt_len), self.seq_slots)

    def pack(self, states, b: int, n_rows: int) -> np.ndarray:
        """Flatten batch slot ``b``'s first ``n_rows`` sequence slots of
        every state leaf into ``(n_rows, row_features)`` float32."""
        segs = []
        for (bi, si, feat), a in zip(self._specs, jax.tree.leaves(states)):
            moved = jnp.moveaxis(a, (bi, si), (0, 1))[b, :n_rows]
            segs.append(np.asarray(moved).reshape(n_rows, feat)
                        .astype(np.float32))
        return np.concatenate(segs, axis=1) if segs \
            else np.zeros((n_rows, 0), np.float32)

    def unpack(self, states, b: int, rows) -> object:
        """The exact inverse of :meth:`pack`: write ``rows`` into batch
        slot ``b``'s leading sequence slots (the slot must have been
        freshly reset, so untouched trailing slots match the source)."""
        rows = np.asarray(rows, np.float32)
        n = rows.shape[0]
        leaves, treedef = jax.tree.flatten(states)
        out, off = [], 0
        for (bi, si, feat), a in zip(self._specs, leaves):
            seg = rows[:, off:off + feat]
            off += feat
            moved = jnp.moveaxis(a, (bi, si), (0, 1))
            seg = jnp.asarray(seg, np.float32).reshape(
                (n,) + moved.shape[2:]).astype(a.dtype)
            moved = moved.at[b, :n].set(seg)
            out.append(jnp.moveaxis(moved, (0, 1), (bi, si)))
        return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# Colocated serving (the decode side of the disaggregated topology)
# ---------------------------------------------------------------------------


class ContinuousBatcher:
    def __init__(self, model, params, *, max_batch: int, max_seq: int,
                 serve_step=None, comm=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        # The communicator this batcher serves over (optional): the
        # comm-rooted construction surfaces its cache picture through
        # ``stats()`` and scopes a later ``comm.free()`` teardown.
        self.comm = comm
        self.caches = model.init_caches(max_batch, max_seq)
        self._fresh = self.caches
        self.slots: list[Request | None] = [None] * max_batch
        self.prefill_cursor = [0] * max_batch
        self.queue: list[Request] = []
        self.done: dict[int, list[int]] = {}
        if serve_step is None:
            def serve_step(params, toks, caches):
                return model.decode_step(params, toks, caches)
            serve_step = jax.jit(serve_step)
        self._step = serve_step
        self.ticks = 0

    # ---- scheduling ----
    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests not yet finished: queued plus in-flight."""
        return len(self.queue) + sum(s is not None for s in self.slots)

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    def admit_prefilled(self, req: Request, rows, pos: int, *,
                        codec: KVRowCodec) -> bool:
        """Admit a request whose prompt was prefilled elsewhere: reset a
        free slot, unpack the migrated KV rows into it, and resume in
        decode phase (cursor past the prompt, position at ``pos``).
        Returns False when no slot is free."""
        for b in range(self.max_batch):
            if self.slots[b] is None:
                break
        else:
            return False
        self.caches = _reset_slot(self.caches, self._fresh, b)
        states = codec.unpack(self.caches["states"], b, rows)
        self.caches = {"states": states,
                       "pos": self.caches["pos"].at[b].set(int(pos))}
        self.slots[b] = req
        self.prefill_cursor[b] = len(req.prompt)
        return True

    # ---- elasticity ----
    def requeue_inflight(self) -> int:
        """Pull every in-flight request back to the front of the queue
        for deterministic replay after a device loss: the tokens already
        generated are folded into the prompt, so re-admission replays
        the exact token feed (prompt, then prior generations) through
        prefill and resumes decoding where the request left off —
        nothing is dropped, outputs are unchanged.  Returns how many
        requests were requeued."""
        moved = []
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            req.prompt = list(req.prompt) + list(req.generated[req.folded:])
            req.folded = len(req.generated)
            moved.append(req)
            self.slots[b] = None
            self.prefill_cursor[b] = 0
        self.queue[:0] = moved
        return len(moved)

    def rebuild(self, *, model=None, params=None, serve_step=None) -> int:
        """After device loss: requeue all in-flight requests, then
        rebuild the slot caches (and optionally swap model / resharded
        params / jitted step) on the surviving device set.  The queue —
        including the requeued in-flight work — drains on the next
        ``step()``/``run()``; no request is dropped."""
        n = self.requeue_inflight()
        if model is not None:
            self.model = model
        if params is not None:
            self.params = params
        self.caches = self.model.init_caches(self.max_batch, self.max_seq)
        self._fresh = self.caches
        self.prefill_cursor = [0] * self.max_batch
        if serve_step is not None:
            self._step = serve_step
        elif model is not None or params is not None:
            model_ = self.model

            def default_step(params, toks, caches):
                return model_.decode_step(params, toks, caches)
            self._step = jax.jit(default_step)
        return n

    def _admit(self):
        for b in range(self.max_batch):
            if self.slots[b] is None and self.queue:
                req = self.queue.pop(0)
                self.caches = _reset_slot(self.caches, self._fresh, b)
                self.slots[b] = req
                self.prefill_cursor[b] = 0

    def _next_tokens(self) -> np.ndarray:
        toks = np.zeros((self.max_batch, 1), np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            c = self.prefill_cursor[b]
            if c < len(req.prompt):
                toks[b, 0] = req.prompt[c]
            else:
                toks[b, 0] = req.generated[-1]
        return toks

    # ---- main loop ----
    def step(self):
        self._admit()
        if all(s is None for s in self.slots):
            return False
        toks = jnp.asarray(self._next_tokens())
        logits, self.caches = self._step(self.params, toks, self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            c = self.prefill_cursor[b]
            if c < len(req.prompt) - 1:
                self.prefill_cursor[b] = c + 1         # still prefilling
                continue
            if c == len(req.prompt) - 1:
                self.prefill_cursor[b] = c + 1         # first generation
            req.generated.append(int(nxt[b]))
            if _finished(req):
                self.done[req.rid] = list(req.generated)
                self.slots[b] = None                   # free -> re-admit
                telemetry.metrics().counter(
                    "serving.requests_completed").inc()
        self.ticks += 1
        telemetry.metrics().counter("serving.decode_ticks").inc()
        return True

    def run(self, max_ticks: int = 100_000):
        while self.step() and self.ticks < max_ticks:
            pass
        return self.done

    # ---- introspection ----
    def stats(self) -> dict:
        """One call for the serving picture: scheduling counters plus the
        unified all-to-all cache state (``a2a_comm_stats``) — scoped to
        this batcher's comm when it owns one, registry-wide otherwise."""
        from ..core.comm import unified_stats
        return {
            "ticks": self.ticks,
            "max_batch": self.max_batch,
            "queued": len(self.queue),
            "active": sum(s is not None for s in self.slots),
            "done": len(self.done),
            "a2a_comm_stats": unified_stats() if self.comm is None
            else self.comm.stats(),
        }


# ---------------------------------------------------------------------------
# Disaggregated serving: prefill domain, admission, topology, server
# ---------------------------------------------------------------------------


class PrefillWorker:
    """One prefill rank: chunked prompt ingestion into its own slot
    caches.  ``step()`` advances up to ``chunk`` tokens per serving tick
    (bounding prefill latency injected between decode ticks); a sequence
    whose prompt is fully consumed produces its first generated token,
    is packed to KV rows immediately (before any later tick could
    ring-wrap over them), and leaves the worker — the handoff payload.
    """

    def __init__(self, model, params, *, max_batch: int, max_seq: int,
                 codec: KVRowCodec, chunk: int = 4, serve_step=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.codec = codec
        self.chunk = max(1, int(chunk))
        self.caches = model.init_caches(max_batch, max_seq)
        self._fresh = self.caches
        self.slots: list[Request | None] = [None] * max_batch
        self.cursor = [0] * max_batch
        if serve_step is None:
            def serve_step(params, toks, caches):
                return model.decode_step(params, toks, caches)
            serve_step = jax.jit(serve_step)
        self._step = serve_step
        self.ticks = 0

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    def admit(self, req: Request) -> bool:
        for b in range(self.max_batch):
            if self.slots[b] is None:
                self.caches = _reset_slot(self.caches, self._fresh, b)
                self.slots[b] = req
                self.cursor[b] = 0
                return True
        return False

    def step(self) -> list[tuple[Request, np.ndarray, int]]:
        """Run up to ``chunk`` prefill ticks; returns the completed
        handoffs as ``(request, kv_rows, position)`` triples."""
        out = []
        for _ in range(self.chunk):
            if all(s is None for s in self.slots):
                break
            toks = np.zeros((self.max_batch, 1), np.int32)
            for b, req in enumerate(self.slots):
                if req is not None:
                    toks[b, 0] = req.prompt[self.cursor[b]]
            logits, self.caches = self._step(self.params,
                                             jnp.asarray(toks), self.caches)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            for b, req in enumerate(self.slots):
                if req is None:
                    continue
                c = self.cursor[b]
                if c < len(req.prompt) - 1:
                    self.cursor[b] = c + 1             # still prefilling
                    continue
                # last prompt token consumed: first generation, then pack
                # the KV rows before any later tick can overwrite them
                self.cursor[b] = c + 1
                req.generated.append(int(nxt[b]))
                n_rows = self.codec.rows_for(len(req.prompt))
                rows = self.codec.pack(self.caches["states"], b, n_rows)
                out.append((req, rows, len(req.prompt)))
                self.slots[b] = None
            self.ticks += 1
        return out

    def requeue_inflight(self) -> list[Request]:
        """Drain in-flight prompts for replay on a rebuilt topology (a
        prefilling request has no folded state to preserve — its prompt
        simply replays from the start)."""
        moved = [req for req in self.slots if req is not None]
        self.slots = [None] * self.max_batch
        self.cursor = [0] * self.max_batch
        return moved


class AdmissionController:
    """Multi-tenant admission: FIFO within each tenant, round-robin
    across tenants, per-tenant in-flight quotas (``quotas`` overrides
    per tenant; ``default_quota`` applies otherwise, ``None`` =
    unlimited).  The server's decode-slot backpressure sets how many
    requests each ``admit`` call may release."""

    def __init__(self, *, quotas=None, default_quota: int | None = None):
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota
        self.queues: dict[str, deque] = {}
        self.inflight: dict[str, int] = {}
        self._order: list[str] = []
        self._rr = 0

    def submit(self, req: Request):
        if req.tenant not in self.queues:
            self.queues[req.tenant] = deque()
            self._order.append(req.tenant)
        self.queues[req.tenant].append(req)

    def requeue_front(self, reqs) -> None:
        """Push replayed requests back to the *front* of their tenants'
        queues (deterministic replay after a rebuild: requeued work
        precedes anything newly submitted)."""
        for req in reversed(list(reqs)):
            if req.tenant not in self.queues:
                self.queues[req.tenant] = deque()
                self._order.append(req.tenant)
            self.queues[req.tenant].appendleft(req)

    def quota(self, tenant: str) -> int | None:
        return self.quotas.get(tenant, self.default_quota)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def admit(self, n: int) -> list[Request]:
        """Release up to ``n`` requests, rotating across tenants."""
        out: list[Request] = []
        while len(out) < n and self._order:
            progressed = False
            for _ in range(len(self._order)):
                t = self._order[self._rr % len(self._order)]
                self._rr += 1
                q = self.queues.get(t)
                if not q:
                    continue
                quota = self.quota(t)
                if quota is not None and self.inflight.get(t, 0) >= quota:
                    continue
                out.append(q.popleft())
                self.inflight[t] = self.inflight.get(t, 0) + 1
                progressed = True
                if len(out) >= n:
                    break
            if not progressed:
                break
        return out

    def release(self, req: Request) -> None:
        self.inflight[req.tenant] = max(
            0, self.inflight.get(req.tenant, 0) - 1)


class ServingTopology:
    """One serving torus partitioned into prefill and decode domains.

    ``comm.partition(n_prefill)`` yields the two domain sub-comms
    (``MPI_Comm_split`` by device range); the KV handoff between them is
    one :class:`~repro.core.plan.KVMigrationPlan` over the *full* comm —
    ranks ``0..n_prefill-1`` are prefill sources, the rest decode
    destinations.  When ``n_prefill`` is omitted the split is sized by
    the alpha-beta model (``core.tuning.choose_serving_split``): the
    predicted migration cost is part of the per-tick objective, so a
    torus with slow links leans toward fewer, longer-lived migrations.
    """

    def __init__(self, comm, *, row_shape, max_count: int,
                 dtype="float32", n_prefill: int | None = None,
                 migrations_per_tick: float = 1.0, backend: str = "tuned",
                 links=None):
        from ..core.tuning import choose_serving_split
        self.split = None
        if n_prefill is None:
            row_bytes = math.prod(tuple(row_shape)) \
                * jnp.dtype(dtype).itemsize
            self.split = choose_serving_split(
                comm.dims, links, row_bytes=float(row_bytes),
                max_count=int(max_count),
                migrations_per_tick=migrations_per_tick)
            n_prefill = self.split.n_prefill
        self.comm = comm
        self.n_prefill = int(n_prefill)
        self.prefill_comm, self.decode_comm = comm.partition(self.n_prefill)
        self.plan = comm.kv_migration(
            tuple(row_shape), dtype, max_count=int(max_count),
            n_prefill=self.n_prefill,
            migrations_per_tick=migrations_per_tick, backend=backend,
            links=links)
        self.migrated_rows = 0
        self.migrations = 0

    @property
    def n_decode(self) -> int:
        return self.comm.p - self.n_prefill

    def migrate(self, rows_by_pair: dict, *, device=None) -> dict:
        """Execute one KV handoff tick: ``{(src, dst): [row, ...]}`` in,
        the delivered rows per pair out — ONE collective through the
        plan, never a per-sequence copy loop.  Device-backed comms run
        the bucketed jitted ``host_fn``; device-agnostic comms run the
        plan's exact host path (``device=`` overrides)."""
        if not rows_by_pair:
            return {}
        counts = self.plan.pair_counts(
            {k: len(v) for k, v in rows_by_pair.items()})
        p = self.comm.p
        use_device = (self.comm.mesh is not None) if device is None \
            else bool(device)
        if use_device:
            dt = jnp.dtype(self.plan.dtype)
            x = np.zeros((p, p, self.plan.bucket) + self.plan.row_shape, dt)
            for (s, d), rs in rows_by_pair.items():
                x[s, d, :len(rs)] = np.asarray(rs, dt)
            recv, _ = self.plan.host_fn()(jnp.asarray(x),
                                          jnp.asarray(counts))
            recv = np.asarray(recv)
            out = {(s, d): [recv[d, s, j] for j in range(counts[s, d])]
                   for (s, d) in rows_by_pair}
        else:
            rows = [[[] for _ in range(p)] for _ in range(p)]
            for (s, d), rs in rows_by_pair.items():
                rows[s][d] = list(rs)
            recv, _ = self.plan.exact(rows)
            out = {(s, d): recv[d][s] for (s, d) in rows_by_pair}
        self.migrations += 1
        self.migrated_rows += int(counts.sum())
        return out

    def rebuild(self, surviving_devices, *,
                n_prefill: int | None = None) -> "ServingTopology":
        """Elastic re-partition: rebuild the underlying comm over the
        survivors (PR 6 semantics — this topology's plan slice is
        freed), then split the fresh torus into new prefill/decode
        domains (re-sized by the cost model unless pinned)."""
        fresh = self.comm.rebuild(surviving_devices)
        return ServingTopology(
            fresh, row_shape=self.plan.row_shape,
            max_count=self.plan.max_count, dtype=self.plan.dtype,
            n_prefill=n_prefill,
            migrations_per_tick=self.plan.migrations_per_tick,
            backend=self.plan.requested_backend)

    def describe(self) -> dict:
        return {
            "kind": "serving_topology",
            "comm": self.comm.describe(),
            "n_prefill": self.n_prefill,
            "n_decode": self.n_decode,
            "prefill_axes": list(self.prefill_comm.axis_names),
            "prefill_dims": list(self.prefill_comm.dims),
            "decode_axes": list(self.decode_comm.axis_names),
            "decode_dims": list(self.decode_comm.dims),
            "plan": self.plan.describe(),
            "split": None if self.split is None else {
                "predicted_seconds": self.split.predicted_seconds,
                "migration_kind": self.split.migration_kind,
            },
            "migrations": self.migrations,
            "migrated_rows": self.migrated_rows,
        }


class DisaggregatedServer:
    """The unified serving API over one torus: admission -> prefill
    domain -> KV migration -> decode domain, one tick at a time.

    Each prefill rank is a :class:`PrefillWorker`; the decode domain is
    one :class:`ContinuousBatcher` rooted on the decode sub-comm.  Per
    tick: the admission controller releases as many prompts as the
    decode domain has headroom for (decode-slot backpressure throttles
    prefill), workers advance their chunks, completed prefills stage for
    migration, at most one staged sequence per (src, dst) pair moves in
    ONE plan collective, and the decode batcher ticks.  ``rebuild``
    replays every in-flight request across a re-partitioned survivor
    topology — zero dropped requests, identical outputs.
    """

    def __init__(self, model, params, comm, *, max_seq: int,
                 decode_batch: int, prefill_batch: int = 2,
                 n_prefill: int | None = None, chunk: int = 4,
                 quotas=None, default_quota: int | None = None,
                 backend: str = "tuned", migrations_per_tick=None,
                 serve_step=None):
        self.model = model
        self.params = params
        self.max_seq = int(max_seq)
        self.decode_batch = int(decode_batch)
        self.prefill_batch = int(prefill_batch)
        self.chunk = int(chunk)
        self._serve_step = serve_step
        self.codec = KVRowCodec(model, max_seq)
        if migrations_per_tick is None:
            migrations_per_tick = 1.0
        self.topology = ServingTopology(
            comm, row_shape=self.codec.row_shape,
            max_count=self.codec.seq_slots, n_prefill=n_prefill,
            migrations_per_tick=migrations_per_tick, backend=backend)
        self.admission = AdmissionController(quotas=quotas,
                                             default_quota=default_quota)
        self._build_domains()
        self.staged: list[tuple[int, Request, np.ndarray, int]] = []
        self._decoding: dict[int, Request] = {}
        self.done: dict[int, list[int]] = {}
        self.ticks = 0
        self._rr_dst = 0

    def _build_domains(self):
        mk_step = (lambda: self._serve_step) if self._serve_step is not None \
            else (lambda: None)
        self.workers = [
            PrefillWorker(self.model, self.params,
                          max_batch=self.prefill_batch,
                          max_seq=self.max_seq, codec=self.codec,
                          chunk=self.chunk, serve_step=mk_step())
            for _ in range(self.topology.n_prefill)]
        self.batcher = ContinuousBatcher(
            self.model, self.params, max_batch=self.decode_batch,
            max_seq=self.max_seq, comm=self.topology.decode_comm,
            serve_step=mk_step())

    # ---- scheduling ----
    def submit(self, req: Request):
        self.admission.submit(req)

    @property
    def pending(self) -> int:
        return (self.admission.pending + len(self.staged)
                + sum(w.active for w in self.workers)
                + self.batcher.pending)

    # ---- main loop ----
    def tick(self) -> bool:
        """One serving tick; returns False once the system is drained."""
        if self.pending == 0:
            return False
        tr = telemetry.get_tracer()
        with tr.span("serve.tick", cat="serving", tick=self.ticks):
            # 1. admission, throttled by decode headroom: never release
            # more prompts than the decode domain can absorb beyond what
            # is already in flight through prefill/migration.
            with tr.span("serve.admission", cat="serving") as sp:
                headroom = self.batcher.max_batch - self.batcher.pending \
                    - len(self.staged) - sum(w.active for w in self.workers)
                budget = min(max(0, headroom),
                             sum(w.free_slots for w in self.workers))
                # drift backpressure: while any plan's measured round
                # times sit above the cost-model threshold (the same
                # signal the watchdog turns into a re-tune), halve the
                # admission budget — don't pile new load onto a comm
                # that is running off its tuned operating point.
                drift = telemetry.drift_detector().summary()
                if budget > 0 and any(v["drifted"] for v in drift.values()):
                    budget //= 2
                    telemetry.metrics().counter(
                        "serving.admission_throttled").inc()
                    sp.set(drift_throttled=True)
                admitted = 0
                for req in self.admission.admit(budget):
                    # least-loaded prefill worker = the placement router
                    worker = max(self.workers, key=lambda w: w.free_slots)
                    assert worker.admit(req)
                    admitted += 1
                sp.set(budget=budget, admitted=admitted)
            # 2. prefill chunks; completed prompts stage for migration (a
            # request finished by its very first token skips the decode
            # domain entirely).
            with tr.span("serve.prefill", cat="serving") as sp:
                completed = 0
                for src, worker in enumerate(self.workers):
                    for req, rows, pos in worker.step():
                        completed += 1
                        if _finished(req):
                            self.done[req.rid] = list(req.generated)
                            self.admission.release(req)
                        else:
                            self.staged.append((src, req, rows, pos))
                sp.set(completed=completed)
            # 3. KV migration: at most one staged sequence per (src, dst)
            # pair per tick (counts stay within the plan's max_count
            # bound), gated on free decode slots — one collective for
            # all of them.
            with tr.span("serve.kv_migrate", cat="serving") as sp:
                free = self.batcher.free_slots
                batch: dict[tuple[int, int], tuple] = {}
                remaining = []
                for entry in self.staged:
                    src, req, rows, pos = entry
                    dst = self.topology.n_prefill \
                        + self._rr_dst % self.topology.n_decode
                    if len(batch) < free and (src, dst) not in batch:
                        batch[(src, dst)] = entry
                        self._rr_dst += 1
                    else:
                        remaining.append(entry)
                self.staged = remaining
                if batch:
                    delivered = self.topology.migrate(
                        {pair: e[2] for pair, e in batch.items()})
                    for pair, (_, req, _, pos) in batch.items():
                        ok = self.batcher.admit_prefilled(
                            req, np.asarray(delivered[pair]), pos,
                            codec=self.codec)
                        assert ok, "migration was gated on free decode slots"
                        self._decoding[req.rid] = req
                sp.set(migrated=len(batch))
            # 4. decode tick + completion bookkeeping.
            with tr.span("serve.decode", cat="serving") as sp:
                self.batcher.step()
                finished = 0
                for rid, toks in list(self.batcher.done.items()):
                    if rid not in self.done:
                        self.done[rid] = toks
                        finished += 1
                    req = self._decoding.pop(rid, None)
                    if req is not None:
                        self.admission.release(req)
                sp.set(finished=finished)
        self.ticks += 1
        return True

    def run(self, max_ticks: int = 100_000):
        while self.tick() and self.ticks < max_ticks:
            pass
        return self.done

    # ---- elasticity ----
    def rebuild(self, surviving_devices, *,
                params=None, n_prefill: int | None = None) -> int:
        """Detect -> degrade -> rebuild -> resume, serving edition:
        requeue every in-flight request (decode in-flight folds its
        generated tokens; prefill in-flight and staged migrations simply
        replay), re-partition the survivor torus into fresh domains, and
        let the admission queue drain through the new topology — zero
        dropped requests, outputs unchanged.  Returns the requeue count.
        """
        if params is not None:
            self.params = params
        # decode in-flight: fold generated tokens, then drain the queue
        self.batcher.requeue_inflight()
        decode_reqs = list(self.batcher.queue)
        self.batcher.queue.clear()
        staged_reqs = [req for (_, req, _, _) in self.staged]
        self.staged = []
        prefill_reqs = []
        for worker in self.workers:
            prefill_reqs.extend(worker.requeue_inflight())
        reqs = decode_reqs + staged_reqs + prefill_reqs
        self._decoding.clear()
        for req in reqs:
            self.admission.release(req)
        self.admission.requeue_front(reqs)
        self.topology = self.topology.rebuild(surviving_devices,
                                              n_prefill=n_prefill)
        self._build_domains()
        return len(reqs)

    # ---- introspection ----
    def stats(self) -> dict:
        out = self.batcher.stats()
        out.update({
            "server_ticks": self.ticks,
            "pending": self.pending,
            "staged": len(self.staged),
            "prefill_active": [w.active for w in self.workers],
            "topology": self.topology.describe(),
        })
        return out
