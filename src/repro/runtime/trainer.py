"""Fault-tolerant trainer: checkpoint/restart, preemption, stragglers.

The loop is restart-idempotent: all state (params, optimizer, data cursor,
step) round-trips through the checkpoint, so ``Trainer.run()`` after a
crash resumes bit-exact (tested).  SIGTERM triggers a final synchronous
checkpoint before exit (preemption handling).  Gradient accumulation and
the straggler watchdog live here; the step function itself is the shared
jitted ``make_train_step``.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.runtime.watchdog import StepTimer, StragglerWatchdog


@dataclass
class TrainerConfig:
    total_steps: int
    checkpoint_dir: str
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
    grad_accum: int = 1
    async_checkpoint: bool = True
    abort_on_hang: bool = True


@dataclass
class Trainer:
    config: TrainerConfig
    train_step: Callable                 # (params, opt, batch) -> (...)
    data: Any                            # SyntheticLM-like
    params: Any
    opt_state: Any
    step: int = 0
    metrics_log: list = field(default_factory=list)
    watchdog: StragglerWatchdog = field(default_factory=StragglerWatchdog)
    _preempted: bool = False

    def __post_init__(self):
        self.ckpt = CheckpointManager(self.config.checkpoint_dir,
                                      self.config.keep_checkpoints)

    # ---- checkpoint plumbing ----
    def _state_tree(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self, sync=False):
        extra = {"step": self.step, "data": self.data.state_dict(),
                 "wall": time.time()}
        if sync or not self.config.async_checkpoint:
            self.ckpt.save_sync(self.step, self._state_tree(), extra)
        else:
            self.ckpt.save_async(self.step, self._state_tree(), extra)

    def try_restore(self, shardings=None) -> bool:
        latest = self.ckpt.latest()
        if latest is None:
            return False
        tree, extra, step = self.ckpt.restore(self._state_tree(), shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.step = int(extra["step"])
        self.data.load_state_dict(extra["data"])
        return True

    # ---- preemption ----
    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    # ---- main loop ----
    def run(self, max_steps: int | None = None):
        cfg = self.config
        end = min(cfg.total_steps,
                  self.step + (max_steps or cfg.total_steps))
        while self.step < end:
            batch = self.data.next()
            with StepTimer() as t:
                # grad accumulation happens inside the jitted step
                # (make_train_step(grad_accum=...)); cfg.grad_accum is
                # plumbing for the builder, not a host loop.
                self.params, self.opt_state, metrics = \
                    self.train_step(self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["total_loss"])
            self.step += 1

            verdict = self.watchdog.observe(self.step, t.seconds)
            if verdict == "hang" and cfg.abort_on_hang:
                self.save(sync=True)
                raise RuntimeError(
                    f"watchdog: presumed hang at step {self.step} "
                    f"({t.seconds:.3f}s vs median "
                    f"{self.watchdog.median:.3f}s); checkpointed for "
                    f"restart")

            if self.step % cfg.log_every == 0 or self.step == end:
                row = {k: float(v) for k, v in metrics.items()}
                row.update(step=self.step, seconds=t.seconds,
                           verdict=verdict)
                self.metrics_log.append(row)

            if self.step % cfg.checkpoint_every == 0:
                self.save()
            if self._preempted:
                self.save(sync=True)
                return "preempted"
        self.ckpt.wait()
        return "done"

