"""Fault-tolerant trainer: checkpoint/restart, preemption, elasticity.

The loop is restart-idempotent: all state (params, optimizer, data cursor,
step) round-trips through the checkpoint, so ``Trainer.run()`` after a
crash resumes bit-exact (tested).  SIGTERM triggers a final synchronous
checkpoint before exit (preemption handling).  Gradient accumulation and
the straggler watchdog live here; the step function itself is the shared
jitted ``make_train_step``.

With ``TrainerConfig.elastic`` the loop drives on the watchdog's
escalation :class:`~repro.runtime.watchdog.Action` instead of bare
verdict strings — detect→degrade→rebuild→resume:

* ``retry`` (straggler): the step already committed, so a retry is a
  backoff sleep, never a re-execution (re-running would double-apply
  the gradient update).
* ``recover`` after a *hang*: the state is intact, just slow —
  checkpoint-now, then ``rebuild_fn`` re-factorizes the communicator
  (``TorusComm.rebuild``) and the trainer restores onto the new mesh via
  elastic resharding.
* ``recover`` after *device loss* (:class:`DeviceLossError` escaping the
  step): the in-flight step never committed and the devices holding the
  live state are gone, so the current state is NOT checkpointed —
  recovery restores the last durable checkpoint onto the survivor torus.
* ``abort``: budgets exhausted — checkpoint if the state is trustworthy
  and raise for external restart.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import telemetry
from repro.core.faults import DeviceLossError, FaultError
from repro.runtime.watchdog import StepTimer, StragglerWatchdog


@dataclass
class TrainerConfig:
    total_steps: int
    checkpoint_dir: str
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
    grad_accum: int = 1
    async_checkpoint: bool = True
    abort_on_hang: bool = True
    # drive the escalation policy (retry/recover/abort) instead of the
    # legacy hang-abort; requires rebuild_fn for the recover path
    elastic: bool = False


@dataclass
class Trainer:
    config: TrainerConfig
    train_step: Callable                 # (params, opt, batch) -> (...)
    data: Any                            # SyntheticLM-like
    params: Any
    opt_state: Any
    step: int = 0
    metrics_log: list = field(default_factory=list)
    watchdog: StragglerWatchdog = field(default_factory=StragglerWatchdog)
    # elastic recovery hook: (trainer, error_or_None) rebuilds the
    # communicator/mesh on the survivors, swaps train_step/data as
    # needed, and returns the shardings tree for the elastic restore
    rebuild_fn: Callable | None = None
    recoveries_done: int = 0
    # drift-retune advisories from the telemetry DriftDetector, routed
    # through the watchdog: list of (step, drift_key, Action)
    retune_log: list = field(default_factory=list)
    _preempted: bool = False

    def __post_init__(self):
        self.ckpt = CheckpointManager(self.config.checkpoint_dir,
                                      self.config.keep_checkpoints)

    # ---- checkpoint plumbing ----
    def _state_tree(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self, sync=False):
        extra = {"step": self.step, "data": self.data.state_dict(),
                 "wall": time.time()}
        if sync or not self.config.async_checkpoint:
            self.ckpt.save_sync(self.step, self._state_tree(), extra)
        else:
            self.ckpt.save_async(self.step, self._state_tree(), extra)

    def try_restore(self, shardings=None) -> bool:
        latest = self.ckpt.latest()
        if latest is None:
            return False
        tree, extra, step = self.ckpt.restore(self._state_tree(), shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.step = int(extra["step"])
        self.data.load_state_dict(extra["data"])
        return True

    # ---- preemption ----
    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    # ---- elastic recovery ----
    def _recover(self, error: Exception | None, reason: str) -> None:
        """checkpoint-now (hang only) → rebuild comm → restore → resume."""
        if error is None:
            # hang: the live state is intact, make it durable first
            self.save(sync=True)
        if self.rebuild_fn is None:
            raise FaultError(f"recovery requested ({reason}) but no "
                             f"rebuild_fn is configured")
        shardings = self.rebuild_fn(self, error)
        if not self.try_restore(shardings):
            raise FaultError(f"recovery ({reason}): no durable "
                             f"checkpoint to restore from")
        self.recoveries_done += 1

    # ---- main loop ----
    def run(self, max_steps: int | None = None):
        cfg = self.config
        end = min(cfg.total_steps,
                  self.step + (max_steps or cfg.total_steps))
        while self.step < end:
            batch = self.data.next()
            try:
                with StepTimer() as t, telemetry.get_tracer().span(
                        "train.step", cat="trainer", step=self.step + 1):
                    # grad accumulation happens inside the jitted step
                    # (make_train_step(grad_accum=...)); cfg.grad_accum
                    # is plumbing for the builder, not a host loop.
                    self.params, self.opt_state, metrics = \
                        self.train_step(self.params, self.opt_state, batch)
                    jax.block_until_ready(metrics["total_loss"])
            except DeviceLossError as err:
                if not cfg.elastic:
                    raise
                # the step never committed: params/opt/step/data-cursor
                # roll back to the last checkpoint during recovery
                action = self.watchdog.policy(self.step + 1, t.seconds,
                                              verdict="device_loss")
                if action.kind == "recover":
                    self._recover(err, action.reason)
                    continue
                raise FaultError(f"device loss at step {self.step + 1}: "
                                 f"{action.reason}") from err
            self.step += 1

            if cfg.elastic:
                action = self.watchdog.policy(self.step, t.seconds)
                verdict = self.watchdog.last_verdict
                if action.kind == "retry":
                    # the slow step still committed — a straggler retry
                    # is backoff-then-continue, never a re-execution
                    time.sleep(action.backoff)
                elif action.kind == "recover":
                    self._recover(None, action.reason)
                    continue
                elif action.kind == "abort":
                    self.save(sync=True)
                    raise FaultError(f"watchdog abort at step "
                                     f"{self.step}: {action.reason}")
                # advisory lane: measured-vs-model drift → "retune"
                # recommendations (never retries/recoveries, never raises)
                for key, act in self.watchdog.check_drift(step=self.step):
                    self.retune_log.append((self.step, key, act))
            else:
                verdict = self.watchdog.observe(self.step, t.seconds)
                if verdict == "hang" and cfg.abort_on_hang:
                    self.save(sync=True)
                    raise RuntimeError(
                        f"watchdog: presumed hang at step {self.step} "
                        f"({t.seconds:.3f}s vs median "
                        f"{self.watchdog.median:.3f}s); checkpointed for "
                        f"restart")

            if self.step % cfg.log_every == 0 or self.step == end:
                row = {k: float(v) for k, v in metrics.items()}
                row.update(step=self.step, seconds=t.seconds,
                           verdict=verdict)
                self.metrics_log.append(row)

            if self.step % cfg.checkpoint_every == 0:
                self.save()
            if self._preempted:
                self.save(sync=True)
                return "preempted"
        self.ckpt.wait()
        return "done"
