"""Straggler / hang detection and the escalation policy state machine.

At multi-pod scale the common failure modes are (a) a slow host
(straggler) stretching every step, (b) a hung collective, and (c) a lost
device surfacing as an exception from the runtime.  The first two show
up in the step-time series: :class:`StragglerWatchdog` keeps a robust
running estimate (median + MAD over a window) and classifies each step.
What to *do* about a verdict is the :class:`EscalationPolicy` state
machine — bounded retry with exponential backoff for stragglers,
recovery (checkpoint-now → rebuild comm → restore → resume) for hangs
and device loss, and abort when the retry/recovery budget or the
per-incident timeout is exhausted.  The trainer and serving loops drive
on the returned :class:`Action`, never on bare strings.

On a real cluster the per-host step times come from the coordination
service; here the single process stands in for the fleet, and the tests
inject synthetic slow steps and device-loss exceptions
(``core.faults``).
"""

from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field

from ..core import telemetry

VERDICTS = ("ok", "straggler", "hang", "device_loss", "drift")
ACTIONS = ("continue", "retry", "recover", "abort", "retune")


@dataclass(frozen=True)
class Action:
    """One escalation decision: what the control loop does next.

    ``kind``: "continue" (nothing to do), "retry" (re-attempt after
    ``backoff`` seconds), "recover" (checkpoint-now → rebuild comm →
    restore → resume), "abort" (checkpoint and raise for external
    restart), or "retune" (measured round times drifted off the
    plan's cost-model prediction — re-run autotune at the next
    convenient boundary; advisory, never consumes retry budget).
    """

    kind: str
    backoff: float = 0.0
    reason: str = ""

    def __post_init__(self):
        if self.kind not in ACTIONS:
            raise ValueError(f"unknown action {self.kind!r}")


@dataclass
class EscalationPolicy:
    """Bounded-retry escalation: verdicts in, :class:`Action` out.

    Transitions:

    * ``ok`` closes any open incident: the retry streak and incident
      clock reset (the recovery budget is per-run, not per-incident).
    * ``straggler`` → ``retry`` with exponential backoff
      (``backoff_base * backoff_factor**(n-1)``) up to ``max_retries``
      consecutive times; a straggler streak past the budget escalates to
      the hang handling below.
    * ``hang`` / ``device_loss`` → ``recover`` up to ``max_recoveries``
      times per run, then ``abort``.
    * any incident open longer than ``incident_timeout`` wall seconds →
      ``abort`` regardless of remaining budget (the timeout-per-verdict
      backstop: escalation itself must not hang).

    ``decide`` takes an optional ``now`` (monotonic seconds) so the
    state machine is fully deterministic under test.
    """

    max_retries: int = 2
    backoff_base: float = 0.05
    backoff_factor: float = 2.0
    max_recoveries: int = 2
    incident_timeout: float = 300.0
    retries: int = 0
    recoveries: int = 0
    transitions: deque = field(
        default_factory=lambda: deque(maxlen=256))
    _incident_start: float | None = None

    def decide(self, verdict, now: float | None = None) -> Action:
        kind = str(verdict)
        if kind not in VERDICTS:
            raise ValueError(f"unknown verdict {kind!r}; "
                             f"expected one of {VERDICTS}")
        now = time.monotonic() if now is None else now
        action = self._decide(kind, now)
        self.transitions.append((kind, action.kind))
        return action

    def _decide(self, kind: str, now: float) -> Action:
        if kind == "ok":
            self.retries = 0
            self._incident_start = None
            return Action("continue")
        if kind == "drift":
            # Advisory: performance drifted off the tuned cost model.
            # Not a fault — no incident opens, no retry/recovery budget
            # is spent; the loop should schedule a re-tune.
            return Action("retune",
                          reason="measured/model drift above threshold")
        if self._incident_start is None:
            self._incident_start = now
        open_for = now - self._incident_start
        if open_for > self.incident_timeout:
            return Action("abort",
                          reason=f"incident open {open_for:.1f}s > "
                                 f"timeout {self.incident_timeout}s")
        if kind == "straggler":
            if self.retries < self.max_retries:
                self.retries += 1
                backoff = self.backoff_base \
                    * self.backoff_factor ** (self.retries - 1)
                return Action("retry", backoff=backoff,
                              reason=f"straggler retry "
                                     f"{self.retries}/{self.max_retries}")
            kind = "hang"   # persistent straggler: escalate
        # hang / device_loss: the recovery ladder
        if self.recoveries < self.max_recoveries:
            self.recoveries += 1
            self.retries = 0
            return Action("recover",
                          reason=f"{kind}: recovery "
                                 f"{self.recoveries}/{self.max_recoveries}")
        return Action("abort",
                      reason=f"{kind}: recovery budget "
                             f"({self.max_recoveries}) exhausted")

    def reset(self) -> None:
        """Forget all streaks and budgets (a fresh run)."""
        self.retries = 0
        self.recoveries = 0
        self._incident_start = None


@dataclass
class StragglerWatchdog:
    window: int = 50
    slow_factor: float = 2.5       # step > factor * median -> straggler
    hang_factor: float = 10.0      # step > factor * median -> presumed hang
    # absolute floor for the (fatal) hang verdict: a real hung collective
    # stalls for seconds, while a millisecond-scale median makes the
    # relative test promote OS scheduling jitter to an abort
    hang_floor_seconds: float = 1.0
    min_samples: int = 5
    # anomalous-step events are bounded: a weeks-long run with a noisy
    # host must not grow the list forever; overflow is counted, not kept
    max_events: int = 512
    events_dropped: int = 0
    last_verdict: str = "ok"
    escalation: EscalationPolicy = field(default_factory=EscalationPolicy)
    _times: deque = field(default_factory=lambda: deque(maxlen=256))
    events: deque = None

    def __post_init__(self):
        if self.events is None:
            self.events = deque(maxlen=self.max_events)

    def _record(self, event: tuple) -> None:
        if self.events.maxlen is not None \
                and len(self.events) == self.events.maxlen:
            self.events_dropped += 1
            telemetry.metrics().counter("watchdog.events_dropped").inc()
            telemetry.warn_once(
                self, "_warned_events_dropped",
                f"watchdog event window full (max_events="
                f"{self.events.maxlen}); oldest anomaly events are being "
                f"dropped — see watchdog.events_dropped for the count")
        self.events.append(event)

    def observe(self, step: int, seconds: float) -> str:
        """Classify a step: 'ok' | 'straggler' | 'hang'."""
        history = list(self._times)[-self.window:]
        self._times.append(seconds)
        if len(history) < self.min_samples:
            return "ok"
        med = statistics.median(history)
        mad = statistics.median([abs(t - med) for t in history]) or 1e-9
        if seconds > max(self.hang_factor * med, med + 20 * mad) \
                and seconds >= self.hang_floor_seconds:
            self._record(("hang", step, seconds, med))
            return "hang"
        if seconds > max(self.slow_factor * med, med + 8 * mad):
            self._record(("straggler", step, seconds, med))
            return "straggler"
        return "ok"

    def policy(self, step: int, seconds: float, *,
               verdict: str | None = None,
               now: float | None = None) -> Action:
        """The control-loop hook: classify the step (or accept an
        externally detected ``verdict``, e.g. "device_loss" from a
        :class:`~repro.core.faults.DeviceLossError`) and run it through
        the escalation policy, returning the :class:`Action` — not a
        bare string.  Non-continue actions are recorded as events."""
        if verdict is None:
            verdict = self.observe(step, seconds)
        elif verdict != "ok":
            self._record((verdict, step, seconds, self.median))
        self.last_verdict = verdict
        action = self.escalation.decide(verdict, now=now)
        if action.kind != "continue":
            self._record((f"action:{action.kind}", step, seconds,
                          action.reason))
        return action

    def check_drift(self, detector=None, step: int | None = None):
        """Poll the telemetry :class:`~repro.core.telemetry.DriftDetector`
        for fresh re-tune recommendations and route each through the
        escalation policy as a "drift" verdict (→ "retune" action,
        advisory — no retry/recovery budget is consumed).

        Returns a list of ``(drift_key, Action)`` pairs, one per newly
        recommended key (empty when nothing drifted — the common case;
        cheap enough to call every step).
        """
        detector = telemetry.drift_detector() if detector is None \
            else detector
        out = []
        for rec in detector.recommendations():
            self._record(("drift", step, rec["ratio"], rec["key"]))
            action = self.escalation.decide("drift")
            self.last_verdict = "drift"
            out.append((rec["key"], action))
        return out

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
