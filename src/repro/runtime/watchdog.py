"""Straggler / hang detection from per-step wall times.

At multi-pod scale the common failure modes are (a) a slow host
(straggler) stretching every step, and (b) a hung collective.  Both show
up in the step-time series.  The watchdog keeps a robust running estimate
(median + MAD over a window) and classifies each step; the trainer policy
reacts (log, checkpoint-now, or abort-for-restart).

On a real cluster the per-host step times come from the coordination
service; here the single process stands in for the fleet, and the tests
inject synthetic slow steps.
"""

from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class StragglerWatchdog:
    window: int = 50
    slow_factor: float = 2.5       # step > factor * median -> straggler
    hang_factor: float = 10.0      # step > factor * median -> presumed hang
    # absolute floor for the (fatal) hang verdict: a real hung collective
    # stalls for seconds, while a millisecond-scale median makes the
    # relative test promote OS scheduling jitter to an abort
    hang_floor_seconds: float = 1.0
    min_samples: int = 5
    _times: deque = field(default_factory=lambda: deque(maxlen=256))
    events: list = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> str:
        """Classify a step: 'ok' | 'straggler' | 'hang'."""
        history = list(self._times)[-self.window:]
        self._times.append(seconds)
        if len(history) < self.min_samples:
            return "ok"
        med = statistics.median(history)
        mad = statistics.median([abs(t - med) for t in history]) or 1e-9
        if seconds > max(self.hang_factor * med, med + 20 * mad) \
                and seconds >= self.hang_floor_seconds:
            self.events.append(("hang", step, seconds, med))
            return "hang"
        if seconds > max(self.slow_factor * med, med + 8 * mad):
            self.events.append(("straggler", step, seconds, med))
            return "straggler"
        return "ok"

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
