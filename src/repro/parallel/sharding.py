"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code annotates every tensor dimension with a *logical* axis name
("batch", "embed", "heads", "expert", ...).  The rules map logical names
to physical mesh axes; the resolver drops physical axes that do not divide
the dimension or are already consumed by another dimension of the same
tensor — tiny models (whisper) then simply replicate where big models
shard, with no per-arch special cases.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rules: logical name -> preferred physical axes, in priority order.
# Tuples mean "shard over the product of these axes".
DEFAULT_RULES: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("batch", ("pod", "data")),
    ("fsdp", ("pod", "data")),      # parameter sharding (ZeRO/FSDP dim)
    ("seq", ()),                    # replicated by default
    ("seq_sp", ("model",)),         # sequence parallelism (Ulysses / decode KV)
    ("embed", ()),                  # activation d_model: replicated
    ("embed_tp", ("model",)),       # param d_model rows under TP
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("mlp", ("model",)),
    ("vocab", ("model",)),
    ("act_embed", ("model",)),      # activation d_model between layers
    ("expert", ("data",)),          # stored expert dim (owner axis)
    ("expert_virtual", ("pod", "data")),  # virtual expert dim (EP group)
    ("embed_fsdp", ("pod", "data")),      # param row dim: FSDP sharding
    ("conv", ()),
    ("state", ()),
)


def ep_axes(mesh: Mesh) -> tuple[str, ...]:
    """EP all-to-all axes, fastest digit first (owner axis, then replicas).

    The virtual-expert rank is ``data_coord + |data| * pod_coord``: experts
    are owned along "data" and replicated across "pod", so the multi-pod
    dispatch is a d=2 factorized all-to-all (ICI round then DCN round)."""
    return tuple(a for a in ("data", "pod") if a in mesh.shape)


@dataclass(frozen=True)
class ShardingRules:
    rules: tuple[tuple[str, tuple[str, ...]], ...] = DEFAULT_RULES

    def lookup(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        for name, axes in self.rules:
            if name == logical:
                return tuple(axes)
        raise KeyError(f"no rule for logical axis {logical!r}")

    def override(self, **kw) -> "ShardingRules":
        new = []
        seen = set()
        for name, axes in self.rules:
            if name in kw:
                new.append((name, tuple(kw[name]) if kw[name] else ()))
                seen.add(name)
            else:
                new.append((name, axes))
        for name in kw:
            if name not in seen:
                new.append((name, tuple(kw[name]) if kw[name] else ()))
        return ShardingRules(tuple(new))


def resolve_spec(shape: tuple[int, ...],
                 logical: tuple[str | None, ...],
                 mesh: Mesh,
                 rules: ShardingRules | None = None) -> P:
    """Resolve logical axes to a PartitionSpec for ``shape`` on ``mesh``.

    Fallback policy (in order): drop physical axes missing from the mesh;
    drop axes already used by an earlier dimension; greedily keep the
    longest prefix of the rule's axis tuple whose size product divides the
    dimension.  The result is always valid for (shape, mesh).
    """
    rules = rules or ShardingRules()
    if len(logical) != len(shape):
        raise ValueError(f"logical {logical} does not match shape {shape}")
    used: set[str] = set()
    parts: list = []
    for dim, name in zip(shape, logical):
        want = [a for a in rules.lookup(name)
                if a in mesh.shape and a not in used]
        # longest prefix whose product divides dim
        best: tuple[str, ...] = ()
        acc = 1
        for a in want:
            if dim % (acc * mesh.shape[a]) == 0:
                acc *= mesh.shape[a]
                best = best + (a,)
            else:
                break
        used.update(best)
        if not best:
            parts.append(None)
        elif len(best) == 1:
            parts.append(best[0])
        else:
            parts.append(best)
    return P(*parts)


def named_sharding(shape, logical, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, logical, mesh, rules))


def constrain(x, logical: tuple[str | None, ...], mesh: Mesh | None = None,
              rules: ShardingRules | None = None):
    """``with_sharding_constraint`` by logical axes (no-op without mesh)."""
    mesh = mesh or get_current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = resolve_spec(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


_CURRENT_MESH: list[Mesh] = []


class use_mesh:
    """Context manager installing the mesh used by ``constrain``."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __enter__(self):
        _CURRENT_MESH.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _CURRENT_MESH.pop()
        return False


def get_current_mesh() -> Mesh | None:
    return _CURRENT_MESH[-1] if _CURRENT_MESH else None
