"""Ulysses-style sequence parallelism via the factorized all-to-all.

For long-context prefill the activations are sequence-sharded over the SP
axis ("model").  Attention needs full sequences per head, so we re-shard
seq<->heads with a *tiled* all-to-all in each direction (DeepSpeed-Ulysses;
here decomposed by the paper's algorithm when the SP group spans multiple
mesh axes).  GQA handling: when KV heads cannot absorb the SP degree, KV
is all-gathered along the sequence instead (small relative to Q for GQA).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import torus_comm
from repro.core.overlap import run_pipelined
from repro.kernels import ops as kops
from repro.parallel.sharding import resolve_spec


def _sp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("model",) if a in mesh.shape
                 and mesh.shape[a] > 1)


def _overlap_chunks(cfg, Hkv: int, sp: int) -> int:
    """Head-group chunk count for the pipelined re-shard.

    Chunks are contiguous KV-head groups (their q heads ride along), so
    each chunk's attention is self-contained; feasibility requires the
    per-chunk KV heads to still absorb the SP degree: Hkv % (sp*n) == 0.
    Shrinks the requested count until feasible (1 = fall back)."""
    if cfg.a2a_backend != "overlap":
        return 1
    n = max(1, cfg.a2a_chunks or 2)
    while n > 1 and Hkv % (sp * n):
        n -= 1
    return n


def ulysses_attention(q, k, v, cfg, *, causal=True, axes=None, mesh=None,
                      rules=None):
    """q: (B, Hq, S, hd) sequence-sharded; returns (B, Hq, S, hd) with the
    same sharding.  Inside: heads-sharded full-sequence attention."""
    mesh = mesh
    if mesh is None:
        from repro.parallel.sharding import get_current_mesh
        mesh = get_current_mesh()
    if mesh is None:
        return kops.attention(q, k, v, causal=causal, window=cfg.window,
                              impl=cfg.attention_impl)
    axes = axes or _sp_axes(mesh)
    sp = math.prod(mesh.shape[a] for a in axes) if axes else 1
    if sp == 1:
        return kops.attention(q, k, v, causal=causal, window=cfg.window,
                              impl=cfg.attention_impl)

    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    if Hq % sp:
        raise ValueError(f"Ulysses needs Hq({Hq}) % sp({sp}) == 0")
    kv_a2a = Hkv % sp == 0
    msf = tuple(reversed(axes))   # most-significant-first for specs

    q_spec = resolve_spec(q.shape, ("batch", None, "seq_sp", None),
                          mesh, rules)

    group = Hq // Hkv
    hq_loc = Hq // sp
    n_chunks = _overlap_chunks(cfg, Hkv, sp) if kv_a2a else 1

    # The SP group's cached Cartesian communicator is the construction
    # root: one plan per (mesh devices, SP axes, tile shape, dtype),
    # resolved once through it and fetched from the registry on every
    # later layer/step.  The re-shard defaults to the factorized tiled
    # kernel; under cfg.a2a_backend="autotune" the tuning DB's measured
    # winner for this tile shape is replayed instead (model fallback on a
    # miss — nothing here ever blocks on a measurement).  The overlap
    # knob chunks at KV-head-group granularity above it (run_pipelined).
    reshard_backend = "autotune" if cfg.a2a_backend == "autotune" \
        else "factorized"
    comm = torus_comm(mesh, axes, variant=cfg.a2a_variant)
    plan = comm.all_to_all(block_shape=(B, hq_loc, S // sp, hd),
                           dtype=q.dtype, backend=reshard_backend)

    def inner_overlap(ql, kl, vl):
        # Chunked seq<->heads re-shard (core.overlap): split the heads
        # into KV-group-aligned chunks and software-pipeline
        #   reshard chunk c ‖ attention chunk c-1 ‖ reverse-reshard c-2
        # so chunk c's tiled all-to-alls sit next to (and overlap with)
        # chunk c-1's attention in program order.
        def split(a, n):
            if a.shape[1] % n:   # guarded by _overlap_chunks; never drop
                raise ValueError(f"head axis {a.shape[1]} not divisible "
                                 f"into {n} chunks")
            step = a.shape[1] // n
            return [a[:, i * step:(i + 1) * step] for i in range(n)]

        states = list(zip(split(ql, n_chunks), split(kl, n_chunks),
                          split(vl, n_chunks)))

        def reshard(st, _c):
            q_, k_, v_ = st
            return (plan.tiled(q_, 1, 2), plan.tiled(k_, 1, 2),
                    plan.tiled(v_, 1, 2))

        def attend(st, _c):
            qh, kh, vh = st
            return kops.attention(qh, kh, vh, causal=causal,
                                  window=cfg.window,
                                  impl=cfg.attention_impl)

        def unshard(oh, _c):
            return plan.tiled(oh, 2, 1, reverse=True)

        outs = run_pipelined(states, [reshard, attend, unshard])
        return jnp.concatenate(outs, axis=1)

    def inner(ql, kl, vl):
        if n_chunks > 1:
            return inner_overlap(ql, kl, vl)
        # ql: (B_loc, Hq, S_loc, hd) -> heads sharded, full seq
        qh = plan.tiled(ql, split_axis=1, concat_axis=2)
        if kv_a2a:
            kh = plan.tiled(kl, 1, 2)
            vh = plan.tiled(vl, 1, 2)
        else:
            # GQA with Hkv < sp: gather full KV along seq, then select the
            # global KV heads matching this device's local q-head range so
            # the kernel's h_q // group mapping stays correct.
            kh = jax.lax.all_gather(kl, msf, axis=2, tiled=True)
            vh = jax.lax.all_gather(vl, msf, axis=2, tiled=True)
            rank = jnp.zeros((), jnp.int32)
            for a in msf:   # most-significant-first linearization
                rank = rank * jax.lax.axis_size(a) + jax.lax.axis_index(a)
            kv_idx = (rank * hq_loc + jnp.arange(hq_loc)) // group
            kh = jnp.take(kh, kv_idx, axis=1)
            vh = jnp.take(vh, kv_idx, axis=1)
        oh = kops.attention(qh, kh, vh, causal=causal, window=cfg.window,
                            impl=cfg.attention_impl)
        # back: heads full, seq sharded
        return plan.tiled(oh, 2, 1, reverse=True)

    return jax.shard_map(
        inner, mesh=mesh,
        in_specs=(q_spec, q_spec, q_spec),
        out_specs=q_spec,
        check_vma=False,
    )(q, k, v)
