"""Ring attention: sequence-sharded exact attention via neighbor exchange.

The alternative to Ulysses for long-context prefill: Q stays put,
KV blocks rotate around the SP axis with ``ppermute`` (torus
neighbor-communication — the same dimension-local discipline the paper's
algorithm imposes), and partial softmax statistics merge online
(flash-style).  Communication per step is one KV block to one neighbor —
p-1 rounds of nearest-neighbor traffic instead of one all-to-all, the
latency/bandwidth dual of the paper's tradeoff.

Causal masking uses absolute positions of the rotating KV shard.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import resolve_spec


def _merge(m1, l1, o1, m2, l2, o2):
    """Merge two partial flash-attention states (m, l, unnormalized o)."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return m, l1 * a1 + l2 * a2, o1 * a1[..., None] + o2 * a2[..., None]


def _partial_attn(q, k, v, q_pos, k_pos, *, scale, causal, window):
    """Unnormalized attention of q against one KV shard.
    q: (B,H,Sq,hd); k/v: (B,Hkv,Sk,hd). Returns (m, l, o)."""
    B, Hq, Sq, hd = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, Sq, hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def ring_attention(q, k, v, cfg=None, *, causal=True, window=None,
                   mesh: Mesh | None = None, axis: str = "model",
                   rules=None):
    """q,k,v: (B, H*, S, hd) sequence-sharded over ``axis``; returns
    attention output with the same sharding.  Exact (== full attention)."""
    window = window if window is not None else \
        (cfg.window if cfg is not None else None)
    if mesh is None or axis not in mesh.shape or mesh.shape[axis] == 1:
        from repro.kernels import ops as kops
        return kops.attention(q, k, v, causal=causal, window=window)
    n = mesh.shape[axis]
    scale = 1.0 / math.sqrt(q.shape[-1])

    spec = resolve_spec(q.shape, ("batch", None, "seq_sp", None), mesh,
                        rules)

    def inner(ql, kl, vl):
        B, Hq, Sl, hd = ql.shape
        Hkv = kl.shape[1]
        g = Hq // Hkv
        rank = jax.lax.axis_index(axis)
        q_pos = rank * Sl + jnp.arange(Sl)

        m = jnp.full((B, Hkv, g, Sl), -1e30, jnp.float32)
        l = jnp.zeros((B, Hkv, g, Sl), jnp.float32)
        o = jnp.zeros((B, Hkv, g, Sl, hd), jnp.float32)
        kv_rank = rank
        k_cur, v_cur = kl, vl
        perm = [(i, (i - 1) % n) for i in range(n)]   # rotate left
        for step in range(n):
            k_pos = kv_rank * Sl + jnp.arange(Sl)
            m2, l2, o2 = _partial_attn(ql, k_cur, v_cur, q_pos, k_pos,
                                       scale=scale, causal=causal,
                                       window=window)
            m, l, o = _merge(m, l, o, m2, l2, o2)
            if step < n - 1:
                k_cur = jax.lax.ppermute(k_cur, axis, perm)
                v_cur = jax.lax.ppermute(v_cur, axis, perm)
                kv_rank = (kv_rank + 1) % n
        safe = jnp.where(l == 0.0, 1.0, l)
        out = (o / safe[..., None]).reshape(B, Hq, Sl, hd)
        return out.astype(ql.dtype)

    return jax.shard_map(inner, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)(q, k, v)
