"""Parallelism: sharding rules, Ulysses SP, mesh helpers."""

from .pipeline import bubble_fraction, make_pipelined_forward, pipeline_apply
from .sharding import (DEFAULT_RULES, ShardingRules, constrain, ep_axes,
                       named_sharding, resolve_spec, use_mesh)

__all__ = ["DEFAULT_RULES", "bubble_fraction", "make_pipelined_forward", "pipeline_apply", "ShardingRules", "constrain", "ep_axes",
           "named_sharding", "resolve_spec", "use_mesh"]
