"""Pipeline parallelism: GPipe-style stages over a mesh axis.

Stages live on the "pod" axis (or any named axis): stage s owns layers
[s*L/S, (s+1)*L/S).  Microbatches stream through with
``collective_permute`` boundary transfers; the classic GPipe schedule
runs S + M - 1 ticks (bubble fraction (S-1)/(S+M-1)).

Implementation notes (JAX-native, cf. the praxis/maxtext circular
schedules): all stages execute the same program (SPMD); at tick t, stage
s computes microbatch t - s (predicated with ``jnp.where`` masks — lax
control flow keeps the HLO O(1) in ticks via ``lax.fori_loop``... here a
python loop over ticks keeps it simple and unrolled: M and S are small).
The per-stage layer parameters arrive pre-sharded over the stage axis
(leading dim = n_stages) so each device reads only its stage's slice.

This is the *forward* pipeline used for inference/serving of stacked
blocks; for training it composes with jax.grad (the transposed permutes
run the reverse schedule automatically).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn, params_stages, x_microbatches, *, axis: str,
                   n_stages: int):
    """Run inside shard_map: stage-parallel pipelined application.

    Args:
      stage_fn: (stage_params, x) -> y, one stage's computation.
      params_stages: pytree with leading dim 1 per device (this stage's
        params slice, leading axis already sharded over ``axis``).
      x_microbatches: (M, mb, ...) microbatches — replicated input; stage
        0 consumes them in order.
    Returns:
      (M, mb, ...) outputs as produced by the LAST stage (valid on every
      device; intermediate stages' copies are don't-care and masked).
    """
    M = x_microbatches.shape[0]
    stage_idx = jax.lax.axis_index(axis)
    my_params = jax.tree.map(lambda p: p[0], params_stages)

    n_ticks = n_stages + M - 1
    carry = jnp.zeros_like(x_microbatches[0])
    outputs = jnp.zeros_like(x_microbatches)

    for t in range(n_ticks):
        # stage s works on microbatch m = t - s when 0 <= m < M
        m = t - stage_idx
        active = (m >= 0) & (m < M)
        m_clamped = jnp.clip(m, 0, M - 1)
        # stage 0 ingests a fresh microbatch; others take the permuted
        # carry from the previous stage
        x_in = jnp.where(stage_idx == 0,
                         jax.lax.dynamic_index_in_dim(
                             x_microbatches, m_clamped, keepdims=False),
                         carry)
        y = stage_fn(my_params, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # last stage writes its finished microbatch to the output buffer
        is_last = stage_idx == n_stages - 1
        outputs = jax.lax.cond(
            jnp.logical_and(active, is_last),
            lambda o: o.at[m_clamped].set(y),
            lambda o: o,
            outputs)
        # shift activations downstream: stage s -> s+1 (ring permute; the
        # wraparound edge is masked by `active` at the receiver)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        carry = jax.lax.ppermute(y, axis, perm)

    # only the last stage ever writes `outputs` (zeros elsewhere), so a
    # psum over the stage axis broadcasts the finished microbatches.
    return jax.lax.psum(outputs, axis) if n_stages > 1 else outputs


def make_pipelined_forward(stage_fn, mesh: Mesh, *, axis: str = "pod",
                           n_microbatches: int = 4,
                           params_spec=P("pod"), x_spec=P()):
    """Host-level: jit-able pipelined forward over ``axis``.

    ``stage_fn(params_slice, x) -> y`` with y.shape == x.shape (a residual
    block stack).  Params' leading dim must equal the axis size.
    """
    n_stages = mesh.shape[axis]

    def run(params_stages, x):
        B = x.shape[0]
        assert B % n_microbatches == 0
        mbs = x.reshape(n_microbatches, B // n_microbatches, *x.shape[1:])

        inner = functools.partial(pipeline_apply, stage_fn, axis=axis,
                                  n_stages=n_stages)
        out = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(params_spec, x_spec),   # P prefixes broadcast over
            out_specs=x_spec,                 # the params pytree
            check_vma=False,
        )(params_stages, mbs)
        return out.reshape(B, *x.shape[1:])

    return jax.jit(run)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)
