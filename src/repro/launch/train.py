"""Training launcher: end-to-end driver over any registered architecture.

Wires config -> model -> sharded init -> fault-tolerant Trainer.  On this
CPU container it is exercised with ``--smoke`` (reduced config, small
mesh); the full configs are exercised via the dry-run.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.data import CopyTaskConfig, DataConfig, SyntheticLM
from repro.models import build_model, make_train_step
from repro.models.common import init_params, param_shardings
from repro.optim import AdamW, AdamWConfig, cosine_with_warmup
from repro.parallel.sharding import ShardingRules
from repro.runtime import Trainer, TrainerConfig


def build_training(cfg, mesh, rules, *, lr=3e-4, warmup=100, total=10000,
                   grad_accum=1, seed=0):
    model = build_model(cfg)
    opt = AdamW(AdamWConfig(lr=cosine_with_warmup(lr, warmup, total)))

    if mesh is not None:
        shardings = param_shardings(model.specs(), mesh, rules)
        init_fn = jax.jit(model.init, out_shardings=shardings)
    else:
        init_fn = jax.jit(model.init)
    params = init_fn(jax.random.PRNGKey(seed))
    opt_state = jax.jit(opt.init)(params)
    step_fn = jax.jit(make_train_step(model, opt, mesh, rules,
                                      grad_accum=grad_accum))
    return model, opt, params, opt_state, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--task", choices=("lm", "copy"), default="copy")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("none", "debug", "debug_multi"),
                    default="none")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = None
    rules = ShardingRules()
    if args.mesh != "none":
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(multi_pod=(args.mesh == "debug_multi"))

    model, opt, params, opt_state, step_fn = build_training(
        cfg, mesh, rules, lr=args.lr, total=args.steps,
        warmup=min(20, args.steps // 5 or 1), grad_accum=args.grad_accum)

    dcfg = CopyTaskConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    data = SyntheticLM(dcfg, mesh=mesh, task=args.task)

    tr = Trainer(
        TrainerConfig(total_steps=args.steps,
                      checkpoint_dir=f"{args.ckpt_dir}/{cfg.name}",
                      checkpoint_every=args.ckpt_every, log_every=10),
        step_fn, data, params, opt_state)
    tr.install_preemption_handler()
    if args.resume and tr.try_restore():
        print(f"[train] resumed from step {tr.step}")
    status = tr.run()
    for row in tr.metrics_log:
        print(json.dumps(row))
    print(f"[train] {status} at step {tr.step}; "
          f"median step {tr.watchdog.median * 1e3:.1f} ms")
    return tr


if __name__ == "__main__":
    main()
