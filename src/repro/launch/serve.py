"""Serving launcher: batched greedy decoding with a prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 16 --gen 24
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model, make_serve_step
from repro.parallel.sharding import ShardingRules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    rules = ShardingRules()
    serve = jax.jit(make_serve_step(model, None, rules))

    B = args.batch
    max_seq = args.prompt_len + args.gen
    caches = model.init_caches(B, max_seq)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (B, args.prompt_len), 0, cfg.vocab)

    memory = None
    if cfg.encoder_layers:
        fe = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.n_frontend_tokens, cfg.d_model))
        memory = model.encode(params, fe)

    # prefill by stepping the decoder over the prompt (KV fills in-place)
    t0 = time.perf_counter()
    nxt = prompts[:, :1]
    for t in range(args.prompt_len):
        nxt, logits, caches = serve(params, caches, prompts[:, t:t + 1],
                                    memory)
    t_prefill = time.perf_counter() - t0

    generated = [nxt[:, None]]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        nxt, logits, caches = serve(params, caches, generated[-1], memory)
        generated.append(nxt[:, None])
    jax.block_until_ready(generated[-1])
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"[serve] prefill {t_prefill * 1e3:.1f} ms, decode "
          f"{t_decode / max(1, args.gen - 1) * 1e3:.2f} ms/token")
    print(f"[serve] sample tokens: {out[0][:12].tolist()}")
    return out


if __name__ == "__main__":
    main()
