"""Serving launcher over the unified serving API.

Colocated continuous batching (default) or prefill/decode disaggregation
(``--disaggregate``: one torus partitioned into the two domains, KV
handoff through the ``KVMigrationPlan``):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 16 --gen 24
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --disaggregate --torus-p 6 --batch 4 --prompt-len 16 --gen 24

The hand-rolled prefill + decode loop this launcher used to carry is
retired; ``legacy_prefill_decode`` remains as a DeprecationWarning shim
delegating to :class:`~repro.runtime.serving.ContinuousBatcher` (the PR 2
policy — external callers keep working, internal call sites fail the
warning-as-error CI leg).
"""

from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model, make_serve_step
from repro.parallel.sharding import ShardingRules
from repro.runtime.serving import ContinuousBatcher, DisaggregatedServer, \
    Request


def _batcher_step(serve, memory=None):
    """Adapt ``make_serve_step``'s ``(params, caches, toks[, memory]) ->
    (nxt, logits, caches)`` to the batcher's ``(params, toks, caches) ->
    (logits, caches)`` contract.  A fixed ``memory`` (enc-dec frontend)
    rides along — valid when slot ``i`` serves request ``i``, i.e.
    ``max_batch == len(requests)``."""
    def step(params, toks, caches):
        _, logits, caches = serve(params, caches, toks, memory)
        return logits, caches
    return step


def legacy_prefill_decode(model, params, serve, prompts, gen, memory=None):
    """Deprecated: the launcher's old ad-hoc prefill + decode loop.

    Delegates to the unified serving API (one
    :class:`~repro.runtime.serving.ContinuousBatcher`); construct that —
    or :class:`~repro.runtime.serving.DisaggregatedServer` — directly.
    """
    warnings.warn(
        "repro.launch.serve.legacy_prefill_decode is deprecated; "
        "construct the unified serving API (runtime.serving"
        ".ContinuousBatcher / DisaggregatedServer) instead",
        DeprecationWarning, stacklevel=2)
    B, L = prompts.shape
    batcher = ContinuousBatcher(
        model, params, max_batch=B, max_seq=L + gen,
        serve_step=_batcher_step(serve, memory))
    for i in range(B):
        batcher.submit(Request(i, [int(t) for t in prompts[i]], gen))
    done = batcher.run()
    return jnp.asarray([done[i] for i in range(B)], jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--disaggregate", action="store_true",
                    help="serve through a prefill/decode-partitioned "
                    "torus with KV migration between the domains")
    ap.add_argument("--torus-p", type=int, default=6,
                    help="serving torus size for --disaggregate "
                    "(device-agnostic: ranks model the placement)")
    ap.add_argument("--n-prefill", type=int, default=None,
                    help="prefill ranks (default: cost-model split)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    rules = ShardingRules()
    serve = jax.jit(make_serve_step(model, None, rules))

    B = args.batch
    max_seq = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (B, args.prompt_len), 0, cfg.vocab)

    memory = None
    if cfg.encoder_layers:
        fe = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.n_frontend_tokens, cfg.d_model))
        memory = model.encode(params, fe)

    reqs = [Request(i, [int(t) for t in prompts[i]], args.gen)
            for i in range(B)]
    t0 = time.perf_counter()
    if args.disaggregate:
        if memory is not None:
            raise SystemExit("--disaggregate does not support enc-dec "
                             "archs (frontend memory is not migrated)")
        from repro.core import torus_comm
        from repro.core.dims import dims_create
        dims = tuple(reversed(dims_create(args.torus_p, 2)))
        comm = torus_comm(dims, tuple(f"s{i}" for i in range(len(dims))))
        server = DisaggregatedServer(
            model, params, comm, max_seq=max_seq, decode_batch=B,
            n_prefill=args.n_prefill,
            serve_step=_batcher_step(serve))
        for r in reqs:
            server.submit(r)
        done = server.run()
        ticks = server.ticks
        stats = server.stats()
        topo = stats["topology"]
        print(f"[serve] disaggregated: {topo['n_prefill']} prefill + "
              f"{topo['n_decode']} decode ranks on torus {dims}, "
              f"{topo['migrations']} migrations "
              f"({topo['migrated_rows']} KV rows, plan="
              f"{topo['plan']['inner_kind']})")
    else:
        batcher = ContinuousBatcher(
            model, params, max_batch=B, max_seq=max_seq,
            serve_step=_batcher_step(serve, memory))
        for r in reqs:
            batcher.submit(r)
        done = batcher.run()
        ticks = batcher.ticks
    elapsed = time.perf_counter() - t0

    out = jnp.asarray([done[i] for i in range(B)], jnp.int32)
    print(f"[serve] arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"[serve] {ticks} ticks, {elapsed * 1e3 / max(1, ticks):.2f} "
          f"ms/tick, {elapsed:.2f} s total")
    print(f"[serve] sample tokens: {out[0][:12].tolist()}")
    return out


if __name__ == "__main__":
    main()
