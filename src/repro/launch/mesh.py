"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state): 16x16 = 256 chips per pod (TPU v5e 2-D ICI
torus), 2 pods over DCN for the multi-pod configuration.  The torus-ness
of the physical interconnect is exactly what the paper's factorized
all-to-all exploits: "data" and "model" are ICI dimensions, "pod" is the
slow DCN dimension, and the EP dispatch spans ("data", "pod") with the
d=2 round schedule.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Reduced mesh of the same axis structure (8 / 16 CPU devices)."""
    shape = (2, 2, 4) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
