"""Launchers: production mesh, dry-run, train and serve drivers."""

from .mesh import make_debug_mesh, make_production_mesh

__all__ = ["make_debug_mesh", "make_production_mesh"]
