import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract (ShapeDtypeStruct, zero-allocation)
parameters/optimizer state/inputs with their production shardings, lowers
the jitted step, compiles it for the 16x16 (single-pod) and 2x16x16
(multi-pod) meshes, and records:

  * ``memory_analysis``  — per-device buffer footprint (proves it fits)
  * ``cost_analysis``    — per-device HLO FLOPs / bytes (roofline inputs)
  * collective bytes by kind (parsed from compiled HLO; roofline input)

Artifacts go to ``benchmarks/artifacts/dryrun/<cell>.json`` and are read
by ``benchmarks/roofline.py`` and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config, \
    input_specs
from repro.core.hlo_inspect import (collective_bytes_by_stride,
                                    loop_aware_analysis, parse_hlo)
from repro.core import telemetry
from repro.core.autotune import autotune_stats
from repro.core.comm import unified_stats
from repro.core.plan import plan_cache_entries, plan_cache_stats
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, make_serve_step, make_train_step
from repro.models.common import abstract_params
from repro.models.transformer import cache_logical_axes
from repro.optim import AdamW, AdamWConfig, cosine_with_warmup
from repro.parallel.sharding import ShardingRules, resolve_spec

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "artifacts" / "dryrun"


def _sharded_sds(shape, dtype, logical, mesh, rules):
    sh = NamedSharding(mesh, resolve_spec(shape, logical, mesh, rules))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def _abstract_opt_state(p_abs):
    mu = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                       sharding=s.sharding), p_abs)
    return {"mu": mu, "nu": mu,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _abstract_batch(cfg, shape_cell, mesh, rules):
    specs = input_specs(cfg, shape_cell)
    out = {}
    for k, v in specs.items():
        if not hasattr(v, "shape"):
            continue
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = _sharded_sds(v.shape, v.dtype, logical, mesh, rules)
    return out


def _abstract_caches(model, cfg, B, W, mesh, rules):
    shapes = jax.eval_shape(lambda: model.init_caches(B, W))
    logical = cache_logical_axes(cfg) if not cfg.encoder_layers else None
    if logical is None:
        # enc-dec: states {k,v,slot_pos} stacked over decoder layers
        kv = (None, "batch", "kv_heads", "seq_sp", None)
        logical = {"states": {"k": kv, "v": kv,
                              "slot_pos": (None, "batch", "seq_sp")},
                   "pos": ("batch",)}
    return jax.tree.map(
        lambda s, ax: _sharded_sds(s.shape, s.dtype, ax, mesh, rules),
        shapes, logical,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or
        (isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                      for a in x)))


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v in ("true", "True", "false", "False"):
        v = v in ("true", "True")
    elif v == "none":
        v = None
    else:
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
    return k, v


def apply_overrides(cfg, rules, overrides):
    """``--set key=value`` config overrides; ``rules.<logical>=axis1+axis2``
    (or ``rules.<logical>=`` for replicated) rewires the sharding rules."""
    rule_kw, cfg_kw = {}, {}
    for kv in overrides or ():
        k, v = _parse_override(kv)
        if k.startswith("rules."):
            axes = tuple(a for a in str(v or "").split("+") if a)
            rule_kw[k[len("rules."):]] = axes
        else:
            cfg_kw[k] = v
    if cfg_kw:
        cfg = cfg.replace(**cfg_kw)
    if rule_kw:
        rules = (rules or ShardingRules()).override(**rule_kw)
    return cfg, rules


def build_lowered(arch: str, shape_name: str, mesh_kind: str,
                  rules: ShardingRules | None = None, overrides=None):
    """Lower one cell; returns (cfg, model, lowered) or raises.
    Shared by the dry-run driver and benchmarks.dissect."""
    cfg = get_config(arch)
    cfg, rules = apply_overrides(cfg, rules, overrides)
    shape_cell = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape_cell)
    if not ok:
        raise ValueError(f"skipped: {reason}")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules or ShardingRules()
    model = build_model(cfg)
    p_abs = abstract_params(model.specs(), cfg.pdtype, mesh, rules)
    if shape_cell.kind == "train":
        opt = AdamW(AdamWConfig(lr=cosine_with_warmup(3e-4, 100, 10000)))
        step = make_train_step(model, opt, mesh, rules)
        o_abs = _abstract_opt_state(p_abs)
        b_abs = _abstract_batch(cfg, shape_cell, mesh, rules)
        return cfg, model, jax.jit(step).lower(p_abs, o_abs, b_abs)
    if shape_cell.kind == "prefill":
        from repro.models.model_api import make_prefill_fn
        prefill = make_prefill_fn(model, mesh, rules)
        b_abs = _abstract_batch(cfg, shape_cell, mesh, rules)
        args = [p_abs, b_abs["tokens"]]
        if "frontend_embeds" in b_abs:
            args.append(b_abs["frontend_embeds"])
        return cfg, model, jax.jit(prefill).lower(*args)
    spec = input_specs(cfg, shape_cell)
    B, W = spec["batch"], spec["cache_len"]
    serve = make_serve_step(model, mesh, rules)
    c_abs = _abstract_caches(model, cfg, B, W, mesh, rules)
    t_abs = _sharded_sds((B, 1), jnp.int32, ("batch", None), mesh, rules)
    args = [p_abs, c_abs, t_abs]
    if cfg.encoder_layers:
        m_abs = _sharded_sds((B, cfg.n_frontend_tokens, cfg.d_model),
                             cfg.cdtype, ("batch", None, None), mesh,
                             rules)
        args.append(m_abs)
    return cfg, model, jax.jit(serve).lower(*args)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rules: ShardingRules | None = None, verbose=True,
             overrides=None):
    cfg = get_config(arch)
    cfg, rules = apply_overrides(cfg, rules, overrides)
    shape_cell = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape_cell)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules or ShardingRules()
    model = build_model(cfg)
    t0 = time.time()
    plans_before = {id(pl) for pl in plan_cache_entries()}
    autotune_before = autotune_stats()

    p_abs = abstract_params(model.specs(), cfg.pdtype, mesh, rules)

    if shape_cell.kind == "train":
        opt = AdamW(AdamWConfig(lr=cosine_with_warmup(3e-4, 100, 10000)))
        step = make_train_step(model, opt, mesh, rules)
        o_abs = _abstract_opt_state(p_abs)
        b_abs = _abstract_batch(cfg, shape_cell, mesh, rules)
        lowered = jax.jit(step).lower(p_abs, o_abs, b_abs)
    elif shape_cell.kind == "prefill":
        from repro.models.model_api import make_prefill_fn
        prefill = make_prefill_fn(model, mesh, rules)
        b_abs = _abstract_batch(cfg, shape_cell, mesh, rules)
        args = [p_abs, b_abs["tokens"]]
        if "frontend_embeds" in b_abs:
            args.append(b_abs["frontend_embeds"])
        lowered = jax.jit(prefill).lower(*args)
    else:  # decode
        spec = input_specs(cfg, shape_cell)
        B, W = spec["batch"], spec["cache_len"]
        serve = make_serve_step(model, mesh, rules)
        c_abs = _abstract_caches(model, cfg, B, W, mesh, rules)
        t_abs = _sharded_sds((B, 1), jnp.int32, ("batch", None), mesh,
                             rules)
        args = [p_abs, c_abs, t_abs]
        if cfg.encoder_layers:
            m_abs = _sharded_sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                 cfg.cdtype, ("batch", None, None), mesh,
                                 rules)
            args.append(m_abs)
        lowered = jax.jit(serve).lower(*args)

    t_lower = time.time() - t0
    with telemetry.get_tracer().span("dryrun.compile", cat="dryrun",
                                     arch=arch, shape=shape_name,
                                     mesh=mesh_kind):
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older JAX: one dict per module
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    rep = parse_hlo(text)
    # Loop-aware accounting: while (scan) bodies weighted by trip count —
    # XLA's cost analysis counts them once, understating a 64-layer model
    # by ~64x.  See core/hlo_inspect.loop_aware_analysis.
    la = loop_aware_analysis(text)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": la["flops"],
        "bytes_accessed_per_device": la["bytes_proxy"],
        "collective_bytes_per_device": la["collective_bytes"],
        "collective_bytes_by_kind": la["collective_bytes_by_kind"],
        "flops_per_device_loop_once": cost.get("flops", -1.0),
        "bytes_accessed_loop_once": cost.get("bytes accessed", -1.0),
        "collective_bytes_loop_once": rep.collective_bytes(),
        "collective_bytes_by_stride": {
            f"{k}@{s}": v for (k, s), v in
            collective_bytes_by_stride(text).items()},
        "collective_bytes_by_span": {
            f"{k}@{s}": v for (k, s), v in
            collective_bytes_by_stride(text, use_span=True).items()},
        "collective_op_counts": {
            k: v for k, v in rep.op_counts.items()
            if any(k.startswith(c) for c in
                   ("all-", "reduce-", "collective-", "ragged-"))},
        "memory_analysis": _mem_dict(mem),
        "params_total": model_param_count(model),
        "params_active": active_param_count(cfg),
        # A2APlans resolved while tracing this cell (MoE dispatch/combine,
        # Ulysses re-shards): the introspectable record of which backend /
        # chunk count / round order was chosen per collective —
        # describe() includes tuned_from ("measured" when a tuning-DB
        # record drove the choice, "model" for the analytic default) and
        # the measured candidate table for DB-hit plans.  Ragged plans
        # (dropless MoE, --set capacity_factor=none) appear here too with
        # kind="ragged", sparse-neighborhood plans with kind="sparse".
        "a2a_plans": (new_plans := [pl.describe()
                                    for pl in plan_cache_entries()
                                    if id(pl) not in plans_before]),
        # Per-cell bucket-occupancy stats for the ragged plans: the
        # expected useful fraction of each bucketed exchange's traffic
        # (avg_count / bucket) — the padding price dropless mode pays, the
        # quantity tuning.predict_ragged charges.
        "a2a_ragged_occupancy": [
            {"axis_names": d["axis_names"], "bucket": d["bucket"],
             "max_count": d["max_count"], "avg_count": d["avg_count"],
             "expected_occupancy": d["expected_occupancy"],
             "backend": d["backend"], "tuned_from": d["tuned_from"]}
            for d in new_plans if d.get("kind") == "ragged"],
        # Sparse-neighborhood plans (dropless MoE below the density
        # crossover): the plan-time density estimate the tuner priced
        # plus the last analyzed traffic stats (None in a dry run — the
        # compile-only path never sees a real count matrix).
        "a2a_sparse": [
            {"axis_names": d["axis_names"], "bucket": d["bucket"],
             "max_count": d["max_count"], "avg_count": d["avg_count"],
             "expected_density": d["expected_density"],
             "density": d["density"],
             "skipped_rounds": d["skipped_rounds"],
             "combined_messages": d["combined_messages"]}
            for d in new_plans if d.get("kind") == "sparse"],
        # KV-migration plans (prefill/decode disaggregated serving): the
        # serving-topology split each plan binds plus the inner exchange
        # the cost model resolved it to — what batcher.stats() reports
        # per serving comm at run time.
        "a2a_kv_migration": [
            {"axis_names": d["axis_names"], "bucket": d["bucket"],
             "max_count": d["max_count"],
             "n_prefill": d["n_prefill"], "n_decode": d["n_decode"],
             "expected_density": d["expected_density"],
             "inner_kind": d["inner_kind"], "backend": d["backend"],
             "tuned_from": d["tuned_from"]}
            for d in new_plans if d.get("kind") == "kv_migrate"],
        # Pencil-transpose plans (workloads.fft / spectral long-conv):
        # the re-shard geometry each stage resolved plus the inner dense
        # backend and the alpha-beta prediction — one entry per FFT
        # transpose stage the cell's data path built.
        "a2a_transpose": [
            {"axis_names": d["axis_names"], "dims": d["dims"],
             "in_shape": d["in_shape"], "out_shape": d["out_shape"],
             "split_axis": d["split_axis"], "concat_axis": d["concat_axis"],
             "backend": d["backend"], "pencil_bytes": d["pencil_bytes"],
             "predicted_seconds": d["predicted_seconds"],
             "tuned_from": d["tuned_from"]}
            for d in new_plans if d.get("kind") == "transpose"],
        "a2a_plan_cache": plan_cache_stats(),
        # Tuning-DB traffic for the cell (delta over the cell, like the
        # a2a_plans snapshot above): under a2a_backend="autotune"
        # db_hits/db_misses show whether measured records covered the
        # plans; timing_executions must stay 0 in a dry run (compile-only
        # paths never measure).
        "a2a_autotune": {k: v - autotune_before[k]
                         for k, v in autotune_stats().items()},
        # The TorusComm-unified view of the same state (factorization /
        # plan / autotune / tuning-DB / comm registries in one dict) —
        # what a single comm.stats() call reports at serving time.
        "a2a_comm_stats": unified_stats(),
        # Per-cell telemetry snapshot: the merged metrics registry (every
        # registered stats provider under its namespace), tracer state,
        # and the measured-vs-model drift summary.  In a dry run the
        # drift table is empty (compile-only paths never execute), but
        # the snapshot documents the cell's cache/plan traffic the same
        # way a production process would export it.
        "a2a_telemetry": {
            "metrics": telemetry.metrics_snapshot(),
            "tracer": telemetry.get_tracer().stats(),
            "drift": telemetry.drift_detector().summary(),
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
              f"compile {t_compile:.1f}s, "
              f"flops/dev {record['flops_per_device']:.3g}, "
              f"coll B/dev {record['collective_bytes_per_device']:.3g}")
        print("  memory_analysis:", record["memory_analysis"])
    return record


def _mem_dict(mem):
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = getattr(mem, attr)
    if not out:
        out["repr"] = str(mem)
    return out


def model_param_count(model) -> int:
    from repro.models.common import param_count
    return param_count(model.specs())


def active_param_count(cfg) -> int:
    return cfg.param_count_estimate(active_only=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute existing artifacts")
    ap.add_argument("--set", action="append", dest="overrides",
                    metavar="KEY=VALUE",
                    help="config override (e.g. remat_policy=dots, "
                         "a2a_backend=direct, rules.act_embed=)")
    ap.add_argument("--tag", default="",
                    help="artifact suffix for variant runs")
    args = ap.parse_args()

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_NAMES if args.all or not args.arch else [args.arch]
    shapes = tuple(SHAPES) if args.all or not args.shape else [args.shape]

    failures = []
    tag = f"__{args.tag}" if args.tag else ""
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                out = ARTIFACTS / f"{arch}__{shape}__{mesh_kind}{tag}.json"
                if out.exists() and not args.force:
                    print(f"[dryrun] cached {out.name}")
                    continue
                try:
                    rec = run_cell(arch, shape, mesh_kind,
                                   overrides=args.overrides)
                    if args.tag:
                        rec["tag"] = args.tag
                        rec["overrides"] = args.overrides
                except Exception as e:  # noqa: BLE001 - record and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": mesh_kind, "status": "failed",
                           "error": f"{type(e).__name__}: {e}"}
                    failures.append(out.name)
                out.write_text(json.dumps(rec, indent=1))
    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
