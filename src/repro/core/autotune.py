"""Empirical autotuner: measured plan selection with a persistent tuning DB.

The paper's §5 conclusion — "by choosing the factorization of p and
selecting appropriate implementations for the component MPI_Alltoall
operations, the presented implementation gives ample opportunities for
algorithm tuning and adaptation to the particular high-performance
system" — is exploited analytically by ``tuning.choose_algorithm``
(alpha-beta model) and *empirically* here: :func:`autotune` times real
executions of candidate configurations for one ``(mesh, axes,
block_shape, dtype)`` plan key and records the measured winner in a
persistent JSON database, keyed by the memoized device fingerprint from
``core.cache`` plus the plan key, so the search cost is paid once per
machine x shape, ever.

Search space (bounded by ``budget_seconds``):

* backend per plan — ``direct`` | ``factorized`` | ``overlap``,
* round order — permutations of the active per-dimension rounds
  (exhaustive for d <= 3, identity + reversal beyond),
* ``n_chunks`` for the overlap engine — powers of two up to
  ``max_chunks`` plus the analytic ``choose_chunks`` suggestion,
* candidate torus factorizations from ``tuning.candidate_factorizations``
  over the same devices (measured on auxiliary Cartesian meshes; recorded
  for mesh-construction decisions, never applied behind the caller's
  axes).

Timing discipline: per candidate, ``warmup`` untimed executions then
``repeats`` timed ones; the score is the median (robust to scheduler
noise); every executed call is counted in ``autotune_stats()
["timing_executions"]`` so tests can prove a DB hit performs zero
measurements.

Per-axis link feedback (the analytic-model bridge): a two-point
alpha-beta fit over each active axis turns measured single-axis
all-to-all times into per-axis :class:`~repro.core.tuning.LinkModel`
overrides, recorded in the DB and fed back into ``choose_chunks`` /
``predict_overlapped`` (which accept per-axis links end-to-end) — so the
cost model a DB-hit plan reports is priced with *this machine's*
bandwidths, not the TPU-flavoured defaults.

Integration: ``plan_all_to_all(..., backend="autotune")`` consults the
DB — hit → build the recorded winner instantly (``tuned_from:
"measured"``); miss → fall back to the analytic ``choose_algorithm``
choice (``tuned_from: "model"``) *without* measuring, so jitted paths
never block on a search.  Only an explicit :func:`autotune` call times
anything.

DB location: ``$REPRO_TUNING_DB`` if set, else
``~/.cache/repro/tuning.json`` (``$XDG_CACHE_HOME`` honored).  Corrupt,
truncated, or unreadable DB files are ignored with a warning — plan
construction must never crash on tuning state.
"""

from __future__ import annotations

import hashlib
import json
import math
import os
import statistics
import time
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from . import telemetry
from .cache import cart_create, device_fingerprint
from .dims import max_dims
from .factorized import _as_tuple
from .tuning import LinkModel, candidate_factorizations, choose_chunks

DB_VERSION = 1

# Backends the measured search may record as a winner (and that a DB
# record is allowed to request at plan-build time).
MEASURED_BACKENDS = ("direct", "factorized", "overlap")

# Backends the ragged-family measured search (``autotune_ragged``) may
# record as a winner: the dense-bucketed ragged executor vs the
# sparse-neighborhood one.  Sparse must *win on measured time* to be
# recorded — there is no analytic shortcut into a measured record.
RAGGED_MEASURED_BACKENDS = ("ragged", "sparse")


# ---------------------------------------------------------------------------
# The persistent tuning database
# ---------------------------------------------------------------------------

def default_db_path() -> Path:
    """``$REPRO_TUNING_DB`` override, else ``~/.cache/repro/tuning.json``."""
    env = os.environ.get("REPRO_TUNING_DB")
    if env:
        return Path(env).expanduser()
    cache_home = os.environ.get("XDG_CACHE_HOME")
    base = Path(cache_home).expanduser() if cache_home \
        else Path.home() / ".cache"
    return base / "repro" / "tuning.json"


# Per-DB-path write counters, bumped on every successful write/clear so
# the plan registry (which caches resolved "autotune" plans) can key on
# DB state and re-resolve after a new measurement lands.  Per path, not
# global: writing a scratch DB must not invalidate cached plans resolved
# against the default one.
_GENERATIONS: dict[str, int] = {}


def db_generation(path=None) -> int:
    p = Path(path).expanduser() if path is not None else default_db_path()
    return _GENERATIONS.get(str(p), 0)


def _bump_generation(path: Path) -> None:
    _GENERATIONS[str(path)] = _GENERATIONS.get(str(path), 0) + 1


class TuningDB:
    """Persistent ``key -> measured record`` store (one JSON file).

    Robustness contract: a missing, corrupt, truncated, or unreadable
    file loads as empty with a single warning; a failed write warns and
    leaves the in-memory state usable.  Writes are atomic (temp file +
    ``os.replace``) so a crashed process never truncates the DB.

    Lock contention contract: the advisory flock serializing
    read-merge-writes is acquired with a bounded timeout
    (``lock_timeout`` seconds, exponential backoff between attempts;
    default from ``$REPRO_TUNING_LOCK_TIMEOUT`` or 5s).  A wedged
    lock-holder therefore degrades this process to *in-memory tuning* —
    the record lands in a per-handle overlay that ``get``/``load`` still
    see — instead of hanging the trainer on a file lock.
    """

    def __init__(self, path: str | os.PathLike | None = None,
                 lock_timeout: float | None = None):
        self.path = Path(path).expanduser() if path is not None \
            else default_db_path()
        # precomputed string form: the plan registry embeds it in every
        # autotune cache key, on the steady-state fetch path
        self.path_key = str(self.path)
        if lock_timeout is None:
            lock_timeout = float(os.environ.get(
                "REPRO_TUNING_LOCK_TIMEOUT", 5.0))
        self.lock_timeout = lock_timeout
        # records that could not be persisted (lock timeout): visible to
        # this handle's reads, overwritten by any later successful put
        self._overlay: dict[str, dict] = {}

    def generation(self) -> int:
        return _GENERATIONS.get(self.path_key, 0)

    def load(self) -> dict:
        """The ``{key: record}`` entry map (empty on any load problem)."""
        try:
            raw = self.path.read_text()
        except FileNotFoundError:
            return self._with_overlay({})
        except (OSError, UnicodeDecodeError) as e:
            # UnicodeDecodeError: corrupted-to-garbage bytes (not UTF-8)
            warnings.warn(f"unreadable tuning DB {self.path}: {e}; "
                          "treating as empty", stacklevel=2)
            return self._with_overlay({})
        try:
            doc = json.loads(raw)
            if not isinstance(doc, dict) or \
                    not isinstance(doc.get("entries"), dict):
                raise ValueError("not a tuning-DB document")
        except (ValueError, TypeError) as e:
            warnings.warn(f"corrupt tuning DB {self.path} ({e}); "
                          "treating as empty", stacklevel=2)
            return self._with_overlay({})
        if doc.get("version") != DB_VERSION:
            # A future format: don't guess, don't crash, don't clobber
            # until someone actually stores a new measurement.
            warnings.warn(f"tuning DB {self.path} has version "
                          f"{doc.get('version')!r} != {DB_VERSION}; "
                          "ignoring its entries", stacklevel=2)
            return self._with_overlay({})
        return self._with_overlay(doc["entries"])

    def _with_overlay(self, entries: dict) -> dict:
        """Merge unpersisted (lock-timeout) records over the file state."""
        if self._overlay:
            entries = {**entries, **self._overlay}
        return entries

    def get(self, key: str) -> dict | None:
        return self.load().get(key)

    def put(self, key: str, record: dict) -> bool:
        """Merge one record and persist; True if the write landed.

        The read-merge-write runs under an advisory file lock (POSIX
        ``flock`` on ``<db>.lock``) so two processes autotuning different
        keys against the shared default DB don't drop each other's
        records; where locking is unavailable the atomic replace still
        prevents corruption (last writer wins per whole file).

        If the lock cannot be acquired within ``lock_timeout`` seconds
        (a wedged holder), the record is kept in this handle's in-memory
        overlay — reads still see it, a later successful ``put`` flushes
        it — and False is returned after a warning, never a hang.
        """
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self._locked():
                entries = self.load()   # merges any pending overlay
                entries[key] = record
                doc = {"version": DB_VERSION, "entries": entries}
                tmp = self.path.with_name(self.path.name + ".tmp")
                tmp.write_text(json.dumps(doc, indent=1))
                os.replace(tmp, self.path)
        except TimeoutError as e:
            self._overlay[key] = record
            warnings.warn(
                f"{e}; degrading to in-memory tuning (record kept in this "
                "process, not persisted)", stacklevel=2)
            _bump_generation(self.path)   # readers of this handle see it
            return False
        except OSError as e:
            warnings.warn(f"could not write tuning DB {self.path}: {e}",
                          stacklevel=2)
            return False
        self._overlay.clear()             # flushed with this write
        _bump_generation(self.path)
        return True

    def _locked(self):
        import contextlib
        try:
            import fcntl
        except ImportError:                   # non-POSIX: best effort
            return contextlib.nullcontext()
        timeout = self.lock_timeout

        @contextlib.contextmanager
        def lock():
            lockfile = self.path.with_name(self.path.name + ".lock")
            with open(lockfile, "w") as fh:
                # Non-blocking acquisition with exponential backoff: a
                # wedged holder must surface as a TimeoutError the caller
                # degrades on, never as an indefinite flock wait.
                deadline = time.perf_counter() + max(0.0, timeout)
                delay = 0.005
                while True:
                    try:
                        fcntl.flock(fh, fcntl.LOCK_EX | fcntl.LOCK_NB)
                        break
                    except OSError:
                        if time.perf_counter() >= deadline:
                            raise TimeoutError(
                                f"tuning-DB lock {lockfile} not acquired "
                                f"within {timeout}s")
                        time.sleep(delay)
                        delay = min(delay * 2, 0.1)
                try:
                    yield
                finally:
                    fcntl.flock(fh, fcntl.LOCK_UN)
        return lock()

    def clear(self) -> None:
        """Delete the DB file (missing file is fine).  Takes the same
        advisory lock as ``put`` so a concurrent read-merge-write can't
        resurrect the cleared entries."""
        self._overlay.clear()
        try:
            with self._locked():
                self.path.unlink()
        except FileNotFoundError:
            pass
        except TimeoutError as e:
            warnings.warn(f"{e}; cleared in-memory state only",
                          stacklevel=2)
            _bump_generation(self.path)
            return
        except OSError as e:
            warnings.warn(f"could not delete tuning DB {self.path}: {e}",
                          stacklevel=2)
            return
        _bump_generation(self.path)

    def __len__(self) -> int:
        return len(self.load())

    def __repr__(self):
        return f"TuningDB({str(self.path)!r})"


# Default handle, memoized per *resolved* path — the same resolution
# autotune()'s default TuningDB() performs — so the two default-DB code
# paths can never diverge, and env changes (tests monkeypatching
# REPRO_TUNING_DB / XDG_CACHE_HOME) take effect immediately.
_DEFAULT_DBS: dict[str, TuningDB] = {}


def get_default_db() -> TuningDB:
    path = str(default_db_path())
    db = _DEFAULT_DBS.get(path)
    if db is None:
        db = _DEFAULT_DBS[path] = TuningDB(path)
    return db


# ---------------------------------------------------------------------------
# Keys, stats, lookup
# ---------------------------------------------------------------------------

_STATS = {"searches": 0, "timing_executions": 0,
          "db_hits": 0, "db_misses": 0}


def autotune_stats() -> dict[str, int]:
    """Counters: measured searches run, timed executions performed, and
    plan-construction DB hits/misses (``backend="autotune"`` lookups)."""
    return dict(_STATS)


# The autotuner slice of the unified telemetry snapshot
# (core.telemetry.metrics_snapshot -> "autotune.*").
telemetry.register_stats_provider("autotune", autotune_stats)


def reset_autotune_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def fingerprint_digest(dev_key) -> str:
    """Short stable digest of a ``core.cache.device_fingerprint`` tuple —
    512-device fingerprints stay out of the JSON keys ("none" for
    device-agnostic dims-tuple plans, which therefore never hit records
    stored from real measurements)."""
    if dev_key is None:
        return "none"
    return hashlib.sha1(repr(dev_key).encode()).hexdigest()[:16]


def plan_db_key(dev_key, dims, axis_names, block_shape, dtype,
                variant: str) -> str:
    """Stable DB key: device-fingerprint digest + the plan identity."""
    fp = fingerprint_digest(dev_key)
    block = "x".join(str(int(s)) for s in block_shape)
    return (f"fp:{fp}|dims:{','.join(str(int(s)) for s in dims)}"
            f"|axes:{','.join(axis_names)}|block:{block}"
            f"|dtype:{jnp.dtype(dtype).name}|variant:{variant}")


def ragged_db_key(dev_key, dims, axis_names, row_shape, dtype,
                  max_count: int, variant: str, density: float) -> str:
    """Stable DB key for the ragged-vs-sparse measured choice.

    Extends :func:`plan_db_key`'s identity with the ragged bucket bound
    and a coarse density bucket (one decade per bucket: 1.0, 0.1, 0.01,
    ...) — the dense<->sparse crossover moves with orders of magnitude
    of occupancy, not percents, and a finer key would fragment the DB.
    """
    fp = fingerprint_digest(dev_key)
    row = "x".join(str(int(s)) for s in row_shape) or "scalar"
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    decade = min(6, max(0, -int(math.floor(math.log10(density)))))
    return (f"ragged|fp:{fp}|dims:{','.join(str(int(s)) for s in dims)}"
            f"|axes:{','.join(axis_names)}|row:{row}"
            f"|dtype:{jnp.dtype(dtype).name}|max:{int(max_count)}"
            f"|variant:{variant}|rho:1e-{decade}")


def _valid_record(rec) -> bool:
    if not isinstance(rec, dict):
        return False
    w = rec.get("winner")
    return (isinstance(w, dict)
            and w.get("backend") in MEASURED_BACKENDS
            and isinstance(w.get("n_chunks", 1), int))


def lookup_measured(dev_key, dims, axis_names, block_shape, dtype,
                    variant: str, db: TuningDB | None = None) -> dict | None:
    """The plan-construction side of the DB: a validated record or None.

    Counts a hit/miss in ``autotune_stats``; malformed records (a
    hand-edited DB, a newer writer) are treated as misses so
    ``plan_all_to_all`` can always fall back to the analytic model.
    """
    db = db if db is not None else get_default_db()
    rec = db.get(plan_db_key(dev_key, dims, axis_names, block_shape,
                             dtype, variant))
    if rec is not None and not _valid_record(rec):
        warnings.warn(f"ignoring malformed tuning record in {db.path}",
                      stacklevel=2)
        rec = None
    if rec is None:
        _STATS["db_misses"] += 1
    else:
        _STATS["db_hits"] += 1
    return rec


def _valid_ragged_record(rec) -> bool:
    if not isinstance(rec, dict):
        return False
    w = rec.get("winner")
    return (isinstance(w, dict)
            and w.get("backend") in RAGGED_MEASURED_BACKENDS)


def lookup_ragged_measured(dev_key, dims, axis_names, row_shape, dtype,
                           max_count: int, variant: str, density: float,
                           db: TuningDB | None = None) -> dict | None:
    """The consumption side of :func:`autotune_ragged`: a validated
    ragged-vs-sparse record or None.  Same hit/miss accounting and
    malformed-record tolerance as :func:`lookup_measured` — a miss means
    the caller falls back to the analytic density-aware policy
    (``tuning.choose_ragged_algorithm``), never a blocking measurement.
    """
    db = db if db is not None else get_default_db()
    rec = db.get(ragged_db_key(dev_key, dims, axis_names, row_shape, dtype,
                               max_count, variant, density))
    if rec is not None and not _valid_ragged_record(rec):
        warnings.warn(f"ignoring malformed ragged tuning record in "
                      f"{db.path}", stacklevel=2)
        rec = None
    if rec is None:
        _STATS["db_misses"] += 1
    else:
        _STATS["db_hits"] += 1
    return rec


def demote_hit_to_miss() -> None:
    """Reclassify the last counted hit as a miss: called by the plan
    layer when a looked-up record proves unusable at build time, so
    ``db_hits`` stays equal to the number of plans actually built from
    measurements (what the dryrun telemetry documents)."""
    _STATS["db_hits"] -= 1
    _STATS["db_misses"] += 1


def migrate_records(db: "TuningDB", old_dev_key, new_dev_key, dims,
                    axis_names) -> int:
    """Re-key measured winners from a dead device set onto its rebuilt
    survivor torus (the ``TorusComm.rebuild`` tuning-migration step).

    Only records whose plan identity is still valid on the new torus
    migrate: every axis the record was measured over must exist in the
    new comm's ``axis_names`` with the *same extent* (the typical case is
    a sub-axes plan — e.g. a single-axis exchange whose dimension length
    survived the re-factorization).  Migrated records keep their measured
    winner and links but gain ``"migrated": True`` — they are a
    warm-start heuristic, since the surviving physical links may differ;
    a later explicit :func:`autotune` overwrites them with fresh
    measurements.  Returns the number of records migrated.
    """
    old_fp, new_fp = fingerprint_digest(old_dev_key), \
        fingerprint_digest(new_dev_key)
    if old_fp == new_fp or old_fp == "none" or new_fp == "none":
        return 0
    new_extent = {a: int(Dk) for a, Dk in zip(axis_names, dims)}
    prefix = f"fp:{old_fp}|"
    migrated = 0
    for key, rec in db.load().items():
        if not key.startswith(prefix) or not _valid_record(rec):
            continue
        rec_axes = rec.get("axis_names") or ()
        rec_dims = rec.get("dims") or ()
        if not rec_axes or len(rec_axes) != len(rec_dims):
            continue
        if any(new_extent.get(a) != int(Dk)
               for a, Dk in zip(rec_axes, rec_dims)):
            continue
        if db.put(f"fp:{new_fp}|" + key[len(prefix):],
                  {**rec, "migrated": True}):
            migrated += 1
    return migrated


def measured_links(record: dict) -> tuple[LinkModel, ...] | None:
    """Per-axis LinkModels recorded by the search, if the fit succeeded."""
    raw = record.get("measured_links")
    if not raw:
        return None
    try:
        return tuple(LinkModel(alpha=float(l["alpha"]),
                               bandwidth=float(l["bandwidth"]))
                     for l in raw)
    except (KeyError, TypeError, ValueError):
        return None


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _timed(fn, x, *, warmup: int, repeats: int, **span_attrs) -> float:
    """Median wall seconds of ``fn(x)``; every execution (warmup included)
    is counted in the timing_executions stat.

    Emits one ``autotune.measure`` telemetry span per candidate (attrs
    from ``span_attrs`` plus the measured median).  The tracer is forced
    off *around the executions themselves* so a sweep run under tracing
    still measures the fused jit path — the stepped per-round traced
    path must never contaminate a tuning record, and measurement
    repetitions must not feed the drift detector they calibrate."""
    tr = telemetry.get_tracer()
    with tr.span("autotune.measure", cat="autotune", warmup=warmup,
                 repeats=repeats, **span_attrs) as sp:
        was_enabled = tr.enabled
        tr.enabled = False
        try:
            for _ in range(max(0, warmup)):
                jax.block_until_ready(fn(x))
                _STATS["timing_executions"] += 1
            ts = []
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(x))
                ts.append(time.perf_counter() - t0)
                _STATS["timing_executions"] += 1
        finally:
            tr.enabled = was_enabled
        med = statistics.median(ts)
        sp.set(median_us=med * 1e6)
    return med


def _operand(p: int, block_shape, dtype):
    """Deterministic global (p, p, *block) host_fn operand."""
    n = p * p * math.prod(block_shape)
    return (jnp.arange(n) % 251).reshape((p, p) + tuple(block_shape)) \
        .astype(dtype)


def _fit_axis_links(mesh, axis_names, dims, dtype, *, warmup, repeats,
                    deadline) -> list[dict] | None:
    """Two-point alpha-beta fit per active axis from measured single-axis
    all-to-alls: t(b) = (D_k - 1) * (alpha_k + b / bw_k) at two payload
    sizes solves for (alpha_k, bw_k).  Returns JSON-ready dicts —
    trivial (size-1) axes, which no prediction ever prices, get a fixed
    placeholder marked ``fit: False`` to keep the list positional with
    the axes — or None when the fit is infeasible (noise-swamped
    timings, budget exhausted).
    """
    from .plan import plan_all_to_all

    e_small, e_big = 16, 4096
    itemsize = jnp.dtype(dtype).itemsize
    out = []
    for ax, Dk in zip(axis_names, dims):
        if Dk <= 1:
            # never on the critical path; keep a sane placeholder so the
            # list stays positional with the axes
            out.append({"alpha": 1e-6, "bandwidth": 1e9, "fit": False})
            continue
        if time.perf_counter() > deadline:
            return None
        ts = []
        for nelem in (e_small, e_big):
            plan = plan_all_to_all(mesh, (ax,), (nelem,), dtype,
                                   backend="factorized")
            x = _operand(Dk, (nelem,), dtype)
            ts.append(_timed(plan.host_fn(mesh), x, warmup=warmup,
                             repeats=repeats))
        b1, b2 = e_small * itemsize, e_big * itemsize
        t1, t2 = ts
        if t2 <= t1:          # noise swamped the size difference
            return None
        bw = (Dk - 1) * (b2 - b1) / (t2 - t1)
        alpha = t1 / (Dk - 1) - b1 / bw
        out.append({"alpha": max(alpha, 1e-9),
                    "bandwidth": max(bw, 1e3), "fit": True})
    return out


def _subgroup_devices(mesh: Mesh, axes) -> list:
    """Devices of one communication subgroup: the tuned axes swept, every
    other mesh axis pinned at index 0.  The factorization sweep rebuilds
    its auxiliary Cartesian meshes over exactly these devices — for axes
    spanning the whole mesh this is all of them, for a subset (MoE EP
    axes on a mesh that also has "model") it is one representative
    group, which is what a single all-to-all actually runs over.

    Returned in this package's linearization: most-significant requested
    axis outermost (row-major flat list, fastest digit contiguous) — the
    order ``cart_create`` expects.
    """
    import numpy as np
    idx = tuple(slice(None) if n in axes else 0 for n in mesh.axis_names)
    sub = mesh.devices[idx]
    sel = [n for n in mesh.axis_names if n in axes]
    sub = np.transpose(sub, [sel.index(a) for a in reversed(axes)])
    return list(sub.flat)


def _round_orders(d_active: int, round_orders):
    if round_orders is not None:
        return [tuple(o) for o in round_orders]
    if d_active <= 1:
        return [tuple(range(d_active))]
    if d_active <= 3:
        import itertools
        return list(itertools.permutations(range(d_active)))
    ident = tuple(range(d_active))
    return [ident, tuple(reversed(ident))]


def _chunk_candidates(dims, links, block_bytes, max_chunks: int):
    cands = {n for n in (2, 4, 8, 16) if n <= max_chunks}
    model_n = choose_chunks(dims, links, block_bytes,
                            max_chunks=max(1, max_chunks))
    if model_n > 1:
        cands.add(model_n)
    return sorted(cands)


def autotune(mesh: Mesh, axis_names, block_shape, dtype, **kwargs):
    """Measure candidate configurations, persist the winner, return its plan.

    The returned :class:`~repro.core.plan.A2APlan` is exactly what any
    later ``plan_all_to_all(mesh, axes, block_shape, dtype,
    backend="autotune")`` call will reconstruct from the DB (``describe()
    ["tuned_from"] == "measured"``).

    ``budget_seconds`` bounds the whole search: once exceeded, remaining
    candidates are recorded as skipped (never silently dropped) — the
    direct and factorized baselines are always measured.

    The whole sweep runs under one ``autotune.search`` telemetry span
    (child ``autotune.measure`` spans per candidate) — see
    ``core.telemetry``.
    """
    axes = _as_tuple(axis_names)
    with telemetry.get_tracer().span(
            "autotune.search", cat="autotune", kind="dense",
            axes=",".join(axes),
            dims="x".join(str(int(mesh.shape[a])) for a in axes)):
        return _autotune_impl(mesh, axes, block_shape, dtype, **kwargs)


def _autotune_impl(mesh: Mesh, axis_names, block_shape, dtype, *,
                   variant: str = "natural", max_chunks: int = 8,
                   round_orders=None, include_factorizations: bool = True,
                   warmup: int = 2, repeats: int = 5,
                   budget_seconds: float = 20.0, fit_links: bool = True,
                   db: TuningDB | None = None, verbose: bool = False):
    from .plan import plan_all_to_all
    from .tuning import default_links

    axes = _as_tuple(axis_names)
    dims = tuple(int(mesh.shape[a]) for a in axes)
    p = math.prod(dims)
    dev_key = device_fingerprint(mesh)
    db = db if db is not None else TuningDB()
    deadline = time.perf_counter() + budget_seconds
    _STATS["searches"] += 1

    block_shape = tuple(int(s) for s in block_shape)
    block_bytes = math.prod(block_shape) * jnp.dtype(dtype).itemsize
    x = _operand(p, block_shape, dtype)

    links_fitted = None
    if fit_links:
        links_fitted = _fit_axis_links(mesh, axes, dims, dtype,
                                       warmup=warmup, repeats=repeats,
                                       deadline=deadline)
    model_links = tuple(LinkModel(l["alpha"], l["bandwidth"])
                        for l in links_fitted) if links_fitted \
        else default_links(axes)

    # ---- candidate list on the caller's axes (winner-eligible) ----
    d_active = len([D for D in dims if D > 1])
    ident = tuple(range(d_active))
    cands = [("direct", ident, 1)]
    for order in _round_orders(d_active, round_orders):
        cands.append(("factorized", order, 1))
    if d_active >= 1:
        for n in _chunk_candidates(dims, model_links, float(block_bytes),
                                   max_chunks):
            cands.append(("overlap", ident, n))

    table, skipped = [], []
    for i, (backend, order, n) in enumerate(cands):
        if i >= 2 and time.perf_counter() > deadline:
            skipped.append({"backend": backend, "round_order": list(order),
                            "n_chunks": n})
            continue
        plan = plan_all_to_all(mesh, axes, block_shape, dtype,
                               backend=backend, variant=variant,
                               round_order=order, n_chunks=n)
        med = _timed(plan.host_fn(mesh), x, warmup=warmup, repeats=repeats,
                     backend=backend, n_chunks=n,
                     round_order=",".join(str(o) for o in order))
        table.append({"backend": backend, "dims": list(dims),
                      "round_order": list(order), "n_chunks": n,
                      "median_us": med * 1e6, "eligible": True})
        if verbose:
            print(f"[autotune] {backend} order={order} n={n}: "
                  f"{med * 1e6:.1f}us")

    # ---- alternative factorizations of p (informational rows: they need
    # a different Cartesian mesh, so they can't be applied behind the
    # caller's axes — recorded to steer mesh construction) ----
    if include_factorizations and p > 1:
        group_devices = _subgroup_devices(mesh, axes)
        for dims_msf in candidate_factorizations(p, max_d=min(4,
                                                              max_dims(p))):
            dims_ff = tuple(reversed(dims_msf))   # fastest digit first
            if dims_ff == dims or len(dims_ff) == 1:
                continue
            if time.perf_counter() > deadline:
                skipped.append({"backend": "factorized",
                                "dims": list(dims_ff), "n_chunks": 1})
                continue
            aux_names = tuple(f"at{i}" for i in range(len(dims_ff)))
            aux_mesh = cart_create(group_devices, dims_ff, aux_names)
            plan = plan_all_to_all(aux_mesh, aux_names, block_shape, dtype,
                                   backend="factorized", variant=variant)
            med = _timed(plan.host_fn(aux_mesh), x, warmup=warmup,
                         repeats=repeats, backend="factorized",
                         dims="x".join(str(s) for s in dims_ff))
            table.append({"backend": "factorized", "dims": list(dims_ff),
                          "round_order": list(range(len(dims_ff))),
                          "n_chunks": 1, "median_us": med * 1e6,
                          "eligible": False})
            if verbose:
                print(f"[autotune] factorized dims={dims_ff}: "
                      f"{med * 1e6:.1f}us")
    if skipped and verbose:
        print(f"[autotune] budget exhausted; skipped {len(skipped)} "
              f"candidates: {skipped}")

    eligible = [r for r in table if r["eligible"]]
    win = min(eligible, key=lambda r: r["median_us"])
    best_row = min(table, key=lambda r: r["median_us"])
    record = {
        "version": DB_VERSION,
        "winner": {"backend": win["backend"],
                   "round_order": win["round_order"],
                   "n_chunks": int(win["n_chunks"]),
                   "median_us": win["median_us"]},
        "p": p, "dims": list(dims), "axis_names": list(axes),
        "block_shape": list(block_shape),
        "dtype": jnp.dtype(dtype).name, "variant": variant,
        "best_factorization": {"dims": best_row["dims"],
                               "backend": best_row["backend"],
                               "median_us": best_row["median_us"]},
        "measured_links": links_fitted,
        "table": table, "skipped": skipped,
        "warmup": warmup, "repeats": repeats,
        "created": time.time(),
    }
    db.put(plan_db_key(dev_key, dims, axes, block_shape, dtype, variant),
           record)
    # Reconstruct through the DB path so the returned plan is the exact
    # object later backend="autotune" callers fetch (tuned_from="measured").
    return plan_all_to_all(mesh, axes, block_shape, dtype,
                           backend="autotune", variant=variant, db=db)


def _sparse_counts_operand(p: int, max_count: int, density: float,
                           seed: int = 0):
    """Deterministic global (p, p) int32 count matrix at roughly the
    requested non-zero density (at least one non-zero pair, so the
    operand always exercises the data rounds)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    counts = (rng.random((p, p)) < density) \
        * rng.integers(1, max_count + 1, (p, p))
    counts = counts.astype(np.int32)
    if not counts.any():
        counts[0, p - 1] = max_count
    return jnp.asarray(counts)


def autotune_ragged(mesh: Mesh, axis_names, row_shape, dtype, *,
                    max_count: int, density: float, **kwargs):
    """Measure dense-bucketed ragged vs sparse-neighborhood Alltoallv on
    a representative sparse operand and persist the winner.

    The two candidates run their jitted ``host_fn`` over the same
    deterministic ``(p, p, bucket, *row)`` payload and a count matrix
    drawn at the requested ``density`` — so the sparse backend's skip
    predicates see realistic emptiness, and it is recorded as the winner
    **only when it wins on measured time** (the same discipline as the
    dense autotuner: no analytic shortcut into a measured record).
    Returns the winning plan; the record is consumed by
    :func:`lookup_ragged_measured` (e.g. the dropless-MoE plan chooser
    under ``a2a_backend="autotune"``).  The sweep runs under one
    ``autotune.search`` telemetry span like the dense search.
    """
    axes = _as_tuple(axis_names)
    with telemetry.get_tracer().span(
            "autotune.search", cat="autotune", kind="ragged",
            axes=",".join(axes), density=float(density),
            dims="x".join(str(int(mesh.shape[a])) for a in axes)):
        return _autotune_ragged_impl(mesh, axes, row_shape, dtype,
                                     max_count=max_count, density=density,
                                     **kwargs)


def _autotune_ragged_impl(mesh: Mesh, axis_names, row_shape, dtype, *,
                          max_count: int, density: float,
                          avg_count: float | None = None,
                          variant: str = "natural", warmup: int = 2,
                          repeats: int = 5, seed: int = 0,
                          db: TuningDB | None = None,
                          verbose: bool = False):
    from .comm import torus_comm
    from .ragged import next_pow2

    axes = _as_tuple(axis_names)
    dims = tuple(int(mesh.shape[a]) for a in axes)
    p = math.prod(dims)
    dev_key = device_fingerprint(mesh)
    db = db if db is not None else TuningDB()
    _STATS["searches"] += 1

    row_shape = tuple(int(s) for s in row_shape)
    max_count = int(max_count)
    bucket = next_pow2(max_count)
    counts = _sparse_counts_operand(p, max_count, density, seed)
    x = _operand(p, (bucket,) + row_shape, dtype)

    comm = torus_comm(mesh, axes, variant=variant, db=db)
    ragged_plan = comm.ragged_all_to_all(row_shape, dtype,
                                         max_count=max_count,
                                         avg_count=avg_count)
    sparse_plan = comm.sparse_all_to_all(row_shape, dtype,
                                         max_count=max_count,
                                         avg_count=avg_count,
                                         density=density)
    table = []
    for backend, plan in (("ragged", ragged_plan), ("sparse", sparse_plan)):
        fn = plan.host_fn(mesh)
        med = _timed(lambda _: fn(x, counts), None, warmup=warmup,
                     repeats=repeats, backend=backend)
        table.append({"backend": backend, "median_us": med * 1e6})
        if verbose:
            print(f"[autotune_ragged] {backend}: {med * 1e6:.1f}us")

    win = min(table, key=lambda r: r["median_us"])
    record = {
        "version": DB_VERSION,
        "winner": {"backend": win["backend"],
                   "median_us": win["median_us"]},
        "p": p, "dims": list(dims), "axis_names": list(axes),
        "row_shape": list(row_shape), "dtype": jnp.dtype(dtype).name,
        "max_count": max_count, "bucket": bucket, "variant": variant,
        "density": float(density), "table": table,
        "warmup": warmup, "repeats": repeats, "seed": seed,
        "created": time.time(),
    }
    db.put(ragged_db_key(dev_key, dims, axes, row_shape, dtype, max_count,
                         variant, density), record)
    return sparse_plan if win["backend"] == "sparse" else ragged_plan
