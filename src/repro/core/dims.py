"""``MPI_Dims_create`` — balanced factorization of a process count.

The paper (§5, Table 1) relies on ``MPI_Dims_create`` returning a
factorization "where the factors are as close to each other as possible"
and observes that OpenMPI 4.1.6 violates this (48x24 instead of 36x32 for
p=1152, d=2).  Following Träff & Lübbe [15] we implement the *correct*
specification semantics: minimize the largest factor, then recursively the
next largest, subject to feasibility (an exact divisor factorization).

Factors are returned in non-increasing order, matching MPI convention.
"""

from __future__ import annotations

import functools
import math


def divisors(n: int) -> list[int]:
    """All divisors of ``n`` in increasing order."""
    small, large = [], []
    i = 1
    while i * i <= n:
        if n % i == 0:
            small.append(i)
            if i != n // i:
                large.append(n // i)
        i += 1
    return small + large[::-1]


@functools.lru_cache(maxsize=None)
def _best(n: int, d: int, cap: int) -> tuple[int, ...] | None:
    """Lexicographically smallest non-increasing factorization of ``n`` into
    exactly ``d`` factors, each ``<= cap`` (compared largest-first)."""
    if d == 1:
        return (n,) if n <= cap else None
    # The largest factor must be at least ceil(n ** (1/d)).
    lo = max(1, math.ceil(n ** (1.0 / d) - 1e-9))
    for f in divisors(n):
        if f < lo or f > cap:
            continue
        rest = _best(n // f, d - 1, f)
        if rest is not None:
            return (f,) + rest
    return None


def dims_create(nnodes: int, ndims: int) -> tuple[int, ...]:
    """Balanced factorization of ``nnodes`` into ``ndims`` factors.

    >>> dims_create(1152, 2)
    (36, 32)
    >>> dims_create(1152, 3)
    (12, 12, 8)
    >>> dims_create(1152, 4)
    (8, 6, 6, 4)
    """
    if nnodes <= 0:
        raise ValueError(f"nnodes must be positive, got {nnodes}")
    if ndims <= 0:
        raise ValueError(f"ndims must be positive, got {ndims}")
    out = _best(nnodes, ndims, nnodes)
    assert out is not None  # always feasible with 1-factors
    assert math.prod(out) == nnodes
    return out


def max_dims(nnodes: int) -> int:
    """ceil(log2 p): the paper's maximum meaningful dimension count."""
    return max(1, math.ceil(math.log2(nnodes))) if nnodes > 1 else 1


def prime_factorization(n: int) -> list[int]:
    """Prime factors of n, non-increasing (the d = ceil(log2 p) case)."""
    out = []
    f = 2
    while f * f <= n:
        while n % f == 0:
            out.append(f)
            n //= f
        f += 1
    if n > 1:
        out.append(n)
    return sorted(out, reverse=True)
