"""Algorithm selection / tuning — the paper's §5 conclusion, made a policy.

The paper finds: the d=2,3 factorized algorithm beats native MPI_Alltoall
by 2x+ for <= ~100 small elements per process (latency/startup regime),
the direct algorithm wins for large blocks (bandwidth regime), and
d = ceil(log2 p) is never competitive on their system.  "By choosing the
factorization of p and selecting appropriate implementations for the
component MPI_Alltoall operations, the presented implementation gives
ample opportunities for algorithm tuning and adaptation."

We encode that as an alpha-beta cost model over a heterogeneous torus
(per-axis latency alpha_k and bandwidth beta_k — ICI vs DCN):

    T_factorized(D) = sum_k [ alpha_k * ceil(log?) ... ]  — we use the
    flat per-round model: alpha_k + (D[k]-1) * msg_k / bw_k, with
    msg_k = p/D[k] * block_bytes the per-peer message in round k
    (composite of p/D[k] blocks), sent to D[k]-1 peers.

    T_direct = alpha_flat + (p-1) * block_bytes / bw_min

``choose_algorithm`` enumerates candidate factorizations (the mesh's own
axes plus dims_create splits) and returns the predicted-optimal schedule.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .dims import dims_create, max_dims, prime_factorization


@dataclass(frozen=True)
class LinkModel:
    """Per-axis link parameters."""
    alpha: float      # startup latency per collective round, seconds
    bandwidth: float  # bytes/second per device along this axis


# TPU v5e-flavoured defaults (per chip): ICI ~50 GB/s/link with ~1us
# collective startup; DCN (inter-pod) ~ 6.4 GB/s with ~25us startup.
ICI = LinkModel(alpha=1e-6, bandwidth=50e9)
DCN = LinkModel(alpha=25e-6, bandwidth=6.4e9)


def per_axis_links(links, d: int) -> tuple[LinkModel, ...]:
    """Normalize a link spec to one :class:`LinkModel` per axis.

    Accepts a single ``LinkModel`` (uniform torus — broadcast to every
    axis) or a length-``d`` sequence of per-axis overrides, e.g. the
    measured fits ``core.autotune`` feeds back into this model.  Every
    prediction entry point below accepts either form.
    """
    if isinstance(links, LinkModel):
        return (links,) * d
    links = tuple(links)
    if len(links) != d:
        raise ValueError(f"{len(links)} links for {d} dims")
    return links


# Mesh axes that cross the slow inter-pod network; everything else is
# priced as ICI.  Overridable per plan via ``links=``.
DCN_AXES = ("pod",)


def default_links(axis_names) -> tuple[LinkModel, ...]:
    """Per-axis link models: DCN for inter-pod axes, ICI otherwise."""
    return tuple(DCN if a in DCN_AXES else ICI for a in axis_names)


def resolve_links(links, dims, axis_names=None) -> tuple[LinkModel, ...]:
    """The one merge point for link-model plumbing.

    ``None`` resolves to the axis-name defaults (DCN for ``pod``-like
    axes when names are known, uniform ICI otherwise); a single
    :class:`LinkModel` broadcasts to every axis; a per-axis sequence is
    length-validated.  Every layer that accepts a link override —
    ``core.plan``, ``core.comm``, the ``core.pipelined`` facade — routes
    through here, so the uniform-``link`` and per-axis-``links`` calling
    conventions can never diverge.
    """
    if links is None:
        if axis_names is not None:
            return default_links(axis_names)
        return (ICI,) * len(dims)
    return per_axis_links(links, len(dims))


@dataclass(frozen=True)
class Schedule:
    """A concrete algorithm choice for one all-to-all call."""
    kind: str                      # "direct" | "factorized" | "overlap"
    dims: tuple[int, ...]          # factor per round (fastest digit first)
    links: tuple[LinkModel, ...]   # link model per round
    predicted_seconds: float
    n_chunks: int = 1              # payload chunks (overlap engine)

    @property
    def d(self) -> int:
        return len(self.dims)


def predict_factorized(dims, links, block_bytes: float, p: int) -> float:
    """Alpha-beta prediction for the d-round algorithm.

    Per-message overhead ``alpha`` is charged per peer (the standard
    linear-cost model); message combining means round k sends only
    ``D[k]-1`` messages of ``p/D[k]`` combined blocks each — this is
    exactly why the factorized algorithm wins the small-block regime.
    """
    links = per_axis_links(links, len(dims))
    t = 0.0
    for Dk, link in zip(dims, links):
        if Dk == 1:
            continue
        msg = (p // Dk) * block_bytes          # composite message per peer
        t += (Dk - 1) * (link.alpha + msg / link.bandwidth)
    return t


def per_axis_round_seconds(dims, links, block_bytes: float,
                           p: int | None = None) -> tuple[float, ...]:
    """:func:`predict_factorized`'s per-round terms, unsummed.

    One entry per torus dimension, in axis order (size-1 dimensions are
    no-op rounds and contribute ``0.0``), so the vector sums exactly to
    ``predict_factorized``.  This is the model side of the telemetry
    drift check: each dimension-wise round's *measured* span duration is
    compared against its entry here (``core.telemetry.DriftDetector``),
    and the apportioned round spans of non-stepped backends split the
    measured wall time in these proportions.
    """
    links = per_axis_links(links, len(dims))
    p = math.prod(dims) if p is None else p
    return tuple(
        0.0 if Dk == 1
        else (Dk - 1) * (link.alpha + (p // Dk) * block_bytes
                         / link.bandwidth)
        for Dk, link in zip(dims, links))


def predict_direct(p: int, block_bytes: float, link: LinkModel) -> float:
    """Direct algorithm: p-1 individual messages of one block each."""
    return (p - 1) * (link.alpha + block_bytes / link.bandwidth)


def predict_overlapped(dims, links, block_bytes: float, p: int,
                       n_chunks: int, compute_seconds: float = 0.0) -> float:
    """Alpha-beta prediction for the chunked, software-pipelined schedule
    (``core.overlap``).

    Splitting the block payload into ``n`` chunks and interleaving the
    per-chunk round schedules lets rounds of different chunks run on
    *different dimension links* concurrently: in steady state the
    bandwidth term is divided by the achievable concurrency
    ``min(d, n)``.  The price is the pipeline fill/drain — each round's
    per-peer latency is paid ``(d + n - 1)/d`` times over the schedule —
    so the latency term *grows monotonically* in ``n`` while the
    bandwidth term shrinks, reproducing the small-vs-large payload
    crossover the paper observes for direct-vs-factorized one level up.

    ``compute_seconds`` models an interleaved per-chunk compute stage
    (MoE expert FFN, Ulysses attention): with ``n`` chunks all but the
    fill fraction ``1/n`` of the cheaper of {communication, compute}
    hides behind the other.

    At ``n_chunks=1`` (and ``compute_seconds=0``) this is exactly
    ``predict_factorized``.
    """
    links = per_axis_links(links, len(dims))
    active = [(Dk, l) for Dk, l in zip(dims, links) if Dk > 1]
    d = len(active)
    if d == 0:
        return compute_seconds
    lat = sum((Dk - 1) * l.alpha for Dk, l in active)
    bw = sum((Dk - 1) * (p // Dk) * block_bytes / l.bandwidth
             for Dk, l in active)
    n = max(1, int(n_chunks))
    if n == 1:
        return lat + bw + compute_seconds
    fill = (d + n - 1) / d
    t_comm = fill * lat + bw / min(d, n)
    return max(t_comm, compute_seconds) \
        + min(t_comm, compute_seconds) / n


def predict_ragged(dims, links, row_bytes: float, bucket: int, p: int, *,
                   occupancy: float = 1.0, counts_bytes: int = 4,
                   n_chunks: int = 1, compute_seconds: float = 0.0) -> float:
    """Alpha-beta prediction for the bucketed ragged (Alltoallv) exchange.

    Two phases: the tiny int32 counts all-to-all (each device's block is
    its full ``p``-entry count row — ``p * counts_bytes`` per block), then
    the data rounds at the padded block size ``bucket * row_bytes``.  The
    bucket relates to the *useful* payload through the expected occupancy
    ``avg_count / bucket``: the padded data phase costs the dense schedule
    at the average ragged block divided by the occupancy — i.e. expected
    occupancy x this prediction == the dense cost of the useful bytes, the
    waste the bucketed executor reports and the tuner prices.

    ``n_chunks > 1`` prices the data phase through the chunked/pipelined
    schedule (``predict_overlapped``) instead, matching a plan whose data
    backend resolved to overlap/pipelined.
    """
    links = per_axis_links(links, len(dims))
    if not 0.0 < occupancy <= 1.0:
        raise ValueError(f"occupancy must be in (0, 1], got {occupancy}")
    t_counts = predict_factorized(dims, links, p * float(counts_bytes), p)
    padded = float(bucket) * float(row_bytes)
    if n_chunks > 1:
        t_data = predict_overlapped(dims, links, padded, p, n_chunks,
                                    compute_seconds)
    else:
        t_data = predict_factorized(dims, links, padded, p) + compute_seconds
    return t_counts + t_data


# Per-lane startup multiplier for the sparse rounds: decomposing a dense
# round into D[k]-1 guarded peer lanes (slice + ppermute + predicate per
# lane instead of one fused all-to-all) costs extra per-message overhead,
# which is what keeps dense-bucketed the winner at high occupancy.
SPARSE_LANE_OVERHEAD = 2.0


def predict_sparse(dims, links, row_bytes: float, bucket: int, p: int, *,
                   density: float, counts_bytes: int = 4,
                   compute_seconds: float = 0.0) -> float:
    """Alpha-beta prediction for the sparse-neighborhood Alltoallv.

    Same two phases as :func:`predict_ragged` — the dense int32 counts
    all-to-all, then the data rounds at the padded ``bucket * row_bytes``
    window — but round ``k``'s per-peer lane is *skippable*: under an
    i.i.d. non-zero-pair ``density`` (the non-zero fraction of the
    ``p x p`` count matrix), a composite message combining ``p / D[k]``
    windows is non-empty with probability ``1 - (1 - density)^(p/D[k])``,
    and only non-empty lanes pay the bandwidth term.  Every lane pays the
    (inflated, ``SPARSE_LANE_OVERHEAD``x) startup term — the predicate
    itself is evaluated everywhere — so at ``density -> 1`` sparse is
    strictly dense-ragged plus lane overhead and the tuner keeps the
    dense bucketed path; the win appears once message combining leaves
    most lanes empty.
    """
    links = per_axis_links(links, len(dims))
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    t = predict_factorized(dims, links, p * float(counts_bytes), p)
    padded = float(bucket) * float(row_bytes)
    for Dk, link in zip(dims, links):
        if Dk == 1:
            continue
        m = p // Dk                         # windows combined per message
        p_nonempty = 1.0 - (1.0 - density) ** m
        t += (Dk - 1) * (SPARSE_LANE_OVERHEAD * link.alpha
                         + p_nonempty * m * padded / link.bandwidth)
    return t + compute_seconds


def choose_ragged_algorithm(axis_dims, axis_links, row_bytes: float,
                            bucket: int, *, max_chunks: int = 1,
                            compute_seconds: float = 0.0,
                            density: float | None = None) -> Schedule:
    """Pick the data-phase backend for a bucketed ragged exchange.

    The data rounds are shape-identical to a dense all-to-all of
    ``bucket * row_bytes`` blocks, so the dense policy applies verbatim at
    the padded size; the counts phase is priced by the same policy over
    its ``(p,)`` int32 block (unchunked — pipelining a counts exchange is
    pointless) and added to the winning schedule's prediction, so ragged
    candidates are priced end to end and this function agrees exactly
    with how ``plan_ragged_all_to_all(backend="tuned")`` resolves both
    sub-plans (``backend="autotune"`` resolves the data phase through the
    measured records keyed by the padded block shape instead).

    When a ``density`` estimate is given (the expected non-zero fraction
    of the count matrix — e.g. the dropless-MoE router's occupancy
    proxy), the sparse-neighborhood schedule (:func:`predict_sparse`,
    priced end to end including its counts phase) joins the candidate
    set and the returned schedule may have ``kind == "sparse"`` — the
    dense<->sparse crossover the ROADMAP names.  ``density`` outside
    (0, 1] raises ``ValueError``; ``None`` keeps the dense-only
    candidate set.
    """
    axis_links = per_axis_links(axis_links, len(axis_dims))
    p = math.prod(axis_dims)
    sched = choose_algorithm(axis_dims, axis_links,
                             float(bucket) * float(row_bytes),
                             max_chunks=max_chunks,
                             compute_seconds=compute_seconds)
    t_counts = choose_algorithm(axis_dims, axis_links, p * 4.0,
                                max_chunks=1).predicted_seconds
    best = Schedule(sched.kind, sched.dims, sched.links,
                    sched.predicted_seconds + t_counts,
                    n_chunks=sched.n_chunks)
    if density is not None:
        t_sparse = predict_sparse(axis_dims, axis_links, float(row_bytes),
                                  bucket, p, density=density,
                                  compute_seconds=compute_seconds)
        if t_sparse < best.predicted_seconds:
            best = Schedule("sparse", tuple(axis_dims), axis_links,
                            t_sparse, n_chunks=1)
    return best


def predict_kv_migration(dims, links, row_bytes: float, bucket: int, *,
                         n_prefill: int,
                         migrations_per_tick: float = 1.0) -> Schedule:
    """Alpha-beta prediction for the prefill->decode KV-cache handoff.

    The handoff is an Alltoallv over the *full* serving comm whose count
    matrix is non-zero only in the prefill->decode block: at most
    ``n_prefill * (p - n_prefill)`` of the ``p^2`` pairs can carry a
    sequence, and a scheduler that migrates ``migrations_per_tick``
    sequences per tick fills that many pairs.  That block density is
    exactly the sparse-neighborhood regime knob, so the prediction is
    :func:`choose_ragged_algorithm` at the expected density — the
    returned schedule's ``kind`` may be ``"sparse"`` (few migrations per
    tick: message combining leaves most lanes empty) or a dense data
    backend (many concurrent migrations), the same dense<->sparse
    crossover the MoE router sees.
    """
    p = math.prod(dims)
    links = per_axis_links(links, len(dims))
    n_prefill = int(n_prefill)
    if not 0 < n_prefill < p:
        raise ValueError(f"n_prefill {n_prefill} outside (0, p={p})")
    if migrations_per_tick <= 0:
        raise ValueError(f"migrations_per_tick must be > 0, got "
                         f"{migrations_per_tick}")
    pairs = min(float(migrations_per_tick),
                float(n_prefill * (p - n_prefill)))
    density = max(pairs, 1.0) / float(p * p)
    return choose_ragged_algorithm(dims, links, float(row_bytes),
                                   int(bucket), density=density)


@dataclass(frozen=True)
class ServingSplit:
    """A sized prefill:decode partition of one serving comm."""
    n_prefill: int
    n_decode: int
    predicted_seconds: float       # per-tick bottleneck incl. migration
    prefill_seconds: float
    decode_seconds: float
    migration_seconds: float
    migration_kind: str            # winning KV-migration schedule kind


def choose_serving_split(dims, links, *, row_bytes: float, max_count: int,
                         prefill_tokens: float = 4.0,
                         decode_tokens: float = 1.0,
                         token_seconds: float = 1e-4,
                         migrations_per_tick: float = 1.0) -> ServingSplit:
    """Size the prefill:decode split from the predicted migration cost.

    Per serving tick the prefill domain must ingest ``prefill_tokens``
    prompt tokens and the decode domain must emit ``decode_tokens``
    generated tokens; a domain of ``n`` ranks processes tokens at rate
    ``n / token_seconds`` (each rank one token per step), so the two
    domains cost ``token_seconds * tokens / n`` and the tick is their
    max — plus the KV handoff, priced end to end by
    :func:`predict_kv_migration` over the *full* comm (``row_bytes`` is
    one flattened per-position KV row, ``max_count`` the per-sequence
    row bound — the cache's sequence extent).  Enumerates every
    ``n_prefill in 1..p-1`` and returns the argmin; ties go to the
    smaller prefill pool (decode capacity is the scarce resource once
    the tick time is equal).
    """
    from .ragged import next_pow2
    p = math.prod(dims)
    if p < 2:
        raise ValueError(f"need p >= 2 ranks to split, got {p}")
    links = resolve_links(links, dims)
    bucket = next_pow2(max_count)
    best = None
    for n in range(1, p):
        t_pre = token_seconds * float(prefill_tokens) / n
        t_dec = token_seconds * float(decode_tokens) / (p - n)
        sched = predict_kv_migration(
            dims, links, float(row_bytes), bucket, n_prefill=n,
            migrations_per_tick=migrations_per_tick)
        t = max(t_pre, t_dec) + sched.predicted_seconds
        if best is None or t < best.predicted_seconds:
            best = ServingSplit(n, p - n, t, t_pre, t_dec,
                                sched.predicted_seconds, sched.kind)
    return best


def slowest_active_link(dims, links) -> LinkModel:
    """The bandwidth bottleneck among links that carry traffic: a size-1
    axis (a trivial "pod" dim, or an unfitted placeholder link from a
    tuning-DB record) must not masquerade as the bottleneck.  The one
    definition of the direct collective's pricing link, shared by every
    policy (``choose_algorithm``, ``choose_dimwise_algorithm``,
    ``core.plan``, ``core.comm``)."""
    links = per_axis_links(links, len(dims))
    active = [l for Dk, l in zip(dims, links) if Dk > 1] or list(links)
    return min(active, key=lambda l: l.bandwidth)


def _active_stages(dims, links, p: int, round_order):
    """Shared prologue of the gather-family predictors: per-axis links,
    ``p`` consistency, the active (size > 1) stages, and the round order
    *over those active stages* — the same convention the kernels and the
    plan layer validate (``round_order=(1, 0)`` on dims ``(1, 4, 4)``
    permutes the two size-4 stages; the trivial axis has no round)."""
    links = per_axis_links(links, len(dims))
    if p != math.prod(dims):
        raise ValueError(f"p={p} != prod(dims)={math.prod(dims)}")
    active = [(Dk, l) for Dk, l in zip(dims, links) if Dk > 1]
    order = tuple(round_order) if round_order is not None \
        else tuple(range(len(active)))
    if sorted(order) != list(range(len(active))):
        raise ValueError(f"round_order {order} is not a permutation of "
                         f"0..{len(active) - 1}")
    return active, order


def predict_allgather(dims, links, block_bytes: float, p: int,
                      round_order=None) -> float:
    """Alpha-beta prediction for the d-stage dimension-wise all-gather.

    Stage ``k`` (in the given round order) ships the payload gathered so
    far — ``block_bytes * prod(D_j for earlier stages j)`` — to the
    ``D[k]-1`` peers of the dimension-``k`` communicator.  The bandwidth
    term telescopes to exactly ``(p-1) * block_bytes`` for any order
    (all-gather has no volume win to factorize, unlike Theorem 1's
    all-to-all), so the d-stage form wins purely on the latency term:
    ``sum_k (D[k]-1)`` messages instead of ``p-1``.  The order knob
    matters only on heterogeneous links (put the slow axis early, while
    the payload is small).
    """
    active, order = _active_stages(dims, links, p, round_order)
    t, held = 0.0, float(block_bytes)
    for k in order:
        Dk, link = active[k]
        t += (Dk - 1) * (link.alpha + held / link.bandwidth)
        held *= Dk
    return t


def predict_reduce_scatter(dims, links, block_bytes: float, p: int,
                           round_order=None) -> float:
    """Alpha-beta prediction for the d-stage dimension-wise reduce-scatter.

    The mirror of :func:`predict_allgather`: stage ``k`` holds
    ``block_bytes * p / prod(D_j for earlier stages j)`` and ships the
    ``(D[k]-1)/D[k]`` fraction bound for other group members, shrinking
    the payload ``D[k]``-fold.  The bandwidth term telescopes to
    ``(p-1) * block_bytes`` for any order (the dual of the all-gather),
    so here too the d-stage form wins on the latency term; on
    heterogeneous links the slow axis wants to go *late*, once the
    payload has shrunk.
    """
    active, order = _active_stages(dims, links, p, round_order)
    t, held = 0.0, float(block_bytes) * p
    for k in order:
        Dk, link = active[k]
        t += (Dk - 1) * link.alpha + held * (Dk - 1) / (Dk * link.bandwidth)
        held /= Dk
    return t


def choose_dimwise_algorithm(kind: str, axis_dims, axis_links,
                             block_bytes: float, *,
                             round_order=None) -> Schedule:
    """Pick direct vs factorized for a dimension-wise gather collective.

    ``kind`` is ``"allgather"`` or ``"reduce_scatter"``; candidates are
    the single product-communicator collective (priced like
    :func:`predict_direct`: ``p-1`` peer messages of one block, bounded
    by the slowest link that carries traffic) and the d per-axis stages
    (:func:`predict_allgather` / :func:`predict_reduce_scatter`), the
    same policy shape as :func:`choose_algorithm` for the all-to-all.
    """
    if kind not in ("allgather", "reduce_scatter"):
        raise ValueError(f"unknown dimension-wise collective kind {kind!r}")
    axis_links = per_axis_links(axis_links, len(axis_dims))
    p = math.prod(axis_dims)
    slowest = slowest_active_link(axis_dims, axis_links)
    best = Schedule("direct", (p,), (slowest,),
                    predict_direct(p, block_bytes, slowest))
    predict = predict_allgather if kind == "allgather" \
        else predict_reduce_scatter
    t = predict(axis_dims, axis_links, block_bytes, p,
                round_order=round_order)
    if t < best.predicted_seconds:
        best = Schedule("factorized", tuple(axis_dims), axis_links, t)
    return best


def choose_chunks(dims, links, block_bytes: float, *, max_chunks: int = 8,
                  compute_seconds: float = 0.0) -> int:
    """Chunk count minimizing ``predict_overlapped`` (1 = don't pipeline).

    ``links``: one uniform :class:`LinkModel` or a per-axis sequence —
    measured per-axis bandwidths (``core.autotune``) plug in directly.
    """
    links = per_axis_links(links, len(dims))
    p = math.prod(dims)
    best_n, best_t = 1, float("inf")
    for n in range(1, max(1, max_chunks) + 1):
        t = predict_overlapped(dims, links, block_bytes, p, n,
                               compute_seconds)
        if t < best_t:
            best_n, best_t = n, t
    return best_n


def candidate_factorizations(p: int, max_d: int | None = None):
    """dims_create splits for d = 1..ceil(log2 p) (paper's sweep), plus the
    full prime factorization."""
    out = []
    hi = max_d if max_d is not None else max_dims(p)
    for d in range(1, hi + 1):
        f = dims_create(p, d)
        if math.prod(f) == p and f not in out:
            out.append(f)
    pf = tuple(prime_factorization(p))
    if pf not in out and len(pf) <= (max_d or len(pf)):
        out.append(pf)
    return out


def choose_algorithm(axis_dims: tuple[int, ...],
                     axis_links: tuple[LinkModel, ...],
                     block_bytes: float, *, max_chunks: int = 1,
                     compute_seconds: float = 0.0) -> Schedule:
    """Pick direct vs factorized vs overlapped for a mesh-axis product.

    ``axis_dims``/``axis_links`` describe the physical torus axes the
    all-to-all spans (fastest digit first).  Candidates: the direct
    single collective (bounded by the slowest link), the axis-wise
    factorization, and — when ``max_chunks > 1`` — the chunked/pipelined
    schedule (``core.overlap``) with the ``choose_chunks`` chunk count,
    all priced by the same alpha-beta model so backend and chunk count
    come from one consistent policy.  The flat per-round model is
    round-order invariant (each round's cost is independent), so the
    schedule keeps the given axis order; ``round_order`` remains an
    empirical knob on the plan (``plan_all_to_all(round_order=...)``).
    """
    axis_links = per_axis_links(axis_links, len(axis_dims))
    p = math.prod(axis_dims)
    slowest = slowest_active_link(axis_dims, axis_links)
    best = Schedule("direct", (p,), (slowest,),
                    predict_direct(p, block_bytes, slowest) + compute_seconds)
    t = predict_factorized(axis_dims, axis_links, block_bytes, p) \
        + compute_seconds
    if t < best.predicted_seconds:
        best = Schedule("factorized", axis_dims, axis_links, t)
    if max_chunks > 1:
        n = choose_chunks(axis_dims, axis_links, block_bytes,
                          max_chunks=max_chunks,
                          compute_seconds=compute_seconds)
        if n > 1:
            t_n = predict_overlapped(axis_dims, axis_links, block_bytes, p,
                                     n, compute_seconds)
            if t_n < best.predicted_seconds:
                best = Schedule("overlap", axis_dims, axis_links, t_n,
                                n_chunks=n)
    return best


def predict_transpose(dims, links, pencil_bytes: float, p: int,
                      kind: str = "factorized") -> float:
    """Alpha-beta prediction for one pencil-decomposition FFT transpose.

    A transpose moves the rank's whole local pencil (``pencil_bytes``)
    re-sharded as ``p`` *uniform* contiguous chunks of ``pencil_bytes/p``
    each — the opposite traffic shape from MoE's many small ragged rows.
    The per-peer block is therefore large, which shifts the alpha-beta
    tradeoff: the factorized algorithm's per-round volume is
    ``(D[k]-1)/D[k] * pencil_bytes`` so its *total* volume exceeds the
    direct algorithm's ``(p-1)/p * pencil_bytes`` — message combining
    only pays when the ``(p-1)`` per-message alphas dominate, i.e. for
    small pencils or very latency-heavy links (DCN axes).
    """
    links = per_axis_links(links, len(dims))
    block = pencil_bytes / p
    if kind == "direct":
        return predict_direct(p, block, slowest_active_link(dims, links))
    if kind == "factorized":
        return predict_factorized(dims, links, block, p)
    raise ValueError(f"unknown transpose kind {kind!r}")


def choose_transpose_algorithm(axis_dims, axis_links, pencil_bytes: float,
                               *, max_chunks: int = 1) -> Schedule:
    """Pencil-aware :func:`choose_algorithm`: pick the backend for a
    pencil transpose from its *whole-pencil* byte count.

    Identical candidate set and cost model as :func:`choose_algorithm`
    with the per-peer block ``pencil_bytes / p`` — kept as its own entry
    point because the transpose regime sits on the other side of the
    crossover from MoE traffic (few large contiguous blocks, so
    ``direct`` wins once the pencil outgrows
    ``p * crossover_block_bytes``), and because the FFT roofline
    (``benchmarks.roofline``) prices strong scaling through it.
    """
    p = math.prod(axis_dims)
    return choose_algorithm(axis_dims, axis_links, pencil_bytes / p,
                            max_chunks=max_chunks)


def crossover_block_bytes(axis_dims, axis_links, lo=1, hi=1 << 30) -> int:
    """Smallest block size for which direct beats the best factorized —
    the paper's empirical ~100-element crossover, derived from the model."""
    def direct_wins(b):
        return choose_algorithm(axis_dims, axis_links, b).kind == "direct"
    if direct_wins(lo):
        return lo
    if not direct_wins(hi):
        return hi
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if direct_wins(mid):
            hi = mid
        else:
            lo = mid
    return hi
