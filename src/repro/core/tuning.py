"""Algorithm selection / tuning — the paper's §5 conclusion, made a policy.

The paper finds: the d=2,3 factorized algorithm beats native MPI_Alltoall
by 2x+ for <= ~100 small elements per process (latency/startup regime),
the direct algorithm wins for large blocks (bandwidth regime), and
d = ceil(log2 p) is never competitive on their system.  "By choosing the
factorization of p and selecting appropriate implementations for the
component MPI_Alltoall operations, the presented implementation gives
ample opportunities for algorithm tuning and adaptation."

We encode that as an alpha-beta cost model over a heterogeneous torus
(per-axis latency alpha_k and bandwidth beta_k — ICI vs DCN):

    T_factorized(D) = sum_k [ alpha_k * ceil(log?) ... ]  — we use the
    flat per-round model: alpha_k + (D[k]-1) * msg_k / bw_k, with
    msg_k = p/D[k] * block_bytes the per-peer message in round k
    (composite of p/D[k] blocks), sent to D[k]-1 peers.

    T_direct = alpha_flat + (p-1) * block_bytes / bw_min

``choose_algorithm`` enumerates candidate factorizations (the mesh's own
axes plus dims_create splits) and returns the predicted-optimal schedule.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field

from .dims import dims_create, max_dims, prime_factorization


@dataclass(frozen=True)
class LinkModel:
    """Per-axis link parameters."""
    alpha: float      # startup latency per collective round, seconds
    bandwidth: float  # bytes/second per device along this axis


# TPU v5e-flavoured defaults (per chip): ICI ~50 GB/s/link with ~1us
# collective startup; DCN (inter-pod) ~ 6.4 GB/s with ~25us startup.
ICI = LinkModel(alpha=1e-6, bandwidth=50e9)
DCN = LinkModel(alpha=25e-6, bandwidth=6.4e9)


@dataclass(frozen=True)
class Schedule:
    """A concrete algorithm choice for one all-to-all call."""
    kind: str                      # "direct" | "factorized"
    dims: tuple[int, ...]          # factor per round (fastest digit first)
    links: tuple[LinkModel, ...]   # link model per round
    predicted_seconds: float

    @property
    def d(self) -> int:
        return len(self.dims)


def predict_factorized(dims, links, block_bytes: float, p: int) -> float:
    """Alpha-beta prediction for the d-round algorithm.

    Per-message overhead ``alpha`` is charged per peer (the standard
    linear-cost model); message combining means round k sends only
    ``D[k]-1`` messages of ``p/D[k]`` combined blocks each — this is
    exactly why the factorized algorithm wins the small-block regime.
    """
    t = 0.0
    for Dk, link in zip(dims, links):
        if Dk == 1:
            continue
        msg = (p // Dk) * block_bytes          # composite message per peer
        t += (Dk - 1) * (link.alpha + msg / link.bandwidth)
    return t


def predict_direct(p: int, block_bytes: float, link: LinkModel) -> float:
    """Direct algorithm: p-1 individual messages of one block each."""
    return (p - 1) * (link.alpha + block_bytes / link.bandwidth)


def candidate_factorizations(p: int, max_d: int | None = None):
    """dims_create splits for d = 1..ceil(log2 p) (paper's sweep), plus the
    full prime factorization."""
    out = []
    hi = max_d if max_d is not None else max_dims(p)
    for d in range(1, hi + 1):
        f = dims_create(p, d)
        if math.prod(f) == p and f not in out:
            out.append(f)
    pf = tuple(prime_factorization(p))
    if pf not in out and len(pf) <= (max_d or len(pf)):
        out.append(pf)
    return out


def choose_algorithm(axis_dims: tuple[int, ...],
                     axis_links: tuple[LinkModel, ...],
                     block_bytes: float) -> Schedule:
    """Pick direct vs factorized (and round order) for a mesh-axis product.

    ``axis_dims``/``axis_links`` describe the physical torus axes the
    all-to-all spans (fastest digit first).  Candidates: the direct
    single collective (bounded by the slowest link) and every round-order
    permutation of the axis-wise factorization.
    """
    p = math.prod(axis_dims)
    slowest = min(axis_links, key=lambda l: l.bandwidth)
    best = Schedule("direct", (p,), (slowest,),
                    predict_direct(p, block_bytes, slowest))
    idx = range(len(axis_dims))
    for order in itertools.permutations(idx):
        dims = tuple(axis_dims[i] for i in order)
        links = tuple(axis_links[i] for i in order)
        t = predict_factorized(dims, links, block_bytes, p)
        if t < best.predicted_seconds:
            best = Schedule("factorized", dims, links, t)
    return best


def crossover_block_bytes(axis_dims, axis_links, lo=1, hi=1 << 30) -> int:
    """Smallest block size for which direct beats the best factorized —
    the paper's empirical ~100-element crossover, derived from the model."""
    def direct_wins(b):
        return choose_algorithm(axis_dims, axis_links, b).kind == "direct"
    if direct_wins(lo):
        return lo
    if not direct_wins(hi):
        return hi
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if direct_wins(mid):
            hi = mid
        else:
            lo = mid
    return hi
