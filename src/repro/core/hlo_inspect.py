"""Structural HLO inspection: zero-copy verification and collective bytes.

Two jobs:

1. **Zero-copy verification** (paper §4: "no process-local explicit copying
   of data whatsoever").  For a lowered factorized all-to-all we count the
   data-movement ops that survive between the component collectives —
   ``copy``/``transpose``/``gather`` — and assert the natural variant emits
   none and that the paper variant's transposes cancel.

2. **Collective byte accounting** for the roofline analysis (§Roofline):
   ``cost_analysis`` does not expose collective traffic, so we parse the
   (optimized or unoptimized) HLO text and sum operand bytes of every
   ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
   ``collective-permute`` / ``*-start`` op.
"""

from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "u1": 0.125, "s2": 0.25, "u2": 0.25,
}

# e.g. "bf16[16,128]{1,0}" or "f32[]" or "(f32[2,4], u32[4])"
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*[a-z0-9]*)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-to-all", "all-gather", "all-reduce", "reduce-scatter",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# ops that would constitute an explicit local copy between rounds
LOCAL_MOVEMENT_KINDS = ("copy", "transpose", "gather", "dynamic-slice",
                        "concatenate", "reshape")


def shape_bytes(shape_str: str) -> float:
    """Sum byte sizes of every typed shape token inside ``shape_str``."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for tok in dims.split(","):
                n *= int(tok)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class HloOp:
    name: str
    kind: str
    result_bytes: float
    line: str


@dataclass
class HloReport:
    ops: list[HloOp] = field(default_factory=list)

    @property
    def op_counts(self) -> Counter:
        return Counter(op.kind for op in self.ops)

    def collective_ops(self) -> list[HloOp]:
        return [o for o in self.ops
                if any(o.kind.startswith(k) or o.kind == k + "-start"
                       for k in COLLECTIVE_KINDS)]

    def collective_bytes(self) -> float:
        """Bytes *moved by* collectives = sum of their result bytes.

        ``*-done`` ops are skipped (the matching ``*-start`` carries the
        shape); sync ops are counted directly.
        """
        total = 0.0
        for o in self.ops:
            base = o.kind.removesuffix("-start")
            if o.kind.endswith("-done"):
                continue
            if base in COLLECTIVE_KINDS:
                total += o.result_bytes
        return total

    def collective_bytes_by_kind(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for o in self.ops:
            base = o.kind.removesuffix("-start")
            if o.kind.endswith("-done"):
                continue
            if base in COLLECTIVE_KINDS:
                out[base] = out.get(base, 0.0) + o.result_bytes
        return out

    def movement_ops_between_collectives(self) -> list[HloOp]:
        """Local data-movement ops appearing between the first and last
        collective — the paper's zero-copy criterion.  ``reshape`` and
        ``bitcast`` are excluded (metadata-only in XLA); ``copy`` /
        ``transpose`` / ``gather`` / ``concatenate`` count."""
        coll_idx = [i for i, o in enumerate(self.ops)
                    if o.kind.removesuffix("-start").removesuffix("-done")
                    in COLLECTIVE_KINDS]
        if len(coll_idx) < 2:
            return []
        lo, hi = coll_idx[0], coll_idx[-1]
        bad_kinds = ("copy", "transpose", "gather", "concatenate",
                     "dynamic-slice")
        return [o for o in self.ops[lo + 1:hi]
                if o.kind in bad_kinds and o.result_bytes > 0]


@dataclass
class InterleaveReport:
    """Program-order interleaving of collectives and compute stages.

    Built for verifying the overlap engine (``core.overlap``): a program
    that pipelines per-dimension rounds against per-chunk compute emits
    collectives *between* the compute stages of consecutive chunks, while
    the strictly sequential communicate->compute->communicate program has
    exactly one collective run before and one after its compute block.

    ``events`` is the lowered program filtered to collective / compute
    ops, in emission order.
    """
    events: list[tuple[str, str]] = field(default_factory=list)  # (cls, op)

    @property
    def runs(self) -> list[tuple[str, int]]:
        """Run-length encoding of the event classes."""
        out: list[tuple[str, int]] = []
        for cls, _ in self.events:
            if out and out[-1][0] == cls:
                out[-1] = (cls, out[-1][1] + 1)
            else:
                out.append((cls, 1))
        return out

    @property
    def collective_runs(self) -> int:
        """Maximal collective runs separated by compute.  Sequential
        comm->compute->comm programs have <= 2; a pipelined program has
        one extra run per interleaved chunk boundary."""
        return sum(1 for cls, _ in self.runs if cls == "collective")

    @property
    def interleaved_collectives(self) -> int:
        """Collectives with a compute stage both before AND after them in
        program order — the rounds the schedule can hide behind compute."""
        classes = [cls for cls, _ in self.events]
        try:
            first = classes.index("compute")
            last = len(classes) - 1 - classes[::-1].index("compute")
        except ValueError:
            return 0
        return sum(1 for cls in classes[first + 1:last]
                   if cls == "collective")


def interleave_report(text: str,
                      compute_kinds: tuple[str, ...] = ("dot",),
                      collective_kind: str | None = "all-to-all") \
        -> InterleaveReport:
    """Classify the program's ops into collectives vs compute, in order.

    Use the *unoptimized* HLO (``lowered.as_text(dialect="hlo")``): there
    program order is trace order, so the report verifies exactly what the
    overlap engine emitted.  ``collective_kind`` restricts to one
    collective family (default ``all-to-all`` — the per-dimension rounds);
    pass ``None`` to count every collective.
    """
    rep = InterleaveReport()
    for op in parse_hlo(text).ops:
        base = op.kind.removesuffix("-start")
        if op.kind.endswith("-done"):
            continue
        if base in COLLECTIVE_KINDS and (collective_kind is None
                                         or base == collective_kind):
            rep.events.append(("collective", op.name))
        elif op.kind in compute_kinds:
            rep.events.append(("compute", op.name))
    return rep


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][a-z0-9\-]*)\(")


def parse_hlo(text: str) -> HloReport:
    report = HloReport()
    for line in text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, kind = m.groups()
        report.ops.append(HloOp(name=name, kind=kind,
                                result_bytes=shape_bytes(shape_str),
                                line=line.strip()))
    return report


def collective_bytes_of(lowered_or_text) -> float:
    text = lowered_or_text if isinstance(lowered_or_text, str) \
        else lowered_or_text.as_text()
    return parse_hlo(text).collective_bytes()


# ---------------------------------------------------------------------------
# Loop-aware whole-module analysis.
#
# XLA's HloCostAnalysis (and a naive text scan) counts ``while`` bodies
# ONCE, but a scan-over-layers body executes trip-count times — for a
# 64-layer model that understates FLOPs/bytes/collective traffic by ~64x.
# We parse the module into computations, recover while trip counts from
# the condition computation's loop-bound constant, propagate execution
# multipliers through the call graph (while/call/fusion/to_apply), and
# accumulate dot FLOPs, a read+write byte proxy, and collective bytes
# weighted by multiplier.
# ---------------------------------------------------------------------------

_COMP_HDR_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.+\{\s*$")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*([a-z0-9_]+\[[0-9,]*\])")
_CALLSITE_RE = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"\(\s*((?:%[\w.\-]+|\w[\w.\-]*)"
                         r"(?:\s*,\s*(?:%[\w.\-]+|\w[\w.\-]*))*)\s*\)")
_DIMS_RE = re.compile(r"\[([0-9,]*)\]")


def _shape_dims(shape_str: str) -> list[int]:
    m = _DIMS_RE.search(shape_str)
    if not m or not m.group(1):
        return []
    return [int(t) for t in m.group(1).split(",")]


@dataclass
class _Comp:
    name: str
    params: dict          # param name -> shape str
    ops: list             # (name, shape_str, kind, line)
    callees: list         # (kind, [names])

    def symbol(self, ref: str) -> str | None:
        ref = ref.lstrip("%")
        if ref in self.params:
            return self.params[ref]
        for (n, shape, _, _) in self.ops:
            if n == ref:
                return shape
        return None


def _parse_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            params = dict(_PARAM_RE.findall(hdr.group(3)))
            cur = _Comp(hdr.group(2), params, [], [])
            comps[cur.name] = cur
            if hdr.group(1):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, shape_str, kind = m.groups()
            cur.ops.append((name, shape_str, kind, line.strip()))
        for cm in _CALLSITE_RE.finditer(line):
            names = [n.strip().lstrip("%")
                     for n in cm.group(1).split(",")]
            key = line.split("=")[0] if "=" in line else ""
            cur.callees.append((("while" if " while(" in line else "call"),
                                names, key))
    return comps


def _while_trip(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for (_, _, _, line) in cond.ops:
        for c in _CONST_INT_RE.findall(line):
            best = max(best, int(c))
    return best


def _multipliers(comps: dict[str, _Comp]) -> dict[str, float]:
    entry = comps.get("__entry__")
    mult: dict[str, float] = {}
    if entry is None:
        return {name: 1.0 for name in comps}

    def visit(comp: _Comp, m: float, depth=0):
        if depth > 50:
            return
        mult[comp.name] = mult.get(comp.name, 0.0) + m
        handled = set()
        for (_, _, _, line) in comp.ops:
            if " while(" in line:
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if cm and bm:
                    trip = _while_trip(comps, cm.group(1))
                    if bm.group(1) in comps:
                        visit(comps[bm.group(1)], m * trip, depth + 1)
                        handled.add(bm.group(1))
                    handled.add(cm.group(1))
            else:
                for cs in _CALLSITE_RE.finditer(line):
                    for n in cs.group(1).split(","):
                        n = n.strip().lstrip("%")
                        if n in comps and n not in handled:
                            visit(comps[n], m, depth + 1)
                            handled.add(n)
    visit(entry, 1.0)
    return mult


def _comp_dot_flops(comp: _Comp) -> float:
    total = 0.0
    for (name, shape_str, kind, line) in comp.ops:
        if kind != "dot":
            continue
        result_elems = 1
        for d in _shape_dims(shape_str):
            result_elems *= d
        cm = _CONTRACT_RE.search(line)
        contract = [int(t) for t in cm.group(1).split(",")] \
            if cm and cm.group(1) else []
        # first operand ref after "dot(" — some XLA versions print typed
        # operands, e.g. ``dot(f32[8,64]{1,0} %Arg_0.1, ...)``, so prefer
        # %-prefixed refs and fall back to the first bare token
        args_m = re.search(r"dot\(([^)]*)", line)
        refs = re.findall(r"%([\w.\-]+)", args_m.group(1)) if args_m else []
        if not refs:
            bare = re.search(r"dot\(\s*([\w.\-]+)", line)
            refs = [bare.group(1)] if bare else []
        k = 1
        if refs:
            lhs_shape = comp.symbol(refs[0])
            if lhs_shape:
                dims = _shape_dims(lhs_shape)
                for c in contract:
                    if c < len(dims):
                        k *= dims[c]
        total += 2.0 * result_elems * k
    return total


# ops that move no HBM bytes themselves (metadata / layout / tuple plumbing)
_FREE_KINDS = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota", "partition-id",
               "replica-id"}


_SLICE_KINDS = ("dynamic-slice", "slice", "gather")


def _op_operand_refs(line: str, kind: str) -> list[str]:
    after = line.split(f"{kind}(", 1)
    if len(after) != 2:
        return []
    args = after[1].split(")", 1)[0]
    return re.findall(r"%([\w.\-]+)", args)


def _fusion_param_bytes(body: _Comp, operand_shapes: list[str | None]) \
        -> float:
    """Effective read bytes of a fusion: a parameter consumed ONLY by
    slice-like ops costs the slice results, not the whole buffer (the
    stacked-parameter scan pattern); a parameter consumed only as the
    TARGET of dynamic-update-slice costs the update region (in-place DUS
    — the residual-stacking scan pattern); otherwise the full operand."""
    param_names = list(body.params)
    total = 0.0
    for i, pname in enumerate(param_names):
        full = shape_bytes(body.params[pname])
        uses = []
        for (_, shape_str, kind, line) in body.ops:
            if kind == "parameter":
                continue
            rhs = line.split("=", 1)[-1]
            if re.search(rf"%{re.escape(pname)}\b", rhs):
                refs = _op_operand_refs(line, kind)
                total_refs = [r for r in refs if r == pname]
                is_dus_target = (kind == "dynamic-update-slice" and refs
                                 and refs[0] == pname)
                update_b = 0.0
                if is_dus_target and len(refs) >= 2:
                    s = body.symbol(refs[1])
                    update_b = shape_bytes(s) if s else 0.0
                uses.append((kind, shape_str, is_dus_target, update_b))
        if not uses:
            continue
        if all(k in _SLICE_KINDS for k, _, _, _ in uses):
            total += sum(shape_bytes(s) for _, s, _, _ in uses)
        elif all(dus for _, _, dus, _ in uses):
            total += sum(2 * ub for _, _, _, ub in uses)
        else:
            total += full
    return total


def _comp_bytes(comp: _Comp, comps: dict | None = None) -> float:
    """Read+write byte proxy at fusion granularity: every *top-level* op
    writes its result once and reads each operand once.  Fusion-internal
    intermediates (registers/VMEM) are excluded by the caller skipping
    fusion-body computations; the ``fusion`` op at its call site accounts
    for the body's HBM traffic (effective operands in, result out).

    Slicing ops (top-level or as sole consumers inside a fusion body)
    charge the slice, not the sliced buffer; dynamic-update-slice charges
    ~2x the update region (XLA performs it in place inside loops)."""
    total = 0.0
    for (name, shape_str, kind, line) in comp.ops:
        if kind in _FREE_KINDS:
            continue
        result_b = shape_bytes(shape_str)
        if kind in _SLICE_KINDS:
            total += 2 * result_b          # read slice + write result
            continue
        if kind == "dynamic-update-slice":
            refs = _op_operand_refs(line, kind)
            update_b = 0.0
            if len(refs) >= 2:
                s = comp.symbol(refs[1])
                if s:
                    update_b = shape_bytes(s)
            total += 2 * update_b if update_b else result_b
            continue
        if kind == "fusion" and comps is not None:
            m = re.search(r"calls=%?([\w.\-]+)", line)
            body = comps.get(m.group(1)) if m else None
            if body is not None:
                pbytes = _fusion_param_bytes(
                    body, [comp.symbol(r)
                           for r in _op_operand_refs(line, kind)])
                # in-place DUS fusion: the result IS the aliased buffer;
                # the 2x-update charge in pbytes already covers the write.
                inplace = any(
                    k == "dynamic-update-slice"
                    and (_op_operand_refs(ln, k) or [None])[0] in body.params
                    for (_, _, k, ln) in body.ops)
                total += pbytes if inplace else result_b + pbytes
                continue
        total += result_b
        for ref in _op_operand_refs(line, kind):
            s = comp.symbol(ref)
            if s:
                total += shape_bytes(s)
    return total


def _comp_collective_bytes(comp: _Comp) -> dict[str, float]:
    out: dict[str, float] = {}
    for (name, shape_str, kind, line) in comp.ops:
        base = kind.removesuffix("-start")
        if kind.endswith("-done"):
            continue
        if base in COLLECTIVE_KINDS:
            out[base] = out.get(base, 0.0) + shape_bytes(shape_str)
    return out


_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def collective_group_stride(line: str) -> tuple[int, int] | None:
    """(group_size, member_stride) of a collective's first replica group.

    Supports both explicit ``replica_groups={{0,16,32,...},...}`` and
    iota-tile ``replica_groups=[n,m]<=[dims]T(perm)`` forms.  The stride
    identifies WHICH mesh axis the collective spans (stride 1 = innermost
    mesh axis, etc.), which is how we attribute collective bytes to ICI
    vs DCN links."""
    out = collective_group_geometry(line)
    return None if out is None else (out[0], out[1])


def collective_group_geometry(line: str) -> tuple[int, int, int] | None:
    """(group_size, member_stride, span): span = max-min member id of a
    group — a group whose span reaches across the pod-axis stride crosses
    DCN even if its *member* stride is small (direct all-to-all over a
    multi-axis product has mixed strides)."""
    m = _GROUPS_RE.search(line)
    if m:
        members = [int(t) for t in m.group(1).split(",")]
        if len(members) < 2:
            return (len(members), 0, 0)
        return (len(members), members[1] - members[0],
                max(members) - min(members))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(t) for t in m.group(3).split(",")]
        perm = [int(t) for t in m.group(4).split(",")] if m.group(4) \
            else list(range(len(dims)))
        strides = []
        acc = 1
        for d in reversed(dims):
            strides.append(acc)
            acc *= d
        strides = list(reversed(strides))     # stride per original dim
        covered = 1
        member_stride = 1
        span = 0
        first = True
        for p in reversed(perm):
            if covered >= gsize:
                break
            take = min(dims[p], max(1, gsize // covered))
            if first:
                member_stride = strides[p]
                first = False
            span += strides[p] * (take - 1)
            covered *= take
        return (gsize, member_stride, span)
    return None


def collective_bytes_by_stride(text: str, loop_aware: bool = True,
                               use_span: bool = False) \
        -> dict[tuple[str, int], float]:
    """{(kind, member_stride-or-span): bytes} with loop multipliers
    applied.  ``use_span=True`` keys by the group's id span instead —
    the right classifier for ICI-vs-DCN attribution (a direct all-to-all
    over (data, pod) has member stride 16 but span >= 256)."""
    comps = _parse_computations(text)
    mult = _multipliers(comps) if loop_aware else \
        {n: 1.0 for n in comps}
    out: dict[tuple[str, int], float] = {}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for (_, shape_str, kind, line) in comp.ops:
            base = kind.removesuffix("-start")
            if kind.endswith("-done") or base not in COLLECTIVE_KINDS:
                continue
            gg = collective_group_geometry(line)
            key_val = -1 if gg is None else (gg[2] if use_span else gg[1])
            key = (base, key_val)
            out[key] = out.get(key, 0.0) + m * shape_bytes(shape_str)
    return out


def _inlined_computations(comps: dict[str, _Comp]) -> set[str]:
    """Computations referenced via calls=/to_apply= (fusion bodies,
    reducers, comparators): their ops run in registers/VMEM, not HBM."""
    out: set[str] = set()
    pat = re.compile(r"(?:calls|to_apply)=\{?%?([\w.\-]+"
                     r"(?:,\s*%?[\w.\-]+)*)\}?")
    for comp in comps.values():
        for (_, _, _, line) in comp.ops:
            for m in pat.finditer(line):
                for n in m.group(1).split(","):
                    out.add(n.strip().lstrip("%"))
    return out


def loop_aware_analysis(text: str) -> dict:
    """Whole-module flops / byte-proxy / collective bytes, with while
    bodies weighted by their trip counts.  FLOPs count dots everywhere
    (incl. inside fusions); bytes count only at fusion granularity."""
    comps = _parse_computations(text)
    mult = _multipliers(comps)
    inlined = _inlined_computations(comps)
    flops = 0.0
    bytes_proxy = 0.0
    coll: dict[str, float] = {}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        flops += m * _comp_dot_flops(comp)
        if name not in inlined:
            bytes_proxy += m * _comp_bytes(comp, comps)
        for k, v in _comp_collective_bytes(comp).items():
            coll[k] = coll.get(k, 0.0) + m * v
    return {
        "flops": flops,
        "bytes_proxy": bytes_proxy,
        "collective_bytes": sum(coll.values()),
        "collective_bytes_by_kind": coll,
        "n_computations": len(comps) - 1,
    }
