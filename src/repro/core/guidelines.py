"""Self-consistent performance guidelines (paper viewpoint 3, refs [5,12]).

A guideline states: a collective must not be slower than an implementation
of itself in terms of other library functionality.  Here:

    MPI_Alltoall(p)  <=~  Alltoall_torus(D)        for every factorization D

i.e. the library-native (direct) all-to-all should never lose to the
factorized composition by more than a tolerance; when it does (as OpenMPI
4.1.6 does by >10x for 80..800-int blocks, paper Fig. 2), that is a
*guideline violation* — a performance bug surfaced automatically.

``check_guidelines`` consumes measured timings (from benchmarks) and
produces a violation report.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Measurement:
    impl: str                 # "direct" | "factorized[d=2:16x16]" | ...
    block_elems: int
    seconds: float


@dataclass(frozen=True)
class Violation:
    block_elems: int
    native_seconds: float
    best_composed_seconds: float
    best_composed_impl: str

    @property
    def factor(self) -> float:
        return self.native_seconds / self.best_composed_seconds


def check_guidelines(measurements: list[Measurement],
                     tolerance: float = 1.10) -> list[Violation]:
    """Native must satisfy t_native <= tolerance * min(t_composed)."""
    by_block: dict[int, list[Measurement]] = {}
    for m in measurements:
        by_block.setdefault(m.block_elems, []).append(m)
    out = []
    for block, ms in sorted(by_block.items()):
        native = [m for m in ms if m.impl == "direct"]
        composed = [m for m in ms if m.impl != "direct"]
        if not native or not composed:
            continue
        t_native = min(m.seconds for m in native)
        best = min(composed, key=lambda m: m.seconds)
        if t_native > tolerance * best.seconds:
            out.append(Violation(block, t_native, best.seconds, best.impl))
    return out


def format_report(violations: list[Violation]) -> str:
    if not violations:
        return "no guideline violations: native all-to-all is never beaten " \
               "by its factorized composition (within tolerance)"
    lines = ["GUIDELINE VIOLATIONS (native slower than composed):"]
    for v in violations:
        lines.append(
            f"  block={v.block_elems:>8} elems: native {v.native_seconds*1e6:10.1f}us"
            f" vs {v.best_composed_impl} {v.best_composed_seconds*1e6:10.1f}us"
            f"  ({v.factor:.2f}x)")
    return "\n".join(lines)
