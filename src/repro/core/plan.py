"""A2APlan — the cached, compiled plan-object API for every all-to-all.

The paper's central engineering lesson is that the expensive setup —
factorizing ``p`` into torus dimensions, building the ``d``-dimensional
Cartesian communicators, and picking the per-round datatypes — is done
**once, cached, and reused** across all-to-all calls (Listings 1–2 plus
the §5 tuning conclusion).  ``plan_all_to_all`` is that setup step for
this repo: it resolves, exactly once per ``(devices, axes, shape, dtype,
knobs)`` key,

* the torus factorization (``core.cache.get_factorization``, keyed by the
  stable ``(device.id, platform)`` fingerprint when a ``Mesh`` is given),
* the backend — ``direct`` | ``factorized`` | ``pipelined`` | ``overlap``,
  either requested explicitly or chosen by the alpha-beta cost model
  (``backend="tuned"`` → ``tuning.choose_algorithm``/``choose_chunks``),
* the per-round peer-axis sequence (forward and reverse/drain orders) and
  the payload chunk count,

and returns an :class:`A2APlan` whose methods — ``forward``, ``reverse``,
``tiled``, ``overlap`` — are the single execution surface every internal
consumer (MoE dispatch/combine, Ulysses re-shards, benchmarks, device
scripts) goes through.  Plans are cached in a bounded LRU registry, so
repeated calls with the same key return the same object: the analogue of
MPI's communicator attribute caching, measured in
``benchmarks/alltoall_cmp.py``'s plan-reuse column.

Execution methods must run inside ``jax.shard_map`` over the torus axes
(they lower to per-axis collectives); construction runs anywhere — at
trace time, at module setup, or from the legacy free-function shims in
``core.factorized`` / ``core.overlap`` (which now just build-or-fetch a
plan and warn).

``plan.describe()`` returns a stable dict (dims, backend, predicted cost,
chunks, cache hit/miss) for logging, goldens, and the dry-run artifacts.
"""

from __future__ import annotations

import math
import warnings
from typing import Callable

import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .cache import (
    LRUCache,
    TorusFactorization,
    device_fingerprint,
    get_factorization,
)
from .factorized import (
    _as_tuple,
    _direct_impl,
    _direct_tiled_impl,
    _factorized_impl,
    _factorized_tiled_impl,
    _skip_trivial,
)
from .overlap import _check_order, _overlapped_impl, _overlapped_tiled_impl
from .tuning import (
    DCN,
    ICI,
    LinkModel,
    Schedule,
    choose_algorithm,
    predict_direct,
    predict_factorized,
    predict_overlapped,
)

BACKENDS = ("tuned", "autotune", "direct", "factorized", "pipelined",
            "overlap")

# Mesh axes that cross the slow inter-pod network; everything else is
# priced as ICI.  Overridable per plan via ``links=``.
DCN_AXES = ("pod",)


def default_links(axis_names) -> tuple[LinkModel, ...]:
    """Per-axis link models: DCN for inter-pod axes, ICI otherwise."""
    return tuple(DCN if a in DCN_AXES else ICI for a in axis_names)


class A2APlan:
    """A resolved, reusable all-to-all execution plan.

    Construct via :func:`plan_all_to_all`; never directly.  All resolution
    (factorization, backend, chunk count, round orders, predicted cost)
    happens at construction; the execution methods only replay the chosen
    kernel.  Plans are plain static Python objects — closing over one
    inside ``shard_map``/``jit`` is free.
    """

    def __init__(self, fact: TorusFactorization, *, requested_backend: str,
                 backend: str, variant: str, order: tuple[int, ...],
                 rev_order: tuple[int, ...], n_chunks: int,
                 block_shape: tuple[int, ...] | None, dtype,
                 links: tuple[LinkModel, ...], schedule: Schedule | None,
                 mesh: Mesh | None, tuned_from: str | None = None,
                 measured: dict | None = None):
        self.fact = fact
        self.requested_backend = requested_backend
        self.backend = backend
        self.variant = variant
        self.order = order
        self.rev_order = rev_order
        self.n_chunks = n_chunks
        self.block_shape = block_shape
        self.dtype = dtype
        self.links = links
        self.schedule = schedule
        # Provenance of the backend/chunk choice: "measured" (tuning-DB
        # record from core.autotune), "model" (alpha-beta cost model), or
        # None (caller requested an explicit backend).
        self.tuned_from = tuned_from
        # For measured plans: the winner median + full measured table.
        self.measured = measured
        self._mesh = mesh
        self._from_cache = False
        self._fetches = 1
        self._host_fns: dict[Mesh, object] = {}

    # -- identity ----------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.fact.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.fact.dims

    @property
    def p(self) -> int:
        return self.fact.p

    @property
    def d(self) -> int:
        return self.fact.d

    @property
    def block_bytes(self) -> int | None:
        if self.block_shape is None or self.dtype is None:
            return None
        return math.prod(self.block_shape) * jnp.dtype(self.dtype).itemsize

    # -- execution surface (inside shard_map) ------------------------------

    def forward(self, x):
        """Blockwise all-to-all: ``x`` is ``(p, *block)``, block ``i``
        destined for torus rank ``i``; returns ``out[i]`` = block received
        from rank ``i``."""
        return self._run(x, self.order)

    def reverse(self, x):
        """The combine-direction all-to-all: same semantics as ``forward``
        but rounds run in the drain order (``rev_order``), so a
        forward+reverse pair fills and empties the dimension links in
        opposite sequence.  Bit-identical to ``forward`` for any order —
        the collective is pure data movement and rounds commute."""
        return self._run(x, self.rev_order)

    def _run(self, x, order):
        if self.backend == "direct":
            return _direct_impl(x, self.axis_names)
        if self.backend == "factorized":
            return _factorized_impl(x, self.axis_names, variant=self.variant,
                                    round_order=order)
        return _overlapped_impl(x, self.axis_names, n_chunks=self.n_chunks,
                                variant=self.variant, round_order=order)

    def tiled(self, x, split_axis: int, concat_axis: int, *,
              reverse: bool = False):
        """Tiled-semantics all-to-all — drop-in for ``lax.all_to_all(x,
        reversed(axis_names), split_axis, concat_axis, tiled=True)``; the
        MoE-dispatch and Ulysses re-shard form."""
        order = self.rev_order if reverse else self.order
        if self.backend == "direct":
            return _direct_tiled_impl(x, self.axis_names, split_axis,
                                      concat_axis)
        if self.backend == "factorized":
            return _factorized_tiled_impl(x, self.axis_names, split_axis,
                                          concat_axis, variant=self.variant,
                                          round_order=order)
        return _overlapped_tiled_impl(x, self.axis_names, split_axis,
                                      concat_axis, n_chunks=self.n_chunks,
                                      variant=self.variant,
                                      round_order=order)

    def overlap(self, x, compute_fn: Callable | None = None, *,
                reverse: bool = True, chunk_axis: int | None = None):
        """Fused forward / per-chunk compute / reverse pipeline
        (``core.overlap``): chunk ``c``'s forward rounds are emitted next
        to chunk ``c-1``'s compute and chunk ``c-2``'s reverse rounds.
        Bit-exact with ``reverse(compute_fn(forward(x)))`` since chunks
        never interact."""
        return _overlapped_impl(x, self.axis_names, n_chunks=self.n_chunks,
                                variant=self.variant, round_order=self.order,
                                compute_fn=compute_fn, reverse=reverse,
                                reverse_round_order=self.rev_order,
                                chunk_axis=chunk_axis)

    # -- host-level convenience -------------------------------------------

    def host_fn(self, mesh: Mesh | None = None):
        """Jitted host-level all-to-all over a global ``(p, p, *block)``
        operand (``x[r, i]`` = rank r's block for rank i), the benchmark
        harness form.  The jitted callable is cached on the plan keyed by
        mesh *value* (Mesh is hashable), so plan reuse amortizes
        retracing even when the caller rebuilds an equal Mesh."""
        mesh = self._mesh if mesh is None else mesh
        if mesh is None:
            raise ValueError("plan was built without a Mesh; pass one")
        if mesh not in self._host_fns:
            import jax
            spec = P(tuple(reversed(self.axis_names)))

            def local(x):   # x: (1, p, *block) per device
                return self.forward(x[0])[None]

            self._host_fns[mesh] = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=spec, out_specs=spec))
        return self._host_fns[mesh]

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the resolved plan."""
        sched = self.schedule
        return {
            "axis_names": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "backend": self.backend,
            "requested_backend": self.requested_backend,
            "variant": self.variant,
            "round_order": list(self.order),
            "reverse_round_order": list(self.rev_order),
            "n_chunks": self.n_chunks,
            "block_shape": None if self.block_shape is None
            else list(self.block_shape),
            "dtype": None if self.dtype is None
            else jnp.dtype(self.dtype).name,
            "block_bytes": self.block_bytes,
            "predicted_seconds": None if sched is None
            else sched.predicted_seconds,
            "blocks_sent_per_device": self.fact.blocks_sent_per_device(),
            "links": [{"alpha": l.alpha, "bandwidth": l.bandwidth}
                      for l in self.links],
            "tuned_from": self.tuned_from,
            "measured": self.measured,
            "cache": "hit" if self._from_cache else "miss",
        }

    def __repr__(self):
        return (f"A2APlan(dims={self.dims}, axes={self.axis_names}, "
                f"backend={self.backend!r}, n_chunks={self.n_chunks}, "
                f"variant={self.variant!r})")


# ---------------------------------------------------------------------------
# Construction + the plan registry
# ---------------------------------------------------------------------------

_PLANS: LRUCache = LRUCache(capacity=256)


def _resolve(dims, axis_names, block_shape, dtype, requested_backend,
             variant, round_order, reverse_round_order, n_chunks,
             max_chunks, links, compute_seconds):
    """All the once-per-plan decisions, in one place."""
    if requested_backend not in BACKENDS:
        raise ValueError(f"unknown a2a backend {requested_backend!r}; "
                         f"expected one of {BACKENDS}")
    if variant not in ("natural", "paper"):
        raise ValueError(f"unknown variant {variant!r}")
    links = default_links(axis_names) if links is None else tuple(links)
    if len(links) != len(dims):
        raise ValueError(f"{len(links)} links for {len(dims)} dims")

    # Round orders act on the *active* (size > 1) dimensions, matching the
    # kernels' skip-trivial semantics; validated here, at plan time.
    _, active = _skip_trivial(axis_names, dims)
    d_active = len(active)
    order = _check_order(round_order, d_active)
    rev_order = (tuple(reversed(order)) if reverse_round_order is None
                 else _check_order(reverse_round_order, d_active))

    p = math.prod(dims)
    block_bytes = None
    if block_shape is not None and dtype is not None:
        block_bytes = math.prod(block_shape) * jnp.dtype(dtype).itemsize

    if requested_backend == "tuned":
        if block_bytes is None:
            raise ValueError('backend="tuned" needs block_shape and dtype '
                             "for the cost model")
        sched = choose_algorithm(dims, links, float(block_bytes),
                                 max_chunks=max_chunks,
                                 compute_seconds=compute_seconds)
        backend = sched.kind
        n = n_chunks or sched.n_chunks
        return backend, order, rev_order, max(1, n), links, sched

    backend = requested_backend
    n = n_chunks or (2 if backend in ("overlap", "pipelined") else 1)
    n = max(1, n)
    sched = None
    if block_bytes is not None:
        if backend == "direct":
            # price only links that carry traffic: a size-1 axis (e.g. a
            # trivial "pod" dim, or an unfitted placeholder link from a
            # tuning-DB record) must not masquerade as the bottleneck
            active_links = [l for Dk, l in zip(dims, links) if Dk > 1] \
                or list(links)
            slowest = min(active_links, key=lambda l: l.bandwidth)
            t = predict_direct(p, float(block_bytes), slowest) \
                + compute_seconds
        elif backend == "factorized":
            t = predict_factorized(dims, links, float(block_bytes), p) \
                + compute_seconds
        else:
            t = predict_overlapped(dims, links, float(block_bytes), p, n,
                                   compute_seconds)
        sched = Schedule(backend, dims, links, t, n_chunks=n)
    return backend, order, rev_order, n, links, sched


def plan_all_to_all(mesh_or_axis_dims, axis_names, block_shape=None,
                    dtype=None, *, backend: str = "tuned",
                    variant: str = "natural", round_order=None,
                    reverse_round_order=None, n_chunks: int = 0,
                    max_chunks: int = 8, links=None,
                    compute_seconds: float = 0.0, db=None) -> A2APlan:
    """Build (or fetch from the LRU registry) an :class:`A2APlan`.

    Args:
      mesh_or_axis_dims: a ``Mesh`` (the torus axes are looked up on it and
        the plan is keyed by the stable device fingerprint) or an explicit
        tuple of per-axis sizes, fastest digit first (device-agnostic key —
        the inside-``shard_map`` shim path).
      axis_names: torus dimensions, fastest digit first.
      block_shape, dtype: shape/dtype of one per-rank block — feeds the
        alpha-beta cost model.  Optional unless ``backend="tuned"`` or
        ``"autotune"``.
      backend: "tuned" (cost-model choice), "autotune" (measured choice
        from the persistent tuning DB — a hit rebuilds the recorded
        winner, a miss falls back to the cost model without measuring;
        see ``core.autotune``), or an explicit kernel:
        "direct" | "factorized" | "pipelined" | "overlap".
      variant: per-round formulation, "natural" (zero-copy) or "paper".
      round_order / reverse_round_order: permutations of the active rounds
        (default: identity, and its reversal for the drain direction).
      n_chunks: payload chunks for the overlap engine; 0 = resolve (cost
        model under "tuned", else 2).
      max_chunks: search bound for the tuned chunk count.
      links: per-axis :class:`LinkModel` overrides (default: DCN for
        ``pod``-like axes, ICI otherwise; measured per-axis fits under a
        tuning-DB hit).
      compute_seconds: per-call interleaved compute estimate for tuning.
      db: tuning-DB handle for ``backend="autotune"`` (default: the
        ``REPRO_TUNING_DB`` / ``~/.cache/repro/tuning.json`` database).
    """
    axis_names = _as_tuple(axis_names)
    mesh = None
    if isinstance(mesh_or_axis_dims, Mesh):
        mesh = mesh_or_axis_dims
        fact = get_factorization(mesh, axis_names, variant=variant)
        dims = fact.dims
        dev_key = device_fingerprint(mesh)
    else:
        dims = tuple(int(s) for s in mesh_or_axis_dims)
        if len(dims) != len(axis_names):
            raise ValueError(f"{len(dims)} dims for {len(axis_names)} axes")
        fact = TorusFactorization(axis_names, dims, variant)
        dev_key = None

    links_key = None if links is None else tuple(links)
    key = (dev_key, dims, axis_names, None if block_shape is None
           else tuple(block_shape),
           None if dtype is None else jnp.dtype(dtype).name,
           backend, variant,
           None if round_order is None else tuple(round_order),
           None if reverse_round_order is None
           else tuple(reverse_round_order),
           int(n_chunks), int(max_chunks), links_key,
           float(compute_seconds))
    if backend == "autotune":
        # Cached autotune plans must be re-resolved when the DB changes
        # (a new measurement landed, or the file was deleted): key on the
        # DB identity + its per-path write generation.
        from .autotune import get_default_db
        db = db if db is not None else get_default_db()
        key = key + (db.path_key, db.generation())
    cached = _PLANS.get(key)
    if cached is not None:
        cached._from_cache = True
        cached._fetches += 1
        return cached

    def build(req_backend, order_, chunks_, links_):
        return _resolve(dims, axis_names, block_shape, dtype, req_backend,
                        variant, order_, reverse_round_order, chunks_,
                        max_chunks, links_, compute_seconds)

    tuned_from, measured = None, None
    if backend == "tuned":
        tuned_from = "model"
        parts = build("tuned", round_order, n_chunks, links)
    elif backend == "autotune":
        if block_shape is None or dtype is None:
            raise ValueError('backend="autotune" needs block_shape and '
                             "dtype (the tuning-DB key)")
        from .autotune import lookup_measured, measured_links
        rec = lookup_measured(dev_key, dims, axis_names,
                              tuple(block_shape), dtype, variant, db=db)
        parts = None
        if rec is not None:
            w = rec["winner"]
            rec_order = round_order if round_order is not None else \
                (tuple(w["round_order"]) if w.get("round_order") is not None
                 else None)
            rec_chunks = n_chunks or int(w.get("n_chunks", 0))
            rec_links = links
            if rec_links is None:
                rec_links = measured_links(rec)
            try:
                parts = build(w["backend"], rec_order, rec_chunks,
                              rec_links)
                tuned_from = "measured"
                measured = {"median_us": w.get("median_us"),
                            "table": rec.get("table", []),
                            "best_factorization":
                                rec.get("best_factorization"),
                            "db_path": str(db.path)}
            except ValueError as e:
                from .autotune import demote_hit_to_miss
                demote_hit_to_miss()   # telemetry: this plan is model-built
                warnings.warn(f"tuning-DB record unusable for this plan "
                              f"({e}); falling back to the cost model")
        if parts is None:   # DB miss (or unusable record): analytic choice,
            tuned_from = "model"   # never a blocking measurement
            parts = build("tuned", round_order, n_chunks, links)
    else:
        parts = build(backend, round_order, n_chunks, links)

    resolved, order, rev_order, n, link_models, sched = parts
    plan = A2APlan(fact, requested_backend=backend, backend=resolved,
                   variant=variant, order=order, rev_order=rev_order,
                   n_chunks=n, block_shape=None if block_shape is None
                   else tuple(block_shape), dtype=dtype, links=link_models,
                   schedule=sched, mesh=mesh, tuned_from=tuned_from,
                   measured=measured)
    _PLANS.put(key, plan)
    return plan


def free_plans() -> None:
    """Evict every cached plan (the registry-wide delete callback)."""
    _PLANS.clear()


def set_plan_cache_capacity(capacity: int) -> None:
    """Bound the plan registry (evicting LRU entries if needed)."""
    _PLANS.set_capacity(capacity)


def plan_cache_stats() -> dict[str, int]:
    out = dict(_PLANS.stats)
    out["size"] = len(_PLANS)
    out["capacity"] = _PLANS.capacity
    return out


def plan_cache_entries() -> list[A2APlan]:
    """Snapshot of the live plans, LRU-oldest first (for logging/artifacts;
    does not touch recency or stats)."""
    return _PLANS.values()
