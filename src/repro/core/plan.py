"""A2APlan — the cached, compiled plan-object API for every all-to-all.

The paper's central engineering lesson is that the expensive setup —
factorizing ``p`` into torus dimensions, building the ``d``-dimensional
Cartesian communicators, and picking the per-round datatypes — is done
**once, cached, and reused** across all-to-all calls (Listings 1–2 plus
the §5 tuning conclusion).  ``plan_all_to_all`` is that setup step for
this repo: it resolves, exactly once per ``(devices, axes, shape, dtype,
knobs)`` key,

* the torus factorization (``core.cache.get_factorization``, keyed by the
  stable ``(device.id, platform)`` fingerprint when a ``Mesh`` is given),
* the backend — ``direct`` | ``factorized`` | ``pipelined`` | ``overlap``,
  either requested explicitly or chosen by the alpha-beta cost model
  (``backend="tuned"`` → ``tuning.choose_algorithm``/``choose_chunks``),
* the per-round peer-axis sequence (forward and reverse/drain orders) and
  the payload chunk count,

and returns an :class:`A2APlan` whose methods — ``forward``, ``reverse``,
``tiled``, ``overlap`` — are the single execution surface every internal
consumer (MoE dispatch/combine, Ulysses re-shards, benchmarks, device
scripts) goes through.  Plans are cached in a bounded LRU registry, so
repeated calls with the same key return the same object: the analogue of
MPI's communicator attribute caching, measured in
``benchmarks/alltoall_cmp.py``'s plan-reuse column.

Execution methods must run inside ``jax.shard_map`` over the torus axes
(they lower to per-axis collectives); construction runs anywhere — at
trace time, at module setup, or from the legacy free-function shims in
``core.factorized`` / ``core.overlap`` (which now just build-or-fetch a
plan and warn).

Since the ``TorusComm`` redesign (``core.comm``) the communicator is the
API root: ``torus_comm(mesh, axes).all_to_all(...)`` is the primary
spelling, and :func:`plan_all_to_all` / :func:`plan_ragged_all_to_all`
are thin delegators that build or reuse the *implicit* comm — same
registry entries, same describe dicts, zero migration pressure for PR 2
era callers.  This module keeps the plan classes, the resolution
machinery (``_build_dense_plan`` / ``_build_ragged_plan``), and the
shared LRU registry with its teardown callback (evicting a composite
plan drops its nested dense entries and releases factorization refs).

``plan.describe()`` returns a stable dict (dims, backend, predicted cost,
chunks, cache hit/miss) for logging, goldens, and the dry-run artifacts.

:func:`plan_ragged_all_to_all` / :class:`RaggedA2APlan` extend the same
plan-object design to MPI_Alltoallv semantics (non-uniform per-pair
counts): a tiny int32 counts plan plus a bucket-padded data plan over the
identical torus, cached in the same registry — see ``core.ragged``.
"""

from __future__ import annotations

import math
import time
import warnings
from typing import Callable

import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import telemetry
from .cache import (
    LRUCache,
    TorusFactorization,
    device_fingerprint,
    get_factorization,
)
from .factorized import (
    _as_tuple,
    _direct_impl,
    _direct_tiled_impl,
    _factorized_impl,
    _factorized_round_impl,
    _factorized_tiled_impl,
    _skip_trivial,
)
from .overlap import _check_order, _overlapped_impl, _overlapped_tiled_impl
from .tuning import (
    DCN_AXES,            # noqa: F401  (re-exported; moved to core.tuning)
    LinkModel,
    Schedule,
    choose_algorithm,
    default_links,   # noqa: F401  (re-exported; moved to core.tuning)
    per_axis_round_seconds,
    predict_direct,
    predict_factorized,
    predict_overlapped,
    resolve_links,
    slowest_active_link,
)

BACKENDS = ("tuned", "autotune", "direct", "factorized", "pipelined",
            "overlap")


class A2APlan:
    """A resolved, reusable all-to-all execution plan.

    Construct via :func:`plan_all_to_all`; never directly.  All resolution
    (factorization, backend, chunk count, round orders, predicted cost)
    happens at construction; the execution methods only replay the chosen
    kernel.  Plans are plain static Python objects — closing over one
    inside ``shard_map``/``jit`` is free.
    """

    def __init__(self, fact: TorusFactorization, *, requested_backend: str,
                 backend: str, variant: str, order: tuple[int, ...],
                 rev_order: tuple[int, ...], n_chunks: int,
                 block_shape: tuple[int, ...] | None, dtype,
                 links: tuple[LinkModel, ...], schedule: Schedule | None,
                 mesh: Mesh | None, tuned_from: str | None = None,
                 measured: dict | None = None):
        self.fact = fact
        self.requested_backend = requested_backend
        self.backend = backend
        self.variant = variant
        self.order = order
        self.rev_order = rev_order
        self.n_chunks = n_chunks
        self.block_shape = block_shape
        self.dtype = dtype
        self.links = links
        self.schedule = schedule
        # Provenance of the backend/chunk choice: "measured" (tuning-DB
        # record from core.autotune), "model" (alpha-beta cost model), or
        # None (caller requested an explicit backend).
        self.tuned_from = tuned_from
        # For measured plans: the winner median + full measured table.
        self.measured = measured
        self._mesh = mesh
        self._from_cache = False
        self._fetches = 1
        self._host_fns: dict[Mesh, object] = {}
        self._round_fns: dict[Mesh, list] = {}

    # -- identity ----------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.fact.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.fact.dims

    @property
    def p(self) -> int:
        return self.fact.p

    @property
    def d(self) -> int:
        return self.fact.d

    @property
    def block_bytes(self) -> int | None:
        if self.block_shape is None or self.dtype is None:
            return None
        return math.prod(self.block_shape) * jnp.dtype(self.dtype).itemsize

    # -- execution surface (inside shard_map) ------------------------------

    def forward(self, x):
        """Blockwise all-to-all: ``x`` is ``(p, *block)``, block ``i``
        destined for torus rank ``i``; returns ``out[i]`` = block received
        from rank ``i``."""
        return self._run(x, self.order)

    def reverse(self, x):
        """The combine-direction all-to-all: same semantics as ``forward``
        but rounds run in the drain order (``rev_order``), so a
        forward+reverse pair fills and empties the dimension links in
        opposite sequence.  Bit-identical to ``forward`` for any order —
        the collective is pure data movement and rounds commute."""
        return self._run(x, self.rev_order)

    def _run(self, x, order):
        if self.backend == "direct":
            return _direct_impl(x, self.axis_names)
        if self.backend == "factorized":
            return _factorized_impl(x, self.axis_names, variant=self.variant,
                                    round_order=order)
        return _overlapped_impl(x, self.axis_names, n_chunks=self.n_chunks,
                                variant=self.variant, round_order=order)

    def tiled(self, x, split_axis: int, concat_axis: int, *,
              reverse: bool = False):
        """Tiled-semantics all-to-all — drop-in for ``lax.all_to_all(x,
        reversed(axis_names), split_axis, concat_axis, tiled=True)``; the
        MoE-dispatch and Ulysses re-shard form."""
        order = self.rev_order if reverse else self.order
        if self.backend == "direct":
            return _direct_tiled_impl(x, self.axis_names, split_axis,
                                      concat_axis)
        if self.backend == "factorized":
            return _factorized_tiled_impl(x, self.axis_names, split_axis,
                                          concat_axis, variant=self.variant,
                                          round_order=order)
        return _overlapped_tiled_impl(x, self.axis_names, split_axis,
                                      concat_axis, n_chunks=self.n_chunks,
                                      variant=self.variant,
                                      round_order=order)

    def overlap(self, x, compute_fn: Callable | None = None, *,
                reverse: bool = True, chunk_axis: int | None = None):
        """Fused forward / per-chunk compute / reverse pipeline
        (``core.overlap``): chunk ``c``'s forward rounds are emitted next
        to chunk ``c-1``'s compute and chunk ``c-2``'s reverse rounds.
        Bit-exact with ``reverse(compute_fn(forward(x)))`` since chunks
        never interact."""
        return _overlapped_impl(x, self.axis_names, n_chunks=self.n_chunks,
                                variant=self.variant, round_order=self.order,
                                compute_fn=compute_fn, reverse=reverse,
                                reverse_round_order=self.rev_order,
                                chunk_axis=chunk_axis)

    # -- host-level convenience -------------------------------------------

    def host_fn(self, mesh: Mesh | None = None):
        """Jitted host-level all-to-all over a global ``(p, p, *block)``
        operand (``x[r, i]`` = rank r's block for rank i), the benchmark
        harness form.  The jitted callable is cached on the plan keyed by
        mesh *value* (Mesh is hashable), so plan reuse amortizes
        retracing even when the caller rebuilds an equal Mesh.

        The returned callable checks the telemetry tracer per call: off
        (the default), it dispatches the cached fused jit directly; on,
        factorized plans execute the *stepped* per-round path (one jitted
        step per dimension-wise round — bit-exact, rounds commute) so
        every round gets a measured span and a drift observation."""
        mesh = self._mesh if mesh is None else mesh
        if mesh is None:
            raise ValueError("plan was built without a Mesh; pass one")
        if mesh not in self._host_fns:
            import jax
            spec = P(tuple(reversed(self.axis_names)))

            def local(x):   # x: (1, p, *block) per device
                return self.forward(x[0])[None]

            self._host_fns[mesh] = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=spec, out_specs=spec))
        fast = self._host_fns[mesh]

        # The tracer singleton is never rebound (enable/disable mutate it
        # in place), so bind it once here: the disabled fast path is one
        # attribute load + branch per call, not a registry lookup.
        tr = telemetry.get_tracer()

        def run(x):
            if not tr.enabled:
                return fast(x)
            return self._traced_execute(tr, mesh, fast, x)

        return run

    # -- telemetry-traced execution ----------------------------------------

    def _drift_key(self) -> str:
        """Stable drift-detector key: one time series per resolved plan
        identity (axes x dims x backend x block)."""
        dims = "x".join(str(s) for s in self.dims)
        return (f"dense[{','.join(self.axis_names)}]{dims}:{self.backend}"
                f":{self.block_bytes}")

    def _per_axis_predictions(self) -> dict[str, float] | None:
        """``{axis_name: model seconds}`` for the active rounds, or None
        without a sized block (tiled plans carry no block shape)."""
        if self.block_bytes is None:
            return None
        per_axis = per_axis_round_seconds(self.dims, self.links,
                                          float(self.block_bytes))
        return {name: t for name, Dk, t
                in zip(self.axis_names, self.dims, per_axis) if Dk > 1}

    def _round_host_fns(self, mesh):
        """Per-round jitted host fns in forward round order — the
        stepped traced path (factorized backend only)."""
        if mesh not in self._round_fns:
            import jax
            spec = P(tuple(reversed(self.axis_names)))
            names, sizes = _skip_trivial(self.axis_names, self.dims)
            fns = []
            for k in self.order:
                def local(x, _k=k):
                    return _factorized_round_impl(
                        x[0], self.axis_names, _k,
                        variant=self.variant)[None]
                fns.append((k, names[k], sizes[k],
                            jax.jit(jax.shard_map(
                                local, mesh=mesh, in_specs=spec,
                                out_specs=spec))))
            self._round_fns[mesh] = fns
        return self._round_fns[mesh]

    def _traced_execute(self, tr, mesh, fast, x):
        import jax
        det = telemetry.drift_detector()
        key = self._drift_key()
        preds = self._per_axis_predictions()
        predicted = self.schedule.predicted_seconds \
            if self.schedule is not None \
            else (sum(preds.values()) if preds else None)
        telemetry.metrics().counter("plan.traced_executions").inc()
        # Installed fault injectors (core.faults) expose a per-round
        # guard so injected slow rounds land inside the round spans.
        check = getattr(self, "_round_fault_check", None)
        with tr.span("plan.execute", cat="plan", kind="dense",
                     backend=self.backend, axes=",".join(self.axis_names),
                     dims="x".join(str(s) for s in self.dims),
                     predicted_seconds=predicted, tuned_from=self.tuned_from,
                     drift_key=key) as ex:
            t0 = time.perf_counter()
            if self.backend == "factorized":
                y = x
                for k, name, Dk, fn in self._round_host_fns(mesh):
                    pred_k = None if preds is None else preds.get(name)
                    with tr.span("plan.round", cat="plan", axis=name,
                                 round=k, dim=Dk,
                                 predicted_seconds=pred_k):
                        if check is not None:
                            check()
                        tr0 = time.perf_counter()
                        y = jax.block_until_ready(fn(y))
                        if pred_k:
                            det.observe(f"{key}:axis={name}", pred_k,
                                        time.perf_counter() - tr0)
            else:
                # direct = a single product-communicator round; overlap
                # interleaves rounds across chunks — neither splits into
                # host-steppable rounds, so one fused span covers them.
                with tr.span("plan.round", cat="plan", axis="*",
                             backend=self.backend, timing="fused",
                             predicted_seconds=predicted):
                    if check is not None:
                        check()
                    y = jax.block_until_ready(fast(x))
            measured = time.perf_counter() - t0
            ratio = det.observe(key, predicted, measured) \
                if predicted else None
            ex.set(measured_seconds=measured, drift_ratio=ratio)
        return y

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the resolved plan."""
        sched = self.schedule
        return {
            "kind": "dense",
            "axis_names": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "backend": self.backend,
            "requested_backend": self.requested_backend,
            "variant": self.variant,
            "round_order": list(self.order),
            "reverse_round_order": list(self.rev_order),
            "n_chunks": self.n_chunks,
            "block_shape": None if self.block_shape is None
            else list(self.block_shape),
            "dtype": None if self.dtype is None
            else jnp.dtype(self.dtype).name,
            "block_bytes": self.block_bytes,
            "predicted_seconds": None if sched is None
            else sched.predicted_seconds,
            "blocks_sent_per_device": self.fact.blocks_sent_per_device(),
            "links": [{"alpha": l.alpha, "bandwidth": l.bandwidth}
                      for l in self.links],
            "tuned_from": self.tuned_from,
            "measured": self.measured,
            "drift_ratio": telemetry.drift_detector()
            .drift_ratio(self._drift_key()),
            "cache": "hit" if self._from_cache else "miss",
        }

    def __repr__(self):
        return (f"A2APlan(dims={self.dims}, axes={self.axis_names}, "
                f"backend={self.backend!r}, n_chunks={self.n_chunks}, "
                f"variant={self.variant!r})")


# ---------------------------------------------------------------------------
# Construction + the plan registry
# ---------------------------------------------------------------------------


def _sub_plans(plan) -> tuple:
    """Nested plans a composite plan owns (ragged: data + counts; sparse:
    counts only — its data rounds are its own kernel; kv_migrate: the
    inner ragged/sparse plan, whose own nested entries drop recursively
    when it does)."""
    if isinstance(plan, RaggedA2APlan):
        return (plan.data, plan.counts_plan)
    if isinstance(plan, SparseA2APlan):
        return (plan.counts_plan,)
    if isinstance(plan, (KVMigrationPlan, TransposePlan)):
        return (plan.inner,)
    return ()


def _plan_fact(plan):
    """The factorization descriptor behind any plan kind."""
    fact = getattr(plan, "fact", None)
    return plan.data.fact if fact is None else fact


def _release_fact(fact) -> None:
    """Drop the factorization registry entries for ``fact`` once no live
    plan uses it — the paper's delete callback (Listing 2's ``torusdel``),
    run from the plan layer so the two registries tear down together."""
    for q in _PLANS.values():
        if _plan_fact(q) == fact:
            return
    from . import cache as _cache
    _cache.free(fact)


def _on_plan_evict(plan) -> None:
    """Teardown symmetry for the plan registry.

    Evicting (or explicitly dropping) a composite plan also drops its
    nested dense plans' registry entries — unless another live composite
    still owns one (two ragged plans over the same torus share a counts
    plan) — and the last plan over a factorization releases the
    descriptor cache entry.  Without this, LRU churn through ragged plans
    left orphaned ``(bucket, *row)`` / counts entries pinned in the
    registry and factorization refs that ``cache_stats`` counted forever.
    """
    for subp in _sub_plans(plan):
        key = getattr(subp, "_registry_key", None)
        # only drop the entry if the registry still holds *this* object:
        # after LRU churn a fresh equal-key plan (possibly a live
        # composite's nested member) may occupy the slot
        if key is None or _PLANS._data.get(key) is not subp:
            continue
        if any(subp in _sub_plans(q) for q in _PLANS.values()):
            continue
        dropped = _PLANS.pop(key)
        if dropped is not None:
            _on_plan_evict(dropped)
    _release_fact(_plan_fact(plan))


_PLANS: LRUCache = LRUCache(capacity=256, on_evict=_on_plan_evict)


def _registry_fetch(key):
    cached = _PLANS.get(key)
    if cached is not None:
        cached._from_cache = True
        cached._fetches += 1
    return cached


def _registry_store(key, plan):
    plan._registry_key = key
    _PLANS.put(key, plan)
    return plan


def _drop_plan(key) -> None:
    """Explicitly remove one plan entry, with the same teardown as LRU
    eviction (used by ``TorusComm.free``)."""
    plan = _PLANS.pop(key)
    if plan is not None:
        _on_plan_evict(plan)


def _resolve(dims, axis_names, block_shape, dtype, requested_backend,
             variant, round_order, reverse_round_order, n_chunks,
             max_chunks, links, compute_seconds):
    """All the once-per-plan decisions, in one place."""
    if requested_backend not in BACKENDS:
        raise ValueError(f"unknown a2a backend {requested_backend!r}; "
                         f"expected one of {BACKENDS}")
    if variant not in ("natural", "paper"):
        raise ValueError(f"unknown variant {variant!r}")
    links = resolve_links(links, dims, axis_names)

    # Round orders act on the *active* (size > 1) dimensions, matching the
    # kernels' skip-trivial semantics; validated here, at plan time.
    _, active = _skip_trivial(axis_names, dims)
    d_active = len(active)
    order = _check_order(round_order, d_active)
    rev_order = (tuple(reversed(order)) if reverse_round_order is None
                 else _check_order(reverse_round_order, d_active))

    p = math.prod(dims)
    block_bytes = None
    if block_shape is not None and dtype is not None:
        block_bytes = math.prod(block_shape) * jnp.dtype(dtype).itemsize

    if requested_backend == "tuned":
        if block_bytes is None:
            raise ValueError('backend="tuned" needs block_shape and dtype '
                             "for the cost model")
        sched = choose_algorithm(dims, links, float(block_bytes),
                                 max_chunks=max_chunks,
                                 compute_seconds=compute_seconds)
        backend = sched.kind
        n = n_chunks or sched.n_chunks
        return backend, order, rev_order, max(1, n), links, sched

    backend = requested_backend
    n = n_chunks or (2 if backend in ("overlap", "pipelined") else 1)
    n = max(1, n)
    sched = None
    if block_bytes is not None:
        if backend == "direct":
            slowest = slowest_active_link(dims, links)
            t = predict_direct(p, float(block_bytes), slowest) \
                + compute_seconds
        elif backend == "factorized":
            t = predict_factorized(dims, links, float(block_bytes), p) \
                + compute_seconds
        else:
            t = predict_overlapped(dims, links, float(block_bytes), p, n,
                                   compute_seconds)
        sched = Schedule(backend, dims, links, t, n_chunks=n)
    return backend, order, rev_order, n, links, sched


def plan_all_to_all(mesh_or_axis_dims, axis_names, block_shape=None,
                    dtype=None, *, backend: str = "tuned",
                    variant: str = "natural", round_order=None,
                    reverse_round_order=None, n_chunks: int = 0,
                    max_chunks: int = 8, links=None,
                    compute_seconds: float = 0.0, db=None) -> A2APlan:
    """Build (or fetch from the LRU registry) an :class:`A2APlan`.

    A thin delegator since the ``TorusComm`` redesign: it builds or
    reuses the *implicit communicator* for ``(devices, axes, variant)``
    (``core.comm.torus_comm``) and constructs the plan through it, so
    legacy callers and the PR 2 deprecation shims share the comm-rooted
    path with no behavior change — new code should hold a
    :class:`~repro.core.comm.TorusComm` and call ``comm.all_to_all``.

    Args:
      mesh_or_axis_dims: a ``Mesh`` (the torus axes are looked up on it and
        the plan is keyed by the stable device fingerprint) or an explicit
        tuple of per-axis sizes, fastest digit first (device-agnostic key —
        the inside-``shard_map`` shim path).
      axis_names: torus dimensions, fastest digit first.
      block_shape, dtype: shape/dtype of one per-rank block — feeds the
        alpha-beta cost model.  Optional unless ``backend="tuned"`` or
        ``"autotune"``.
      backend: "tuned" (cost-model choice), "autotune" (measured choice
        from the persistent tuning DB — a hit rebuilds the recorded
        winner, a miss falls back to the cost model without measuring;
        see ``core.autotune``), or an explicit kernel:
        "direct" | "factorized" | "pipelined" | "overlap".
      variant: per-round formulation, "natural" (zero-copy) or "paper".
      round_order / reverse_round_order: permutations of the active rounds
        (default: identity, and its reversal for the drain direction).
      n_chunks: payload chunks for the overlap engine; 0 = resolve (cost
        model under "tuned", else 2).
      max_chunks: search bound for the tuned chunk count.
      links: per-axis :class:`LinkModel` overrides (default: DCN for
        ``pod``-like axes, ICI otherwise; measured per-axis fits under a
        tuning-DB hit).
      compute_seconds: per-call interleaved compute estimate for tuning.
      db: tuning-DB handle for ``backend="autotune"`` (default: the
        ``REPRO_TUNING_DB`` / ``~/.cache/repro/tuning.json`` database).
    """
    from .comm import torus_comm
    return torus_comm(mesh_or_axis_dims, axis_names,
                      variant=variant).all_to_all(
        block_shape, dtype, backend=backend, round_order=round_order,
        reverse_round_order=reverse_round_order, n_chunks=n_chunks,
        max_chunks=max_chunks, links=links,
        compute_seconds=compute_seconds, db=db)


def _build_dense_plan(mesh_or_axis_dims, axis_names, block_shape=None,
                      dtype=None, *, backend: str = "tuned",
                      variant: str = "natural", round_order=None,
                      reverse_round_order=None, n_chunks: int = 0,
                      max_chunks: int = 8, links=None,
                      compute_seconds: float = 0.0, db=None) -> A2APlan:
    """The resolution machinery behind ``TorusComm.all_to_all`` (and the
    :func:`plan_all_to_all` delegator): all once-per-plan decisions plus
    the LRU registry."""
    axis_names = _as_tuple(axis_names)
    mesh = None
    if isinstance(mesh_or_axis_dims, Mesh):
        mesh = mesh_or_axis_dims
        fact = get_factorization(mesh, axis_names, variant=variant)
        dims = fact.dims
        dev_key = device_fingerprint(mesh)
    else:
        dims = tuple(int(s) for s in mesh_or_axis_dims)
        if len(dims) != len(axis_names):
            raise ValueError(f"{len(dims)} dims for {len(axis_names)} axes")
        fact = TorusFactorization(axis_names, dims, variant)
        dev_key = None

    # None stays None in the key (under "autotune" it means measured
    # links may substitute); anything else is normalized so a uniform
    # LinkModel and its broadcast tuple key identically.
    links_key = None if links is None else resolve_links(links, dims)
    key = (dev_key, dims, axis_names, None if block_shape is None
           else tuple(block_shape),
           None if dtype is None else jnp.dtype(dtype).name,
           backend, variant,
           None if round_order is None else tuple(round_order),
           None if reverse_round_order is None
           else tuple(reverse_round_order),
           int(n_chunks), int(max_chunks), links_key,
           float(compute_seconds))
    if backend == "autotune":
        # Cached autotune plans must be re-resolved when the DB changes
        # (a new measurement landed, or the file was deleted): key on the
        # DB identity + its per-path write generation.
        from .autotune import get_default_db
        db = db if db is not None else get_default_db()
        key = key + (db.path_key, db.generation())
    cached = _registry_fetch(key)
    if cached is not None:
        return cached

    def build(req_backend, order_, chunks_, links_):
        return _resolve(dims, axis_names, block_shape, dtype, req_backend,
                        variant, order_, reverse_round_order, chunks_,
                        max_chunks, links_, compute_seconds)

    tuned_from, measured = None, None
    if backend == "tuned":
        tuned_from = "model"
        parts = build("tuned", round_order, n_chunks, links)
    elif backend == "autotune":
        if block_shape is None or dtype is None:
            raise ValueError('backend="autotune" needs block_shape and '
                             "dtype (the tuning-DB key)")
        from .autotune import lookup_measured, measured_links
        rec = lookup_measured(dev_key, dims, axis_names,
                              tuple(block_shape), dtype, variant, db=db)
        parts = None
        if rec is not None:
            w = rec["winner"]
            rec_order = round_order if round_order is not None else \
                (tuple(w["round_order"]) if w.get("round_order") is not None
                 else None)
            rec_chunks = n_chunks or int(w.get("n_chunks", 0))
            rec_links = links
            if rec_links is None:
                rec_links = measured_links(rec)
            try:
                parts = build(w["backend"], rec_order, rec_chunks,
                              rec_links)
                tuned_from = "measured"
                measured = {"median_us": w.get("median_us"),
                            "table": rec.get("table", []),
                            "best_factorization":
                                rec.get("best_factorization"),
                            "db_path": str(db.path)}
            except ValueError as e:
                from .autotune import demote_hit_to_miss
                demote_hit_to_miss()   # telemetry: this plan is model-built
                warnings.warn(f"tuning-DB record unusable for this plan "
                              f"({e}); falling back to the cost model")
        if parts is None:   # DB miss (or unusable record): analytic choice,
            tuned_from = "model"   # never a blocking measurement
            parts = build("tuned", round_order, n_chunks, links)
    else:
        parts = build(backend, round_order, n_chunks, links)

    resolved, order, rev_order, n, link_models, sched = parts
    plan = A2APlan(fact, requested_backend=backend, backend=resolved,
                   variant=variant, order=order, rev_order=rev_order,
                   n_chunks=n, block_shape=None if block_shape is None
                   else tuple(block_shape), dtype=dtype, links=link_models,
                   schedule=sched, mesh=mesh, tuned_from=tuned_from,
                   measured=measured)
    return _registry_store(key, plan)


# ---------------------------------------------------------------------------
# Pencil-transpose plans (distributed-FFT re-shard)
# ---------------------------------------------------------------------------


class TransposePlan:
    """A resolved, reusable pencil↔pencil transpose plan.

    Construct via :meth:`TorusComm.transpose` (or :func:`plan_transpose`);
    never directly.  The global transpose of a pencil-decomposed FFT
    (``workloads.fft``) is an all-to-all of *uniform contiguous* chunks:
    the local pencil ``in_shape`` is split into ``p`` chunks along
    ``split_axis`` (chunk ``t`` -> torus rank ``t``) and the received
    chunks are concatenated source-major along ``concat_axis`` — the
    tiled collective semantics.  The plan composes the block-shape
    metadata for that re-shard with an inner dense :class:`A2APlan` over
    the same torus whose per-peer block is one chunk, so the transpose
    resolves through any dense backend — ``direct`` / ``factorized`` /
    ``pipelined`` / ``overlap`` / ``tuned`` / ``autotune`` — and shares
    the registry, cost model, tuning DB, and telemetry machinery.

    Correctness oracle: ``core.simulator.simulate_pencil_transpose``.
    """

    kind = "transpose"

    def __init__(self, inner: A2APlan, *, in_shape: tuple[int, ...],
                 split_axis: int, concat_axis: int, parent=None):
        self.inner = inner
        self.in_shape = tuple(in_shape)
        self.split_axis = int(split_axis)
        self.concat_axis = int(concat_axis)
        out = list(self.in_shape)
        out[self.split_axis] //= inner.p
        out[self.concat_axis] *= inner.p
        self.out_shape = tuple(out)
        self.parent = parent
        self._from_cache = False
        self._fetches = 1
        self._host_fns: dict[Mesh, object] = {}
        self._step_fns: dict[Mesh, tuple] = {}

    # -- identity ----------------------------------------------------------

    @property
    def fact(self):
        return self.inner.fact

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.inner.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.inner.dims

    @property
    def p(self) -> int:
        return self.inner.p

    @property
    def d(self) -> int:
        return self.inner.d

    @property
    def variant(self) -> str:
        return self.inner.variant

    @property
    def backend(self) -> str:
        return self.inner.backend

    @property
    def dtype(self):
        return self.inner.dtype

    @property
    def block_shape(self) -> tuple[int, ...]:
        """One per-peer chunk: ``in_shape`` with ``split_axis`` divided by
        ``p`` — the inner dense plan's block."""
        return self.inner.block_shape

    @property
    def block_bytes(self) -> int | None:
        return self.inner.block_bytes

    @property
    def pencil_bytes(self) -> int | None:
        bb = self.inner.block_bytes
        return None if bb is None else bb * self.p

    # -- execution surface (inside shard_map) ------------------------------

    def apply(self, x):
        """The forward re-shard: ``x`` is this device's ``in_shape``
        pencil; returns its ``out_shape`` pencil (``split_axis`` sharded,
        ``concat_axis`` gathered).  Runs inside ``jax.shard_map`` over
        the torus axes."""
        if x.shape != self.in_shape:
            raise ValueError(f"pencil shape {x.shape} != plan in_shape "
                             f"{self.in_shape}")
        return self.inner.tiled(x, self.split_axis, self.concat_axis)

    def inverse_apply(self, y):
        """The exact inverse re-shard (the tiled collective with split and
        concat swapped, rounds in the drain order): bit-identical
        round-trip with :meth:`apply` for any backend."""
        if y.shape != self.out_shape:
            raise ValueError(f"pencil shape {y.shape} != plan out_shape "
                             f"{self.out_shape}")
        return self.inner.tiled(y, self.concat_axis, self.split_axis,
                                reverse=True)

    # -- host-level convenience -------------------------------------------

    def specs(self) -> tuple[P, P]:
        """Default global PartitionSpecs for :meth:`host_fn`: the
        distributed pencil axis (``concat_axis`` in, ``split_axis`` out)
        sharded over the plan's torus axes, everything else replicated.
        Only complete when the plan spans *all* mesh axes (the slab /
        full-torus transpose); sub-group transposes must pass specs that
        also shard the other pencil axes."""
        nd = len(self.in_shape)
        axes = tuple(reversed(self.axis_names))
        in_spec = [None] * nd
        in_spec[self.concat_axis] = axes
        out_spec = [None] * nd
        out_spec[self.split_axis] = axes
        return P(*in_spec), P(*out_spec)

    def host_fn(self, mesh: Mesh | None = None, *, in_spec: P | None = None,
                out_spec: P | None = None):
        """Jitted transpose over the *stage-global* array (the full
        logical array at this FFT stage, sharded per ``in_spec``);
        returns it re-sharded per ``out_spec``.  Defaults to
        :meth:`specs`.  Like ``A2APlan.host_fn`` the callable is
        tracer-aware: tracing off dispatches one fused jit; tracing on
        runs the stepped per-round path (factorized backend) so every
        dimension-wise round gets a measured span and a drift
        observation."""
        mesh = self.inner._mesh if mesh is None else mesh
        if mesh is None:
            raise ValueError("plan was built without a Mesh; pass one")
        d_in, d_out = self.specs()
        in_spec = d_in if in_spec is None else in_spec
        out_spec = d_out if out_spec is None else out_spec
        fkey = (mesh, in_spec, out_spec)
        if fkey not in self._host_fns:
            import jax
            self._host_fns[fkey] = jax.jit(jax.shard_map(
                self.apply, mesh=mesh, in_specs=in_spec,
                out_specs=out_spec))
        fast = self._host_fns[fkey]
        tr = telemetry.get_tracer()

        def run(x):
            if not tr.enabled:
                return fast(x)
            return self._traced_execute(tr, mesh, fast, x, in_spec,
                                        out_spec)

        return run

    # -- telemetry-traced execution ----------------------------------------

    def _drift_key(self) -> str:
        dims = "x".join(str(s) for s in self.dims)
        shape = "x".join(str(s) for s in self.in_shape)
        return (f"transpose[{','.join(self.axis_names)}]{dims}"
                f":{self.backend}:{shape}:{self.split_axis}"
                f"->{self.concat_axis}")

    def _stepped_fns(self, mesh, in_spec, out_spec):
        """Pre/post jitted re-layout fns bracketing the inner plan's
        per-round host fns: pencil -> harness block form ``(p, p,
        *block)`` -> rounds -> pencil.  Valid when the plan spans all
        mesh axes (the default-spec harness form)."""
        fkey = (mesh, in_spec, out_spec)
        if fkey not in self._step_fns:
            import jax
            import jax.numpy as _jnp
            p, s, c = self.p, self.split_axis, self.concat_axis
            block_spec = P(tuple(reversed(self.axis_names)))

            def pre(xl):
                sh = xl.shape
                xb = xl.reshape(sh[:s] + (p, sh[s] // p) + sh[s + 1:])
                return _jnp.moveaxis(xb, s, 0)[None]

            def post(yl):
                y = _jnp.moveaxis(yl[0], 0, c)
                sh = y.shape
                return y.reshape(sh[:c] + (sh[c] * sh[c + 1],)
                                 + sh[c + 2:])

            self._step_fns[fkey] = (
                jax.jit(jax.shard_map(pre, mesh=mesh, in_specs=in_spec,
                                      out_specs=block_spec)),
                jax.jit(jax.shard_map(post, mesh=mesh,
                                      in_specs=block_spec,
                                      out_specs=out_spec)))
        return self._step_fns[fkey]

    def _traced_execute(self, tr, mesh, fast, x, in_spec, out_spec):
        import jax
        det = telemetry.drift_detector()
        key = self._drift_key()
        preds = self.inner._per_axis_predictions()
        sched = self.inner.schedule
        predicted = sched.predicted_seconds if sched is not None \
            else (sum(preds.values()) if preds else None)
        telemetry.metrics().counter("plan.traced_executions").inc()
        stepped = (self.backend == "factorized"
                   and set(self.axis_names) == set(mesh.axis_names))
        with tr.span("plan.execute", cat="plan", kind="transpose",
                     backend=self.backend,
                     axes=",".join(self.axis_names),
                     dims="x".join(str(n) for n in self.dims),
                     pencil="x".join(str(n) for n in self.in_shape),
                     predicted_seconds=predicted,
                     tuned_from=self.inner.tuned_from,
                     drift_key=key) as ex:
            t0 = time.perf_counter()
            if stepped:
                pre, post = self._stepped_fns(mesh, in_spec, out_spec)
                y = jax.block_until_ready(pre(x))
                for k, name, Dk, fn in self.inner._round_host_fns(mesh):
                    pred_k = None if preds is None else preds.get(name)
                    with tr.span("plan.round", cat="plan", axis=name,
                                 round=k, dim=Dk,
                                 predicted_seconds=pred_k):
                        tr0 = time.perf_counter()
                        y = jax.block_until_ready(fn(y))
                        if pred_k:
                            det.observe(f"{key}:axis={name}", pred_k,
                                        time.perf_counter() - tr0)
                y = jax.block_until_ready(post(y))
            else:
                with tr.span("plan.round", cat="plan", axis="*",
                             backend=self.backend, timing="fused",
                             predicted_seconds=predicted):
                    y = jax.block_until_ready(fast(x))
            measured = time.perf_counter() - t0
            ratio = det.observe(key, predicted, measured) \
                if predicted else None
            ex.set(measured_seconds=measured, drift_ratio=ratio)
        return y

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the resolved plan."""
        inner = self.inner.describe()
        return {
            "kind": "transpose",
            "axis_names": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "backend": self.backend,
            "requested_backend": self.inner.requested_backend,
            "variant": self.variant,
            "in_shape": list(self.in_shape),
            "out_shape": list(self.out_shape),
            "split_axis": self.split_axis,
            "concat_axis": self.concat_axis,
            "block_shape": None if self.block_shape is None
            else list(self.block_shape),
            "dtype": inner["dtype"],
            "pencil_bytes": self.pencil_bytes,
            "block_bytes": self.block_bytes,
            "predicted_seconds": inner["predicted_seconds"],
            "tuned_from": self.inner.tuned_from,
            "parent": None if self.parent is None else list(self.parent),
            "drift_ratio": telemetry.drift_detector()
            .drift_ratio(self._drift_key()),
            "cache": "hit" if self._from_cache else "miss",
        }

    def __repr__(self):
        return (f"TransposePlan(dims={self.dims}, axes={self.axis_names}, "
                f"in_shape={self.in_shape}, split={self.split_axis}, "
                f"concat={self.concat_axis}, backend={self.backend!r})")


def plan_transpose(mesh_or_axis_dims, axis_names, local_shape, dtype, *,
                   split_axis: int, concat_axis: int,
                   backend: str = "tuned", variant: str = "natural",
                   round_order=None, reverse_round_order=None,
                   n_chunks: int = 0, max_chunks: int = 8, links=None,
                   db=None) -> TransposePlan:
    """Build (or fetch) a :class:`TransposePlan` — thin delegator to
    ``torus_comm(...).transpose(...)``, mirroring :func:`plan_all_to_all`."""
    from .comm import torus_comm
    return torus_comm(mesh_or_axis_dims, axis_names,
                      variant=variant).transpose(
        local_shape, dtype, split_axis=split_axis, concat_axis=concat_axis,
        backend=backend, round_order=round_order,
        reverse_round_order=reverse_round_order, n_chunks=n_chunks,
        max_chunks=max_chunks, links=links, db=db)


def _build_transpose_plan(mesh_or_axis_dims, axis_names, local_shape, dtype,
                          *, split_axis: int, concat_axis: int,
                          backend: str = "tuned", variant: str = "natural",
                          round_order=None, reverse_round_order=None,
                          n_chunks: int = 0, max_chunks: int = 8,
                          links=None, db=None,
                          parent=None) -> TransposePlan:
    """Resolution + registry for pencil-transpose plans: validate the
    re-shard geometry, resolve the inner dense plan over the per-peer
    chunk (any backend, including the tuning DB), and key the composite
    off the inner's registry key so autotune DB-generation invalidation
    propagates for free."""
    local_shape = tuple(int(n) for n in local_shape)
    nd = len(local_shape)
    if not 0 <= split_axis < nd or not 0 <= concat_axis < nd:
        raise ValueError(f"split/concat axes ({split_axis}, {concat_axis}) "
                         f"outside pencil rank {nd}")
    if split_axis == concat_axis:
        raise ValueError("split_axis and concat_axis must differ")
    axis_names = _as_tuple(axis_names)
    if isinstance(mesh_or_axis_dims, Mesh):
        dims = get_factorization(mesh_or_axis_dims, axis_names,
                                 variant=variant).dims
    else:
        dims = tuple(int(s) for s in mesh_or_axis_dims)
    p = math.prod(dims)
    if local_shape[split_axis] % p:
        raise ValueError(f"split axis size {local_shape[split_axis]} not "
                         f"divisible by p={p} (dims {dims})")
    block_shape = list(local_shape)
    block_shape[split_axis] //= p
    inner = _build_dense_plan(
        mesh_or_axis_dims, axis_names, tuple(block_shape), dtype,
        backend=backend, variant=variant, round_order=round_order,
        reverse_round_order=reverse_round_order, n_chunks=n_chunks,
        max_chunks=max_chunks, links=links, db=db)
    key = ("transpose", inner._registry_key, local_shape, int(split_axis),
           int(concat_axis), parent)
    cached = _registry_fetch(key)
    if cached is not None:
        return cached
    plan = TransposePlan(inner, in_shape=local_shape,
                         split_axis=split_axis, concat_axis=concat_axis,
                         parent=parent)
    return _registry_store(key, plan)


# ---------------------------------------------------------------------------
# Ragged (MPI_Alltoallv) plans
# ---------------------------------------------------------------------------


class RaggedA2APlan:
    """A resolved, reusable ragged all-to-all (Alltoallv) plan.

    Construct via :func:`plan_ragged_all_to_all`; never directly.  The
    plan composes two dense :class:`A2APlan` resolutions over the same
    torus — the tiny int32 *counts* plan and the bucket-padded *data*
    plan — plus the bucket itself (the power-of-two row bound that keeps
    every dimension-wise round fixed-shape and jit-stable; see
    ``core.ragged``).  Like dense plans it is a static Python object,
    cached in the same LRU registry, free to close over inside
    ``shard_map``/``jit``.
    """

    def __init__(self, data: A2APlan, counts: A2APlan, *, max_count: int,
                 avg_count: float, row_shape: tuple[int, ...], dtype,
                 predicted_seconds: float | None):
        self.data = data
        self.counts_plan = counts
        self.max_count = max_count
        self.avg_count = avg_count
        self.row_shape = row_shape
        self.dtype = dtype
        self.predicted_seconds = predicted_seconds
        self._from_cache = False
        self._fetches = 1
        self._host_fns: dict[Mesh, object] = {}
        self._counts_fns: dict[Mesh, object] = {}

    # -- identity ----------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.data.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.data.dims

    @property
    def p(self) -> int:
        return self.data.p

    @property
    def d(self) -> int:
        return self.data.d

    @property
    def bucket(self) -> int:
        return self.data.block_shape[0]

    @property
    def backend(self) -> str:
        return self.data.backend

    @property
    def variant(self) -> str:
        return self.data.variant

    @property
    def n_chunks(self) -> int:
        return self.data.n_chunks

    @property
    def tuned_from(self) -> str | None:
        return self.data.tuned_from

    @property
    def row_bytes(self) -> int:
        return math.prod(self.row_shape) * jnp.dtype(self.dtype).itemsize

    @property
    def expected_occupancy(self) -> float:
        return float(self.avg_count) / float(self.bucket)

    # -- execution surface (inside shard_map) ------------------------------

    def counts_matrix(self, send_counts):
        """The counts phase alone: ``(p,)`` int32 send counts -> the full
        ``(p, p)`` matrix, identical on every device."""
        from .ragged import _counts_matrix_impl
        return _counts_matrix_impl(send_counts, self.counts_plan)

    def forward(self, x, send_counts):
        """Bucketed ragged all-to-all: ``x`` is ``(p, m, *row)`` with
        ``m <= bucket``, block ``i``'s rows destined for torus rank ``i``;
        returns ``(recv, recv_counts)`` — ``recv[i]`` the ``(bucket,
        *row)`` window received from rank ``i``."""
        from .ragged import _bucketed_impl
        return _bucketed_impl(x, send_counts, data_plan=self.data,
                              counts_plan=self.counts_plan,
                              axis_names=self.axis_names)

    def reverse(self, x, send_counts):
        """The combine-direction bucketed exchange (drain round order);
        ``send_counts`` is typically the ``recv_counts`` of the matching
        ``forward``."""
        from .ragged import _bucketed_impl
        return _bucketed_impl(x, send_counts, data_plan=self.data,
                              counts_plan=self.counts_plan,
                              axis_names=self.axis_names, reverse=True)

    def occupancy(self, send_counts):
        """Measured occupancy of one call (traced scalar): useful rows
        over ``p * bucket`` padded rows."""
        from .ragged import bucket_occupancy
        return bucket_occupancy(send_counts, self.bucket)

    # -- host-level paths --------------------------------------------------

    def exact(self, rows):
        """The exact two-phase host/debug path (``core.ragged
        .exact_alltoallv``): global nested ``rows[s][d]`` arrays in, exact
        per-pair arrays out — no bucket, no padding.  Runs the plan's
        forward round order over the active dimensions."""
        from .ragged import exact_alltoallv
        active = [i for i, Dk in enumerate(self.dims) if Dk > 1]
        trivial = [i for i, Dk in enumerate(self.dims) if Dk == 1]
        full_order = [active[k] for k in self.data.order] + trivial
        return exact_alltoallv(rows, self.dims, round_order=full_order)

    def host_fn(self, mesh: Mesh | None = None):
        """Jitted host-level ragged all-to-all over global ``(p, p,
        bucket, *row)`` data and ``(p, p)`` int32 counts operands
        (``x[r, i]`` = rank r's bucket window for rank i); returns the
        exchanged windows plus per-rank recv counts.

        With the telemetry tracer enabled the two phases split at host
        level — a measured ``ragged.counts`` span around the tiny int32
        exchange, then the data rounds through the dense plan's traced
        path (per-round spans for the factorized backend) — bit-exact
        with the fused jit, which still serves the disabled path."""
        mesh = self.data._mesh if mesh is None else mesh
        if mesh is None:
            raise ValueError("plan was built without a Mesh; pass one")
        if mesh not in self._host_fns:
            import jax
            axes = tuple(reversed(self.axis_names))
            x_spec = P(axes)
            c_spec = P(axes)

            def local(x, c):    # x: (1, p, bucket, *row); c: (1, p)
                recv, rc = self.forward(x[0], c[0])
                return recv[None], rc[None]

            self._host_fns[mesh] = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=(x_spec, c_spec),
                out_specs=(x_spec, c_spec)))
        fast = self._host_fns[mesh]

        tr = telemetry.get_tracer()   # stable singleton; bind once

        def run(x, c):
            if not tr.enabled:
                return fast(x, c)
            return self._traced_execute(tr, mesh, x, c)

        return run

    # -- telemetry-traced execution ----------------------------------------

    def _drift_key(self) -> str:
        dims = "x".join(str(s) for s in self.dims)
        return (f"ragged[{','.join(self.axis_names)}]{dims}"
                f":{self.backend}:b{self.bucket}")

    def _counts_host_fn(self, mesh):
        """Jitted counts phase alone: global ``(p, p)`` send counts ->
        global ``(p, p)`` per-rank recv counts."""
        if mesh not in self._counts_fns:
            import jax
            from .ragged import (_counts_matrix_impl,
                                 _recv_counts_from_matrix)
            spec = P(tuple(reversed(self.axis_names)))

            def local(c):       # c: (1, p) per device
                matrix = _counts_matrix_impl(c[0], self.counts_plan)
                return _recv_counts_from_matrix(
                    matrix, self.axis_names)[None]

            self._counts_fns[mesh] = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=spec, out_specs=spec))
        return self._counts_fns[mesh]

    def _traced_execute(self, tr, mesh, x, c):
        import jax
        det = telemetry.drift_detector()
        key = self._drift_key()
        with tr.span("plan.execute", cat="plan", kind="ragged",
                     backend=self.backend,
                     axes=",".join(self.axis_names),
                     dims="x".join(str(s) for s in self.dims),
                     bucket=self.bucket,
                     predicted_seconds=self.predicted_seconds,
                     tuned_from=self.tuned_from, drift_key=key) as ex:
            t0 = time.perf_counter()
            counts_sched = self.counts_plan.schedule
            with tr.span("ragged.counts", cat="plan",
                         backend=self.counts_plan.backend,
                         block_bytes=self.counts_plan.block_bytes,
                         predicted_seconds=None if counts_sched is None
                         else counts_sched.predicted_seconds):
                rc = jax.block_until_ready(self._counts_host_fn(mesh)(c))
            self.data.host_fn(mesh)           # ensure the fused jit exists
            recv = self.data._traced_execute(
                tr, mesh, self.data._host_fns[mesh], x)
            measured = time.perf_counter() - t0
            ratio = det.observe(key, self.predicted_seconds, measured) \
                if self.predicted_seconds else None
            ex.set(measured_seconds=measured, drift_ratio=ratio)
        return recv, rc

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the resolved ragged plan.

        ``expected_occupancy`` is the plan-time estimate ``avg_count /
        bucket`` — the useful fraction of the bucketed data phase's
        traffic (1.0 means no padding waste); per-call measured occupancy
        comes from :meth:`occupancy`.  ``tuned_from`` is the data plan's
        provenance ("measured" under a tuning-DB hit, "model" for the
        analytic choice, None for an explicit backend).
        """
        return {
            "kind": "ragged",
            "axis_names": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "backend": self.backend,
            "requested_backend": self.data.requested_backend,
            "variant": self.variant,
            "round_order": list(self.data.order),
            "reverse_round_order": list(self.data.rev_order),
            "n_chunks": self.n_chunks,
            "row_shape": list(self.row_shape),
            "dtype": jnp.dtype(self.dtype).name,
            "row_bytes": self.row_bytes,
            "max_count": self.max_count,
            "avg_count": self.avg_count,
            "bucket": self.bucket,
            "bucket_block_bytes": self.data.block_bytes,
            "expected_occupancy": self.expected_occupancy,
            "counts_backend": self.counts_plan.backend,
            "counts_block_bytes": self.counts_plan.block_bytes,
            "predicted_seconds": self.predicted_seconds,
            "blocks_sent_per_device": self.data.fact
            .blocks_sent_per_device(),
            "links": [{"alpha": l.alpha, "bandwidth": l.bandwidth}
                      for l in self.data.links],
            "tuned_from": self.tuned_from,
            "measured": self.data.measured,
            "drift_ratio": telemetry.drift_detector()
            .drift_ratio(self._drift_key()),
            "cache": "hit" if self._from_cache else "miss",
        }

    def __repr__(self):
        return (f"RaggedA2APlan(dims={self.dims}, axes={self.axis_names}, "
                f"backend={self.backend!r}, bucket={self.bucket}, "
                f"max_count={self.max_count})")


def plan_ragged_all_to_all(mesh_or_axis_dims, axis_names, row_shape=(),
                           dtype="float32", *, max_count: int,
                           avg_count: float | None = None,
                           backend: str = "tuned", variant: str = "natural",
                           round_order=None, reverse_round_order=None,
                           n_chunks: int = 0, max_chunks: int = 8,
                           links=None, compute_seconds: float = 0.0,
                           db=None) -> RaggedA2APlan:
    """Build (or fetch from the LRU registry) a :class:`RaggedA2APlan`.

    Like :func:`plan_all_to_all`, a thin delegator since the ``TorusComm``
    redesign: it builds or reuses the implicit communicator and delegates
    to ``comm.ragged_all_to_all`` — new code should construct through a
    :class:`~repro.core.comm.TorusComm` directly.

    Args mirror :func:`plan_all_to_all` with the ragged additions:

      row_shape, dtype: shape/dtype of ONE ragged row (the unit the
        per-pair counts count); ``()`` means scalar rows.
      max_count: static upper bound on any single ``send_counts`` entry —
        the jit-stability contract.  The bucket is its power-of-two
        round-up, so every dimension-wise exchange has a fixed shape.
      avg_count: expected mean per-pair count, for the plan's
        ``expected_occupancy`` estimate and the tuner's ragged cost term
        (default: ``max_count``, i.e. occupancy = max_count/bucket).
      backend: resolves the *data* plan (padded blocks of ``(bucket,
        *row_shape)``) exactly like the dense API — "tuned" prices
        candidates at the padded size (``tuning.choose_ragged_algorithm``
        semantics), "autotune" replays the measured winner recorded for
        the padded block shape.  The counts plan is always resolved as
        "tuned" over its ``(p,)`` int32 block.
    """
    from .comm import torus_comm
    return torus_comm(mesh_or_axis_dims, axis_names,
                      variant=variant).ragged_all_to_all(
        row_shape, dtype, max_count=max_count, avg_count=avg_count,
        backend=backend, round_order=round_order,
        reverse_round_order=reverse_round_order, n_chunks=n_chunks,
        max_chunks=max_chunks, links=links,
        compute_seconds=compute_seconds, db=db)


def _build_ragged_plan(mesh_or_axis_dims, axis_names, row_shape=(),
                       dtype="float32", *, max_count: int,
                       avg_count: float | None = None,
                       backend: str = "tuned", variant: str = "natural",
                       round_order=None, reverse_round_order=None,
                       n_chunks: int = 0, max_chunks: int = 8,
                       links=None, compute_seconds: float = 0.0,
                       db=None) -> RaggedA2APlan:
    """The resolution machinery behind ``TorusComm.ragged_all_to_all``
    (and the :func:`plan_ragged_all_to_all` delegator): the bucket, the
    nested dense data/counts plans, and the shared LRU registry."""
    axis_names = _as_tuple(axis_names)
    if isinstance(mesh_or_axis_dims, Mesh):
        dims = tuple(mesh_or_axis_dims.shape[n] for n in axis_names)
        dev_key = device_fingerprint(mesh_or_axis_dims)
    else:
        dims = tuple(int(s) for s in mesh_or_axis_dims)
        if len(dims) != len(axis_names):
            raise ValueError(f"{len(dims)} dims for {len(axis_names)} axes")
        dev_key = None
    from .ragged import next_pow2
    max_count = int(max_count)
    # Power-of-two bucket: any static bound keeps the rounds fixed-shape,
    # but snapping to pow2 bounds the set of distinct compiled shapes (and
    # plan-cache entries) across workloads whose max_count drifts — the
    # padding it adds beyond max_count is reported in expected_occupancy.
    bucket = next_pow2(max_count)
    avg = float(max_count if avg_count is None else avg_count)
    if not 0.0 < avg <= bucket:
        raise ValueError(f"avg_count {avg} outside (0, bucket={bucket}]")
    row_shape = tuple(int(s) for s in row_shape)
    p = math.prod(dims)

    links_key = None if links is None else resolve_links(links, dims)
    key = ("ragged", dev_key, dims, axis_names, row_shape,
           jnp.dtype(dtype).name, max_count, avg, backend, variant,
           None if round_order is None else tuple(round_order),
           None if reverse_round_order is None
           else tuple(reverse_round_order),
           int(n_chunks), int(max_chunks), links_key,
           float(compute_seconds))
    if backend == "autotune":
        from .autotune import get_default_db
        db = db if db is not None else get_default_db()
        key = key + (db.path_key, db.generation())
    cached = _registry_fetch(key)
    if cached is not None:
        return cached

    data = _build_dense_plan(mesh_or_axis_dims, axis_names,
                             (bucket,) + row_shape, dtype, backend=backend,
                             variant=variant, round_order=round_order,
                             reverse_round_order=reverse_round_order,
                             n_chunks=n_chunks, max_chunks=max_chunks,
                             links=links, compute_seconds=compute_seconds,
                             db=db)
    counts = _build_dense_plan(mesh_or_axis_dims, axis_names, (p,),
                               jnp.int32, backend="tuned", variant=variant,
                               round_order=round_order,
                               reverse_round_order=reverse_round_order,
                               max_chunks=1, links=links)
    predicted = None
    if data.schedule is not None and counts.schedule is not None:
        predicted = data.schedule.predicted_seconds \
            + counts.schedule.predicted_seconds
    plan = RaggedA2APlan(data, counts, max_count=max_count, avg_count=avg,
                         row_shape=row_shape, dtype=dtype,
                         predicted_seconds=predicted)
    return _registry_store(key, plan)


# ---------------------------------------------------------------------------
# Sparse neighborhood (message-combining) Alltoallv plans
# ---------------------------------------------------------------------------


class SparseA2APlan:
    """A resolved, reusable sparse-neighborhood Alltoallv plan.

    Construct via :func:`plan_sparse_all_to_all` (or
    ``TorusComm.sparse_all_to_all``); never directly.  The sparse family
    (``core.sparse``) keeps the ragged subsystem's counts phase and
    bucket contract but replaces the dense data rounds with
    message-combined, *skippable* per-peer lanes: each dimension-wise
    round decomposes into its ``D[k] - 1`` peer exchanges, and a lane
    whose combined payload is empty — determined from the replicated
    counts matrix against the plan-time ``round_message_masks`` — is
    skipped identically on every device (SPMD-safe ``lax.cond``).

    The execution surface duck-types :class:`RaggedA2APlan`'s
    ``forward``/``reverse`` (``(x, send_counts) -> (recv, recv_counts)``)
    so callers like the dropless MoE path can swap plans without code
    changes; the window contract is relaxed — rows beyond
    ``recv_counts[i]`` are unspecified (see ``core.sparse``).
    """

    def __init__(self, fact: TorusFactorization, counts: A2APlan, *,
                 max_count: int, avg_count: float, expected_density: float,
                 row_shape: tuple[int, ...], dtype, order: tuple[int, ...],
                 rev_order: tuple[int, ...], masks_fwd, masks_rev,
                 links: tuple[LinkModel, ...],
                 predicted_seconds: float | None, mesh: Mesh | None):
        self.fact = fact
        self.counts_plan = counts
        self.max_count = max_count
        self.avg_count = avg_count
        self.expected_density = expected_density
        self.row_shape = row_shape
        self.dtype = dtype
        self.order = order
        self.rev_order = rev_order
        self._masks_fwd = masks_fwd
        self._masks_rev = masks_rev
        self.links = links
        self.predicted_seconds = predicted_seconds
        # Traffic stats of the last host-side analyze()/exact() call
        # (density, skipped/combined messages, skipped rounds) — the jit
        # path never materializes them; None until first analysis.
        self.last_stats: dict | None = None
        self._mesh = mesh
        self._from_cache = False
        self._fetches = 1
        self._host_fns: dict[Mesh, object] = {}

    # -- identity ----------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.fact.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.fact.dims

    @property
    def p(self) -> int:
        return self.fact.p

    @property
    def d(self) -> int:
        return self.fact.d

    @property
    def variant(self) -> str:
        return self.fact.variant

    @property
    def backend(self) -> str:
        return "sparse"

    @property
    def bucket(self) -> int:
        from .ragged import next_pow2
        return next_pow2(self.max_count)

    @property
    def round_order(self) -> tuple[int, ...]:
        return self.order

    @property
    def reverse_round_order(self) -> tuple[int, ...]:
        return self.rev_order

    @property
    def row_bytes(self) -> int:
        return math.prod(self.row_shape) * jnp.dtype(self.dtype).itemsize

    @property
    def expected_occupancy(self) -> float:
        return float(self.avg_count) / float(self.bucket)

    # -- execution surface (inside shard_map) ------------------------------

    def counts_matrix(self, send_counts):
        """The counts phase alone: ``(p,)`` int32 send counts -> the full
        ``(p, p)`` matrix, identical on every device."""
        from .ragged import _counts_matrix_impl
        return _counts_matrix_impl(send_counts, self.counts_plan)

    def forward(self, x, send_counts):
        """Bucketed sparse all-to-all: same signature and return
        convention as :meth:`RaggedA2APlan.forward`, with empty per-peer
        lanes skipped; rows beyond ``recv_counts[i]`` are unspecified."""
        from .sparse import _sparse_bucketed_impl
        return _sparse_bucketed_impl(x, send_counts, plan=self)

    def reverse(self, x, send_counts):
        """The combine-direction sparse exchange (drain round order)."""
        from .sparse import _sparse_bucketed_impl
        return _sparse_bucketed_impl(x, send_counts, plan=self,
                                     reverse=True)

    def occupancy(self, send_counts):
        """Measured occupancy of one call (traced scalar): useful rows
        over ``p * bucket`` padded rows."""
        from .ragged import bucket_occupancy
        return bucket_occupancy(send_counts, self.bucket)

    # -- host-level paths --------------------------------------------------

    def _full_order(self, order) -> list[int]:
        active = [i for i, Dk in enumerate(self.dims) if Dk > 1]
        trivial = [i for i, Dk in enumerate(self.dims) if Dk == 1]
        return [active[k] for k in order] + trivial

    def analyze(self, counts) -> dict:
        """Host-side traffic analysis of a concrete ``(p, p)`` count
        matrix via the simulator's sparse oracle: density, per-message
        skip accounting, whole skipped rounds.  Caches the result on the
        plan (surfaced by :meth:`describe` and the dry-run artifacts)."""
        from .sparse import sparse_traffic_stats
        self.last_stats = sparse_traffic_stats(
            self.dims, counts, round_order=self._full_order(self.order))
        return self.last_stats

    def exact(self, rows):
        """The exact sparse host/debug path (``core.sparse
        .sparse_exact_alltoallv``): global nested ``rows[s][d]`` arrays
        in, exact per-pair arrays out plus the per-round skip accounting
        (also cached onto :attr:`last_stats`)."""
        from .sparse import sparse_exact_alltoallv
        recv, counts, vol = sparse_exact_alltoallv(
            rows, self.dims, round_order=self._full_order(self.order))
        self.analyze(counts)
        return recv, counts, vol

    def host_fn(self, mesh: Mesh | None = None):
        """Jitted host-level sparse all-to-all over global ``(p, p,
        bucket, *row)`` data and ``(p, p)`` int32 counts operands; the
        benchmark-harness form.  Replication checking is disabled
        (``check_vma=False``): the skip predicates wrap collectives in
        ``lax.cond``, which the older shard_map replication checker
        cannot see through."""
        mesh = self._mesh if mesh is None else mesh
        if mesh is None:
            raise ValueError("plan was built without a Mesh; pass one")
        if mesh not in self._host_fns:
            import jax
            axes = tuple(reversed(self.axis_names))
            x_spec = P(axes)
            c_spec = P(axes)

            def local(x, c):    # x: (1, p, bucket, *row); c: (1, p)
                recv, rc = self.forward(x[0], c[0])
                return recv[None], rc[None]

            self._host_fns[mesh] = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=(x_spec, c_spec),
                out_specs=(x_spec, c_spec), check_vma=False))
        fast = self._host_fns[mesh]

        tr = telemetry.get_tracer()   # stable singleton; bind once

        def run(x, c):
            if not tr.enabled:
                return fast(x, c)
            return self._traced_execute(tr, fast, x, c)

        return run

    # -- telemetry-traced execution ----------------------------------------

    def _drift_key(self) -> str:
        dims = "x".join(str(s) for s in self.dims)
        return (f"sparse[{','.join(self.axis_names)}]{dims}"
                f":b{self.bucket}:rho{self.expected_density}")

    def _traced_execute(self, tr, fast, x, c):
        """One measured execute span around the fused jit — the sparse
        rounds' ``lax.cond``-guarded lanes cannot be stepped at host
        level (the skip predicates live inside the trace), so per-round
        device attribution comes from the ``named_scope`` annotations in
        the profile, not host spans."""
        import jax
        det = telemetry.drift_detector()
        key = self._drift_key()
        with tr.span("plan.execute", cat="plan", kind="sparse",
                     backend="sparse", axes=",".join(self.axis_names),
                     dims="x".join(str(s) for s in self.dims),
                     bucket=self.bucket,
                     expected_density=self.expected_density,
                     predicted_seconds=self.predicted_seconds,
                     drift_key=key, timing="fused") as ex:
            t0 = time.perf_counter()
            out = jax.block_until_ready(fast(x, c))
            measured = time.perf_counter() - t0
            ratio = det.observe(key, self.predicted_seconds, measured) \
                if self.predicted_seconds else None
            ex.set(measured_seconds=measured, drift_ratio=ratio)
        return out

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the resolved sparse plan.

        ``expected_density`` is the plan-time estimate of the non-zero
        fraction of the count matrix (what the tuner priced); ``density``
        / ``skipped_rounds`` / ``combined_messages`` /
        ``skipped_exchanges`` reflect the last host-side
        :meth:`analyze` / :meth:`exact` call (None before one runs).
        """
        stats = self.last_stats or {}
        return {
            "kind": "sparse",
            "axis_names": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "backend": "sparse",
            "requested_backend": "sparse",
            "variant": self.variant,
            "round_order": list(self.order),
            "reverse_round_order": list(self.rev_order),
            "n_chunks": 1,
            "row_shape": list(self.row_shape),
            "dtype": jnp.dtype(self.dtype).name,
            "row_bytes": self.row_bytes,
            "max_count": self.max_count,
            "avg_count": self.avg_count,
            "bucket": self.bucket,
            "expected_occupancy": self.expected_occupancy,
            "expected_density": self.expected_density,
            "density": stats.get("density"),
            "skipped_rounds": stats.get("skipped_rounds"),
            "combined_messages": stats.get("combined_messages"),
            "skipped_exchanges": stats.get("skipped_exchanges"),
            "total_exchanges": stats.get("total_exchanges"),
            "counts_backend": self.counts_plan.backend,
            "counts_block_bytes": self.counts_plan.block_bytes,
            "predicted_seconds": self.predicted_seconds,
            "blocks_sent_per_device": self.fact.blocks_sent_per_device(),
            "links": [{"alpha": l.alpha, "bandwidth": l.bandwidth}
                      for l in self.links],
            "tuned_from": None,
            "measured": None,
            "drift_ratio": telemetry.drift_detector()
            .drift_ratio(self._drift_key()),
            "cache": "hit" if self._from_cache else "miss",
        }

    def __repr__(self):
        return (f"SparseA2APlan(dims={self.dims}, axes={self.axis_names}, "
                f"bucket={self.bucket}, max_count={self.max_count}, "
                f"expected_density={self.expected_density})")


def plan_sparse_all_to_all(mesh_or_axis_dims, axis_names, row_shape=(),
                           dtype="float32", *, max_count: int,
                           avg_count: float | None = None,
                           density: float | None = None,
                           variant: str = "natural", round_order=None,
                           reverse_round_order=None,
                           links=None) -> SparseA2APlan:
    """Build (or fetch from the LRU registry) a :class:`SparseA2APlan`.

    A thin delegator to ``TorusComm.sparse_all_to_all`` (the comm is the
    API root).  Args mirror :func:`plan_ragged_all_to_all` minus the
    backend knobs — the sparse data rounds are one kernel — plus:

      density: expected non-zero fraction of the ``p x p`` count matrix
        (default 1.0 — i.e. price as if dense).  Feeds
        ``tuning.predict_sparse`` and the plan key; must be in (0, 1].
    """
    from .comm import torus_comm
    return torus_comm(mesh_or_axis_dims, axis_names,
                      variant=variant).sparse_all_to_all(
        row_shape, dtype, max_count=max_count, avg_count=avg_count,
        density=density, round_order=round_order,
        reverse_round_order=reverse_round_order, links=links)


def _build_sparse_plan(mesh_or_axis_dims, axis_names, row_shape=(),
                       dtype="float32", *, max_count: int,
                       avg_count: float | None = None,
                       density: float | None = None,
                       variant: str = "natural", round_order=None,
                       reverse_round_order=None,
                       links=None) -> SparseA2APlan:
    """The resolution machinery behind ``TorusComm.sparse_all_to_all``:
    bucket, counts plan, plan-time message masks, and the shared LRU
    registry."""
    axis_names = _as_tuple(axis_names)
    mesh = None
    if isinstance(mesh_or_axis_dims, Mesh):
        mesh = mesh_or_axis_dims
        fact = get_factorization(mesh, axis_names, variant=variant)
        dims = fact.dims
        dev_key = device_fingerprint(mesh)
    else:
        dims = tuple(int(s) for s in mesh_or_axis_dims)
        if len(dims) != len(axis_names):
            raise ValueError(f"{len(dims)} dims for {len(axis_names)} axes")
        fact = TorusFactorization(axis_names, dims, variant)
        dev_key = None
    if variant not in ("natural", "paper"):
        raise ValueError(f"unknown variant {variant!r}")

    from .ragged import next_pow2
    max_count = int(max_count)
    bucket = next_pow2(max_count)
    avg = float(max_count if avg_count is None else avg_count)
    if not 0.0 < avg <= bucket:
        raise ValueError(f"avg_count {avg} outside (0, bucket={bucket}]")
    rho = float(1.0 if density is None else density)
    if not 0.0 < rho <= 1.0:
        raise ValueError(f"density {rho} outside (0, 1]")
    row_shape = tuple(int(s) for s in row_shape)
    p = math.prod(dims)

    _, active = _skip_trivial(axis_names, dims)
    d_active = len(active)
    order = _check_order(round_order, d_active)
    rev_order = (tuple(reversed(order)) if reverse_round_order is None
                 else _check_order(reverse_round_order, d_active))

    links_key = None if links is None else resolve_links(links, dims)
    key = ("sparse", dev_key, dims, axis_names, row_shape,
           jnp.dtype(dtype).name, max_count, avg, rho, variant, order,
           rev_order, links_key)
    cached = _registry_fetch(key)
    if cached is not None:
        return cached

    # Same counts-plan resolution as the ragged family, so a ragged and a
    # sparse plan over one torus share the registry entry.
    counts = _build_dense_plan(mesh_or_axis_dims, axis_names, (p,),
                               jnp.int32, backend="tuned", variant=variant,
                               round_order=round_order,
                               reverse_round_order=reverse_round_order,
                               max_chunks=1, links=links)

    from .sparse import round_message_masks
    masks_fwd = round_message_masks(active, order)
    masks_rev = masks_fwd if rev_order == order \
        else round_message_masks(active, rev_order)

    from .tuning import predict_sparse
    link_models = resolve_links(links, dims, axis_names)
    row_bytes = math.prod(row_shape) * jnp.dtype(dtype).itemsize
    predicted = predict_sparse(dims, link_models, float(row_bytes), bucket,
                               p, density=rho)

    plan = SparseA2APlan(fact, counts, max_count=max_count, avg_count=avg,
                         expected_density=rho, row_shape=row_shape,
                         dtype=dtype, order=order, rev_order=rev_order,
                         masks_fwd=masks_fwd, masks_rev=masks_rev,
                         links=link_models, predicted_seconds=predicted,
                         mesh=mesh)
    return _registry_store(key, plan)


# ---------------------------------------------------------------------------
# KV-migration (prefill -> decode handoff) plans
# ---------------------------------------------------------------------------


class KVMigrationPlan:
    """A resolved, reusable prefill->decode KV-cache migration plan.

    Construct via :func:`plan_kv_migration` (or
    ``TorusComm.kv_migration``); never directly.  The KV handoff of a
    disaggregated serving topology is an Alltoallv over the *full*
    serving comm whose count matrix is non-zero only in the
    prefill->decode block (rows ``< n_prefill``, columns ``>=
    n_prefill``): per-sequence variable lengths are the send counts and
    the scheduler's placement is the router.  The plan wraps the
    matching exchange machinery — a :class:`RaggedA2APlan` or, in the
    few-migrations-per-tick regime the cost model prices via the block
    density, a :class:`SparseA2APlan` — and adds the block-structure
    validation (:meth:`pair_counts`) so a misplaced sequence fails at
    the datatype layer, not as silent corruption.

    Like every plan it is a static Python object in the shared LRU
    registry; evicting it drops the inner plan (and its nested entries)
    via the same teardown symmetry.
    """

    kind = "kv_migrate"

    def __init__(self, inner, *, requested_backend: str, n_prefill: int,
                 migrations_per_tick: float, expected_density: float,
                 predicted_seconds: float | None, tuned_from: str | None):
        self.inner = inner
        self.requested_backend = requested_backend
        self.n_prefill = int(n_prefill)
        self.migrations_per_tick = float(migrations_per_tick)
        self.expected_density = float(expected_density)
        self.predicted_seconds = predicted_seconds
        self.tuned_from = tuned_from
        # the factorization descriptor, for the registry teardown
        self.fact = inner.fact if hasattr(inner, "fact") else inner.data.fact
        self._from_cache = False
        self._fetches = 1

    # -- identity ----------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.inner.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.inner.dims

    @property
    def p(self) -> int:
        return self.inner.p

    @property
    def d(self) -> int:
        return self.inner.d

    @property
    def n_decode(self) -> int:
        return self.p - self.n_prefill

    @property
    def inner_kind(self) -> str:
        return "sparse" if isinstance(self.inner, SparseA2APlan) \
            else "ragged"

    @property
    def backend(self) -> str:
        return self.inner.backend

    @property
    def variant(self) -> str:
        return self.inner.variant

    @property
    def bucket(self) -> int:
        return self.inner.bucket

    @property
    def max_count(self) -> int:
        return self.inner.max_count

    @property
    def avg_count(self) -> float:
        return self.inner.avg_count

    @property
    def row_shape(self) -> tuple[int, ...]:
        return self.inner.row_shape

    @property
    def dtype(self):
        return self.inner.dtype

    @property
    def row_bytes(self) -> int:
        return self.inner.row_bytes

    @property
    def expected_occupancy(self) -> float:
        return self.inner.expected_occupancy

    # -- the datatype layer ------------------------------------------------

    def pair_counts(self, pairs) -> "np.ndarray":
        """Validate scheduler placements and build the ``(p, p)`` int32
        count matrix: ``pairs`` maps ``(src, dst) -> row count``.  Every
        source must be a prefill rank (``src < n_prefill``), every
        destination a decode rank (``dst >= n_prefill``), and every
        count within the plan's ``max_count`` bound — the jit-stability
        contract of the bucketed exchange."""
        import numpy as np
        counts = np.zeros((self.p, self.p), np.int32)
        for (src, dst), n in pairs.items():
            src, dst, n = int(src), int(dst), int(n)
            if not 0 <= src < self.n_prefill:
                raise ValueError(f"migration source {src} is not a prefill "
                                 f"rank (n_prefill={self.n_prefill})")
            if not self.n_prefill <= dst < self.p:
                raise ValueError(f"migration destination {dst} is not a "
                                 f"decode rank (n_prefill="
                                 f"{self.n_prefill}, p={self.p})")
            if not 0 <= n <= self.max_count:
                raise ValueError(f"migration count {n} for pair "
                                 f"({src}, {dst}) outside [0, max_count="
                                 f"{self.max_count}]")
            counts[src, dst] = n
        return counts

    # -- execution surface -------------------------------------------------

    def forward(self, x, send_counts):
        """Bucketed exchange inside ``shard_map`` — delegates to the
        inner ragged/sparse plan (same signature and window contract)."""
        return self.inner.forward(x, send_counts)

    def reverse(self, x, send_counts):
        return self.inner.reverse(x, send_counts)

    def counts_matrix(self, send_counts):
        return self.inner.counts_matrix(send_counts)

    def occupancy(self, send_counts):
        return self.inner.occupancy(send_counts)

    def exact(self, rows):
        """The exact host path: nested ``rows[s][d]`` in, ``(recv,
        counts)`` out with ``recv[r][s]`` the rows rank ``r`` received
        from ``s`` — the sparse inner plan's volume accounting lands on
        ``inner.last_stats``."""
        out = self.inner.exact(rows)
        if len(out) == 3:            # sparse: (recv, counts, vol)
            recv, counts, _ = out
            return recv, counts
        return out

    def host_fn(self, mesh: Mesh | None = None):
        """Jitted host-level exchange over global ``(p, p, bucket,
        *row)`` data and ``(p, p)`` int32 counts operands — the one
        collective a serving tick executes.  Telemetry spans and drift
        tracking ride the inner ragged/sparse plan's instrumented
        path."""
        return self.inner.host_fn(mesh)

    def _drift_key(self) -> str:
        return self.inner._drift_key()

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the resolved plan —
        ``kind="kv_migrate"`` plus occupancy / ``tuned_from`` like every
        other plan, and the serving-topology fields (``n_prefill`` /
        ``n_decode`` / ``expected_density`` / ``inner_kind``)."""
        return {
            "kind": "kv_migrate",
            "inner_kind": self.inner_kind,
            "axis_names": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "backend": self.backend,
            "requested_backend": self.requested_backend,
            "variant": self.variant,
            "row_shape": list(self.row_shape),
            "dtype": jnp.dtype(self.dtype).name,
            "row_bytes": self.row_bytes,
            "max_count": self.max_count,
            "avg_count": self.avg_count,
            "bucket": self.bucket,
            "expected_occupancy": self.expected_occupancy,
            "n_prefill": self.n_prefill,
            "n_decode": self.n_decode,
            "migrations_per_tick": self.migrations_per_tick,
            "expected_density": self.expected_density,
            "predicted_seconds": self.predicted_seconds,
            "tuned_from": self.tuned_from,
            "drift_ratio": telemetry.drift_detector()
            .drift_ratio(self._drift_key()),
            "cache": "hit" if self._from_cache else "miss",
        }

    def __repr__(self):
        return (f"KVMigrationPlan(dims={self.dims}, "
                f"axes={self.axis_names}, inner={self.inner_kind!r}, "
                f"n_prefill={self.n_prefill}, bucket={self.bucket})")


def plan_kv_migration(mesh_or_axis_dims, axis_names, row_shape=(),
                      dtype="float32", *, max_count: int, n_prefill: int,
                      avg_count: float | None = None,
                      migrations_per_tick: float = 1.0,
                      backend: str = "tuned", variant: str = "natural",
                      round_order=None, reverse_round_order=None,
                      links=None, db=None) -> KVMigrationPlan:
    """Build (or fetch from the LRU registry) a :class:`KVMigrationPlan`.

    A thin delegator to ``TorusComm.kv_migration`` (the comm is the API
    root).  Args mirror :func:`plan_ragged_all_to_all` plus:

      n_prefill: ranks ``0..n_prefill-1`` are the prefill domain, the
        rest the decode domain — the block structure
        :meth:`KVMigrationPlan.pair_counts` enforces.
      migrations_per_tick: expected concurrently migrating sequences per
        serving tick; with ``backend="tuned"`` it sets the count-matrix
        density the cost model prices (``tuning.predict_kv_migration``)
        to pick the ragged vs sparse inner exchange.
      backend: ``"tuned"`` (cost-model choice between the dense-bucketed
        ragged exchange and the sparse-neighborhood one), ``"ragged"`` /
        ``"sparse"`` (explicit inner kind), or any dense data backend
        (``"direct"`` | ``"factorized"`` | ``"overlap"`` |
        ``"pipelined"`` | ``"autotune"`` — an explicit ragged data
        phase).
    """
    from .comm import torus_comm
    return torus_comm(mesh_or_axis_dims, axis_names,
                      variant=variant).kv_migration(
        row_shape, dtype, max_count=max_count, n_prefill=n_prefill,
        avg_count=avg_count, migrations_per_tick=migrations_per_tick,
        backend=backend, round_order=round_order,
        reverse_round_order=reverse_round_order, links=links, db=db)


def _build_kv_plan(mesh_or_axis_dims, axis_names, row_shape=(),
                   dtype="float32", *, max_count: int, n_prefill: int,
                   avg_count: float | None = None,
                   migrations_per_tick: float = 1.0,
                   backend: str = "tuned", variant: str = "natural",
                   round_order=None, reverse_round_order=None,
                   links=None, db=None) -> KVMigrationPlan:
    """The resolution machinery behind ``TorusComm.kv_migration`` (and
    the :func:`plan_kv_migration` delegator): the block-density estimate,
    the ragged-vs-sparse inner choice, and the shared LRU registry."""
    axis_names = _as_tuple(axis_names)
    if isinstance(mesh_or_axis_dims, Mesh):
        dims = tuple(mesh_or_axis_dims.shape[n] for n in axis_names)
        dev_key = device_fingerprint(mesh_or_axis_dims)
    else:
        dims = tuple(int(s) for s in mesh_or_axis_dims)
        if len(dims) != len(axis_names):
            raise ValueError(f"{len(dims)} dims for {len(axis_names)} axes")
        dev_key = None
    p = math.prod(dims)
    n_prefill = int(n_prefill)
    if not 0 < n_prefill < p:
        raise ValueError(f"n_prefill {n_prefill} outside (0, p={p}); a "
                         "disaggregated topology needs at least one rank "
                         "in each domain")
    migrations = float(migrations_per_tick)
    if migrations <= 0:
        raise ValueError(f"migrations_per_tick must be > 0, got "
                         f"{migrations}")
    pairs = min(migrations, float(n_prefill * (p - n_prefill)))
    density = max(pairs, 1.0) / float(p * p)

    from .ragged import next_pow2
    bucket = next_pow2(int(max_count))
    row_shape = tuple(int(s) for s in row_shape)
    links_key = None if links is None else resolve_links(links, dims)
    key = ("kv_migrate", dev_key, dims, axis_names, row_shape,
           jnp.dtype(dtype).name, int(max_count),
           None if avg_count is None else float(avg_count), n_prefill,
           migrations, backend, variant,
           None if round_order is None else tuple(round_order),
           None if reverse_round_order is None
           else tuple(reverse_round_order), links_key)
    cached = _registry_fetch(key)
    if cached is not None:
        return cached

    from .tuning import predict_kv_migration
    link_models = resolve_links(links, dims, axis_names)
    row_bytes = math.prod(row_shape) * jnp.dtype(dtype).itemsize
    sched = predict_kv_migration(dims, link_models, float(row_bytes),
                                 bucket, n_prefill=n_prefill,
                                 migrations_per_tick=migrations)

    inner_kind = backend
    tuned_from = None
    if backend == "tuned":
        inner_kind = "sparse" if sched.kind == "sparse" else "ragged"
        tuned_from = "model"
    if inner_kind == "sparse":
        inner = _build_sparse_plan(
            mesh_or_axis_dims, axis_names, row_shape, dtype,
            max_count=max_count, avg_count=avg_count, density=density,
            variant=variant, round_order=round_order,
            reverse_round_order=reverse_round_order, links=links)
    else:
        # "ragged" resolves the data phase through the cost model; any
        # other name is an explicit dense data backend, passed through.
        data_backend = "tuned" if inner_kind == "ragged" else inner_kind
        inner = _build_ragged_plan(
            mesh_or_axis_dims, axis_names, row_shape, dtype,
            max_count=max_count, avg_count=avg_count,
            backend=data_backend, variant=variant,
            round_order=round_order,
            reverse_round_order=reverse_round_order, links=links, db=db)
        if tuned_from is None:
            tuned_from = inner.tuned_from

    plan = KVMigrationPlan(inner, requested_backend=backend,
                           n_prefill=n_prefill,
                           migrations_per_tick=migrations,
                           expected_density=density,
                           predicted_seconds=sched.predicted_seconds,
                           tuned_from=tuned_from)
    return _registry_store(key, plan)


def free_plans() -> None:
    """Evict every cached plan, running the delete callback on each — so
    composite plans drop their nested entries and the factorization refs
    they pinned are released symmetrically with LRU eviction."""
    while True:
        keys = _PLANS.keys()
        if not keys:
            return
        _drop_plan(keys[0])


def set_plan_cache_capacity(capacity: int) -> None:
    """Bound the plan registry (evicting LRU entries if needed)."""
    _PLANS.set_capacity(capacity)


def plan_cache_stats() -> dict[str, int]:
    out = dict(_PLANS.stats)
    out["size"] = len(_PLANS)
    out["capacity"] = _PLANS.capacity
    return out


def plan_cache_entries() -> list[A2APlan]:
    """Snapshot of the live plans, LRU-oldest first (for logging/artifacts;
    does not touch recency or stats)."""
    return _PLANS.values()


# The plan-cache slice of the unified telemetry snapshot
# (core.telemetry.metrics_snapshot -> "plan_cache.*").
telemetry.register_stats_provider("plan_cache", plan_cache_stats)
