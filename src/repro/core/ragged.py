"""Ragged (MPI_Alltoallv) all-to-all on the factorized torus.

The paper's Algorithm 1 moves block *slots*, never inspecting their
contents — so the dimension-wise decomposition extends unchanged to
non-uniform per-partner volumes (Träff et al.'s isomorphic sparse
collectives).  This module is that extension: the collective family
between ``MPI_Alltoall`` (``core.factorized``) and real applications
whose exchanges are ragged (dropless MoE dispatch, Alltoallv-based FFT
transposes à la Dalcin & Mortensen).

Execution modes (surfaced through ``core.plan.RaggedA2APlan``):

* **counts phase** — before any data moves, every device learns the full
  ``p x p`` count matrix via one *tiny* dense int32 all-to-all through
  the layer's existing ``A2APlan``: each device contributes its send-count
  row as every one of its ``p`` blocks, so block ``i`` of the result is
  rank ``i``'s row — the whole matrix, from one fixed-shape collective.

* **bucketed** (``_bucketed_impl``) — the jit path.  Every block is
  rounded up to a shared power-of-two ``bucket`` of rows, so each of the
  d dimension-wise exchanges stays a *fixed-shape, zero-copy,
  double-buffered* round (the dense plan's kernels, bit-for-bit); shapes
  are jit-stable because the bucket is resolved at plan time from
  ``max_count``, never from traced counts.  The price is padding,
  reported as an *occupancy* statistic (useful rows / bucketed rows) —
  ``tuning.predict_ragged`` prices exactly that trade.

* **exact** (``exact_alltoallv``) — the two-phase host/debug path: phase
  one exchanges counts, phase two runs the d rounds with *true* ragged
  composite messages (variable-length slot payloads concatenated in
  round-datatype order, per-peer displacements derived from the counts
  matrix — ``MPI_Alltoallv`` per round).  No padding, no jit; validated
  slot-for-slot against the ``core.simulator`` oracle.

Data-layout contract for the bucketed mode: the canonical operand packs
each destination's rows at the front of its bucket window
(``x[i, :send_counts[i]]`` valid, remainder zeros).  The rounds transport
whole bucket windows bit-exactly, so callers may use any within-window
layout (MoE keeps expert-strided slots) — ``send_counts`` feeds the
counts phase and occupancy accounting either way.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np
from jax import lax

from .factorized import _as_tuple
from .simulator import rank_to_coords, round_datatype


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1) — the shared bucket size."""
    n = int(n)
    if n < 1:
        raise ValueError(f"bucket bound must be >= 1, got {n}")
    return 1 << (n - 1).bit_length()


def torus_rank(axis_names) -> jnp.ndarray:
    """This device's torus rank (traced int32), fastest digit first —
    usable only inside ``shard_map`` over the named axes."""
    axis_names = _as_tuple(axis_names)
    rank, stride = jnp.int32(0), 1
    for name in axis_names:
        rank = rank + lax.axis_index(name).astype(jnp.int32) * stride
        stride *= lax.axis_size(name)
    return rank


# ---------------------------------------------------------------------------
# Counts phase
# ---------------------------------------------------------------------------


def _counts_matrix_impl(send_counts, counts_plan):
    """One tiny dense all-to-all -> the full (p, p) count matrix.

    ``send_counts``: this device's (p,) int32 row (counts destined to each
    torus rank).  Every one of the ``p`` blocks we contribute is that same
    row, so after the exchange block ``i`` is rank ``i``'s row and the
    stacked result ``M[i, j]`` = elements rank ``i`` sends rank ``j`` —
    identical on every device.
    """
    p = counts_plan.p
    row = jnp.asarray(send_counts, jnp.int32)
    if row.shape != (p,):
        raise ValueError(f"send_counts shape {row.shape} != ({p},)")
    return counts_plan.forward(jnp.broadcast_to(row, (p, p)))


def _recv_counts_from_matrix(matrix, axis_names):
    """Column of the count matrix for this device: ``M[i, r]`` = rows rank
    ``i`` sends here = rows received from rank ``i``."""
    return jnp.take(matrix, torus_rank(axis_names), axis=1)


# ---------------------------------------------------------------------------
# Bucketed execution mode (jit path)
# ---------------------------------------------------------------------------


def _pad_to_bucket(x, bucket: int):
    """Zero-pad the per-block row axis (axis 1) up to the bucket size."""
    m = x.shape[1]
    if m > bucket:
        raise ValueError(f"{m} rows per block exceed the plan bucket "
                         f"{bucket}; rebuild the plan with max_count>={m}")
    if m == bucket:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, bucket - m)
    return jnp.pad(x, pad)


def _bucketed_impl(x, send_counts, *, data_plan, counts_plan, axis_names,
                   reverse: bool = False):
    """Fixed-shape ragged all-to-all: counts phase + bucket-padded rounds.

    Args:
      x: ``(p, m, *row)`` send blocks, ``m <= bucket``; block ``i`` holds
        the rows destined for torus rank ``i`` (``send_counts[i]`` of them
        under the canonical packed layout).
      send_counts: ``(p,)`` int32.
      data_plan / counts_plan: the resolved dense plans (block shapes
        ``(bucket, *row)`` and ``(p,)`` int32 respectively).
      reverse: run the data rounds in the drain order (combine direction).

    Returns ``(recv, recv_counts)``: ``recv[i]`` is the ``(bucket, *row)``
    window received from rank ``i`` (rows beyond ``recv_counts[i]`` are
    the sender's padding), ``recv_counts`` the matching ``(p,)`` int32.
    """
    p = data_plan.p
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != p={p}")
    bucket = data_plan.block_shape[0]
    matrix = _counts_matrix_impl(send_counts, counts_plan)
    recv_counts = _recv_counts_from_matrix(matrix, axis_names)
    padded = _pad_to_bucket(x, bucket)
    run = data_plan.reverse if reverse else data_plan.forward
    return run(padded), recv_counts


def bucket_occupancy(counts, bucket: int):
    """Useful fraction of the bucketed exchange's traffic (traced ok):
    total ragged rows over total padded rows."""
    counts = jnp.asarray(counts)
    return jnp.sum(counts) / (counts.size * bucket)


# ---------------------------------------------------------------------------
# Exact two-phase mode (host/debug path)
# ---------------------------------------------------------------------------


def exact_alltoallv(rows, dims, round_order=None):
    """Exact global Alltoallv over the torus — host/debug path, no padding.

    Args:
      rows: nested list, ``rows[s][d]`` = array-like of shape
        ``(counts[s][d], *row)`` — rank ``s``'s payload for rank ``d``
        (zero-length arrays allowed).
      dims: torus factor per dimension, fastest digit first.
      round_order: optional permutation of ``range(d)``.

    Phase one derives the count matrix (the host analogue of the counts
    collective); phase two runs Algorithm 1's d rounds with true ragged
    messages: in round ``k`` each rank sends peer ``j`` the concatenation
    of the variable-length slots at round-datatype positions
    ``positions + j * extent`` — per-peer counts and displacements
    straight from the evolving count matrix, an ``MPI_Alltoallv`` per
    dimension.  Returns ``(recv, counts)``: ``recv[r][s]`` = the rows rank
    ``r`` received from rank ``s``, and the phase-one count matrix.
    """
    dims = tuple(int(s) for s in dims)
    d = len(dims)
    p = math.prod(dims)
    if len(rows) != p or any(len(per_dst) != p for per_dst in rows):
        raise ValueError(f"rows must be a {p}x{p} nested list")
    order = tuple(round_order) if round_order is not None \
        else tuple(range(d))
    if sorted(order) != list(range(d)):
        raise ValueError(f"round_order {order} is not a permutation "
                         f"of 0..{d - 1}")

    # Phase 1: the count matrix (every rank's send-count row).
    counts = [[int(np.shape(rows[s][t])[0]) for t in range(p)]
              for s in range(p)]

    # Phase 2: d ragged rounds at slot granularity.  buf[r][b] is the
    # payload currently in slot b of rank r's flat buffer; a round moves
    # slots between group members exactly as the dense algorithm does,
    # composing each peer message from its slots' (variable) lengths.
    buf = {r: [np.asarray(rows[r][t]) for t in range(p)] for r in range(p)}
    coords = {r: rank_to_coords(r, dims) for r in range(p)}
    for k in order:
        positions, extent = round_datatype(dims, k)
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        staged = {}
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            for g_r, r in enumerate(members):
                newbuf = [None] * p
                for g_s, s in enumerate(members):
                    # the composite message s -> r: slots positions +
                    # g_r*extent on the sender, landing at positions +
                    # g_s*extent on the receiver (variable per-slot size)
                    for pos in positions:
                        newbuf[pos + g_s * extent] = \
                            buf[s][pos + g_r * extent]
                staged[r] = newbuf
        for r, newbuf in staged.items():
            buf[r] = newbuf

    recv = [[buf[r][s] for s in range(p)] for r in range(p)]
    # Postcondition (the MPI contract): slot s of rank r's recvbuf is
    # exactly what s addressed to r, order preserved.
    for r in range(p):
        for s in range(p):
            if np.shape(recv[r][s])[0] != counts[s][r]:
                raise AssertionError(
                    f"exact alltoallv postcondition violated at "
                    f"recv[{r}][{s}]")
    return recv, counts


def exact_round_message_elements(dims, counts, k: int):
    """Elements of the round-``k`` composite message rank 0 sends each
    peer, from the *initial* count matrix — the per-peer ``scounts`` of
    the first round's Alltoallv (introspection/debug helper)."""
    positions, extent = round_datatype(tuple(dims), k)
    return [sum(counts[0][pos + j * extent] for pos in positions)
            for j in range(dims[k])]


__all__ = [
    "bucket_occupancy",
    "exact_alltoallv",
    "exact_round_message_elements",
    "next_pow2",
    "torus_rank",
]
