"""Communicator factorization and caching — the JAX analogue of Listings 1–2.

The paper amortizes the expensive ``MPI_Cart_create`` + d ``MPI_Comm_split``
calls by caching the per-dimension subcommunicators on the communicator via
attribute caching (a hidden keyval, Listing 2).  In JAX the analogue is:

* ``cart_create(mesh_or_devices, dims, names)`` — build a Cartesian mesh
  over the same devices (the Cartesian communicator).  Splitting an
  existing mesh axis into virtual sub-axes gives the dimension-wise
  "communicators" for free: a ``shard_map`` collective over one named axis
  *is* the concurrent per-group collective.
* ``TorusFactorization`` — the cached descriptor: dims, strides, round
  schedule, chosen variant.  Descriptors are cached in a registry keyed by
  (device fingerprint, dims, names) so repeated all-to-all calls never
  recompute the factorization or rebuild the mesh (mesh construction and
  jit tracing play the role of the paper's datatype/communicator setup
  cost, paid once).
* ``free()`` — the analogue of the delete callback (Listing 2's
  ``torusdel``), evicting the cache entry.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import Mesh

from .dims import dims_create
from .simulator import strides


@dataclass(frozen=True)
class TorusFactorization:
    """Cached factorization descriptor (the paper's ``torusattr``)."""

    axis_names: tuple[str, ...]          # fastest digit first
    dims: tuple[int, ...]
    variant: str = "natural"
    round_order: tuple[int, ...] | None = None

    @property
    def d(self) -> int:
        return len(self.dims)

    @property
    def p(self) -> int:
        return math.prod(self.dims)

    @property
    def sigma(self) -> tuple[int, ...]:
        return strides(self.dims)

    def blocks_sent_per_device(self) -> int:
        """Theorem 1: dp - sum_k p/D[k]."""
        return self.d * self.p - sum(self.p // Dk for Dk in self.dims)

    def mesh_axes_reversed(self) -> tuple[str, ...]:
        """Most-significant-first tuple (JAX collective linearization)."""
        return tuple(reversed(self.axis_names))


def cart_create(devices, dims: tuple[int, ...],
                names: tuple[str, ...] | None = None) -> Mesh:
    """``MPI_Cart_create``: a Cartesian mesh over the given devices.

    ``devices`` may be a flat device list, an existing ``Mesh`` (its devices
    are reused in order — the no-reorder case of Listing 1), or an int
    (take the first n local devices).  ``dims`` follows the digit
    convention of this package: ``dims[0]`` is the fastest digit, so the
    device array is built with ``dims`` reversed (row-major, most
    significant first).
    """
    if isinstance(devices, Mesh):
        devs = list(devices.devices.flat)
    elif isinstance(devices, int):
        devs = jax.devices()[:devices]
    else:
        devs = list(devices)
    p = math.prod(dims)
    if len(devs) != p:
        raise ValueError(f"{len(devs)} devices != prod(dims) = {p}")
    if names is None:
        names = tuple(f"t{i}" for i in range(len(dims)))
    if len(names) != len(dims):
        raise ValueError("names/dims length mismatch")
    arr = np.array(devs, dtype=object).reshape(tuple(reversed(dims)))
    return Mesh(arr, tuple(reversed(names)))


_REGISTRY: dict[tuple, tuple[Mesh | None, TorusFactorization]] = {}
_SPLIT_COUNTER = {"cart_creates": 0, "lookups": 0}


def device_fingerprint(mesh: Mesh) -> tuple:
    """Stable identity of the mesh's device set.

    Uses the runtime-assigned ``device.id`` (stable for a given process
    topology) and platform, NOT ``id(device)`` — CPython object identity
    changes whenever the device list is rebuilt, which silently defeated
    the cache across descriptor re-lookups through fresh ``Mesh`` objects.
    """
    devs = mesh.devices.flat
    return tuple((int(d.id), getattr(d, "platform", "?")) for d in devs)


def _key(devices_fingerprint, dims, names, variant):
    return (devices_fingerprint, tuple(dims), tuple(names or ()), variant)


def get_factorization(mesh: Mesh, axis_names=None, *, d: int | None = None,
                      variant: str = "natural") -> TorusFactorization:
    """Look up (or create and cache) the factorization descriptor.

    If ``axis_names`` is given, the mesh's existing axes are the torus
    dimensions (fastest digit first).  Otherwise the *product* of all mesh
    axes is factorized into ``d`` balanced factors via ``dims_create`` —
    the caller should then build the Cartesian mesh with ``cart_create``.
    """
    if axis_names is not None:
        axis_names = (axis_names,) if isinstance(axis_names, str) \
            else tuple(axis_names)
        dims = tuple(mesh.shape[n] for n in axis_names)
    else:
        p = math.prod(mesh.shape.values())
        if d is None:
            raise ValueError("need either axis_names or d")
        dims = tuple(reversed(dims_create(p, d)))  # fastest digit smallest
        axis_names = tuple(f"t{i}" for i in range(d))
    key = _key(device_fingerprint(mesh), dims, axis_names, variant)
    _SPLIT_COUNTER["lookups"] += 1
    if key not in _REGISTRY:
        _SPLIT_COUNTER["cart_creates"] += 1
        _REGISTRY[key] = (None, TorusFactorization(axis_names, dims, variant))
    return _REGISTRY[key][1]


def free(descriptor: TorusFactorization) -> None:
    """The delete-callback analogue: evict all cache entries using it."""
    dead = [k for k, (_, v) in _REGISTRY.items() if v == descriptor]
    for k in dead:
        del _REGISTRY[k]


def cache_stats() -> dict[str, int]:
    return dict(_SPLIT_COUNTER)
