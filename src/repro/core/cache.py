"""Communicator factorization and caching — the JAX analogue of Listings 1–2.

The paper amortizes the expensive ``MPI_Cart_create`` + d ``MPI_Comm_split``
calls by caching the per-dimension subcommunicators on the communicator via
attribute caching (a hidden keyval, Listing 2).  In JAX the analogue is:

* ``cart_create(mesh_or_devices, dims, names)`` — build a Cartesian mesh
  over the same devices (the Cartesian communicator).  Splitting an
  existing mesh axis into virtual sub-axes gives the dimension-wise
  "communicators" for free: a ``shard_map`` collective over one named axis
  *is* the concurrent per-group collective.
* ``TorusFactorization`` — the cached descriptor: dims, strides, round
  schedule, chosen variant.  Descriptors are cached in a bounded LRU
  registry keyed by (device fingerprint, dims, names) so repeated
  all-to-all calls never recompute the factorization or rebuild the mesh
  (mesh construction and jit tracing play the role of the paper's
  datatype/communicator setup cost, paid once).  ``core.plan`` keys its
  ``A2APlan`` cache alongside the same fingerprint.
* ``free()`` / ``free_all()`` — the analogue of the delete callback
  (Listing 2's ``torusdel``), evicting cache entries; the LRU capacity
  (``set_cache_capacity``) bounds the registry so long-running serving
  processes that cycle through many meshes cannot grow it unboundedly.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh

from .dims import dims_create
from .simulator import strides


class LRUCache:
    """Minimal bounded LRU mapping with hit/miss/eviction accounting.

    Shared by the factorization registry below and the ``A2APlan`` registry
    in ``core.plan``; eviction may run a callback (the paper's delete
    callback, Listing 2).
    """

    def __init__(self, capacity: int = 128,
                 on_evict: Callable | None = None):
        self.capacity = int(capacity)
        self.on_evict = on_evict
        self._data: OrderedDict = OrderedDict()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0}

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def keys(self):
        return list(self._data.keys())

    def values(self):
        return list(self._data.values())

    def get(self, key):
        """Return the cached value (refreshing recency) or None; counts a
        hit or miss."""
        if key in self._data:
            self.stats["hits"] += 1
            self._data.move_to_end(key)
            return self._data[key]
        self.stats["misses"] += 1
        return None

    def put(self, key, value):
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > max(1, self.capacity):
            _, evicted = self._data.popitem(last=False)
            self.stats["evictions"] += 1
            if self.on_evict is not None:
                self.on_evict(evicted)
        return value

    def pop(self, key):
        return self._data.pop(key, None)

    def clear(self):
        self._data.clear()

    def set_capacity(self, capacity: int):
        self.capacity = int(capacity)
        while len(self._data) > max(1, self.capacity):
            _, evicted = self._data.popitem(last=False)
            self.stats["evictions"] += 1
            if self.on_evict is not None:
                self.on_evict(evicted)


@dataclass(frozen=True)
class TorusFactorization:
    """Cached factorization descriptor (the paper's ``torusattr``)."""

    axis_names: tuple[str, ...]          # fastest digit first
    dims: tuple[int, ...]
    variant: str = "natural"
    round_order: tuple[int, ...] | None = None

    @property
    def d(self) -> int:
        return len(self.dims)

    @property
    def p(self) -> int:
        return math.prod(self.dims)

    @property
    def sigma(self) -> tuple[int, ...]:
        return strides(self.dims)

    def blocks_sent_per_device(self) -> int:
        """Theorem 1: dp - sum_k p/D[k]."""
        return self.d * self.p - sum(self.p // Dk for Dk in self.dims)

    def mesh_axes_reversed(self) -> tuple[str, ...]:
        """Most-significant-first tuple (JAX collective linearization)."""
        return tuple(reversed(self.axis_names))


def cart_create(devices, dims: tuple[int, ...],
                names: tuple[str, ...] | None = None) -> Mesh:
    """``MPI_Cart_create``: a Cartesian mesh over the given devices.

    ``devices`` may be a flat device list, an existing ``Mesh`` (its devices
    are reused in order — the no-reorder case of Listing 1), or an int
    (take the first n local devices).  ``dims`` follows the digit
    convention of this package: ``dims[0]`` is the fastest digit, so the
    device array is built with ``dims`` reversed (row-major, most
    significant first).
    """
    if isinstance(devices, Mesh):
        devs = list(devices.devices.flat)
    elif isinstance(devices, int):
        devs = jax.devices()[:devices]
    else:
        devs = list(devices)
    p = math.prod(dims)
    if len(devs) != p:
        raise ValueError(f"{len(devs)} devices != prod(dims) = {p}")
    if names is None:
        names = tuple(f"t{i}" for i in range(len(dims)))
    if len(names) != len(dims):
        raise ValueError("names/dims length mismatch")
    arr = np.array(devs, dtype=object).reshape(tuple(reversed(dims)))
    return Mesh(arr, tuple(reversed(names)))


_REGISTRY: LRUCache = LRUCache(capacity=128)
_SPLIT_COUNTER = {"cart_creates": 0, "lookups": 0}


_FINGERPRINTS: "weakref.WeakKeyDictionary[Mesh, tuple]" | None = None


def device_fingerprint(mesh: Mesh) -> tuple:
    """Stable identity of the mesh's device set.

    Uses the runtime-assigned ``device.id`` (stable for a given process
    topology) and platform, NOT ``id(device)`` — CPython object identity
    changes whenever the device list is rebuilt, which silently defeated
    the cache across descriptor re-lookups through fresh ``Mesh`` objects.
    Memoized per Mesh object so steady-state plan fetches don't re-walk
    the device list (a Mesh is immutable; rebuilt meshes over the same
    devices hash to the same fingerprint anyway).
    """
    global _FINGERPRINTS
    if _FINGERPRINTS is None:
        import weakref
        _FINGERPRINTS = weakref.WeakKeyDictionary()
    try:
        fp = _FINGERPRINTS.get(mesh)
    except TypeError:       # unhashable / non-weakref-able mesh subclass
        fp = None
    if fp is None:
        fp = tuple((int(d.id), getattr(d, "platform", "?"))
                   for d in mesh.devices.flat)
        try:
            _FINGERPRINTS[mesh] = fp
        except TypeError:
            pass
    return fp


def _key(devices_fingerprint, dims, names, variant):
    return (devices_fingerprint, tuple(dims), tuple(names or ()), variant)


def get_factorization(mesh: Mesh, axis_names=None, *, d: int | None = None,
                      variant: str = "natural") -> TorusFactorization:
    """Look up (or create and cache) the factorization descriptor.

    If ``axis_names`` is given, the mesh's existing axes are the torus
    dimensions (fastest digit first).  Otherwise the *product* of all mesh
    axes is factorized into ``d`` balanced factors via ``dims_create`` —
    the caller should then build the Cartesian mesh with ``cart_create``.
    """
    if axis_names is not None:
        axis_names = (axis_names,) if isinstance(axis_names, str) \
            else tuple(axis_names)
        dims = tuple(mesh.shape[n] for n in axis_names)
    else:
        p = math.prod(mesh.shape.values())
        if d is None:
            raise ValueError("need either axis_names or d")
        dims = tuple(reversed(dims_create(p, d)))  # fastest digit smallest
        axis_names = tuple(f"t{i}" for i in range(d))
    key = _key(device_fingerprint(mesh), dims, axis_names, variant)
    _SPLIT_COUNTER["lookups"] += 1
    hit = _REGISTRY.get(key)
    if hit is None:
        _SPLIT_COUNTER["cart_creates"] += 1
        hit = _REGISTRY.put(key, TorusFactorization(axis_names, dims,
                                                    variant))
    return hit


def free(descriptor: TorusFactorization) -> None:
    """The delete-callback analogue: evict all cache entries using it."""
    dead = [k for k in _REGISTRY.keys() if _REGISTRY._data[k] == descriptor]
    for k in dead:
        _REGISTRY.pop(k)


def free_all() -> None:
    """Evict every cached factorization descriptor (and the per-Mesh
    fingerprint memo), restoring the full cold-start setup cost."""
    _REGISTRY.clear()
    if _FINGERPRINTS is not None:
        _FINGERPRINTS.clear()


def set_cache_capacity(capacity: int) -> None:
    """Bound the factorization registry (evicting LRU entries if needed)."""
    _REGISTRY.set_capacity(capacity)


def cache_stats() -> dict[str, int]:
    out = dict(_SPLIT_COUNTER)
    out.update(_REGISTRY.stats)
    out["size"] = len(_REGISTRY)
    out["capacity"] = _REGISTRY.capacity
    return out


# The factorization-cache slice of the unified telemetry snapshot
# (core.telemetry.metrics_snapshot -> "factorization.*").
from . import telemetry as _telemetry                       # noqa: E402

_telemetry.register_stats_provider("factorization", cache_stats)
