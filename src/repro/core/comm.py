"""TorusComm — the cached Cartesian communicator as the API root.

The paper's load-bearing object is the *cached Cartesian communicator*:
``MPI_Cart_create`` once, split into d dimension-wise sub-communicators
once, cache both via attribute caching (Listings 1–2), and express every
collective as d dimension-wise exchanges.  Earlier PRs built the
collectives (dense, ragged, overlapped) but left the communicator
implicit — factorizations in ``core.cache``, plans in ``core.plan``'s
LRU, measurements in ``core.autotune``'s TuningDB, every call site
re-supplying ``(mesh, axes)`` tuples.  :class:`TorusComm` makes it
explicit:

* ``torus_comm(mesh_or_dims, axes, *, d=None, variant=...)`` builds (or
  fetches from a bounded LRU registry) the communicator: it owns the
  torus factorization descriptor, the stable device fingerprint, its
  slice of the plan registry, and the tuning-DB handle/generation.
* ``comm.sub(axes)`` is the paper's dimension-wise split made user-visible
  and recursive: a child communicator over an axis subset.  Sub-comm
  plans share the global plan registry with their top-level equivalents,
  so ``comm.sub(axes).all_to_all(...)`` *is* the identical cached plan a
  top-level ``torus_comm(mesh, axes).all_to_all(...)`` returns
  (bit-exactness by construction; property- and device-tested).
* ``comm.all_to_all`` / ``comm.ragged_all_to_all`` are the single factory
  for the existing plan family (``A2APlan`` / ``RaggedA2APlan``), and
  ``comm.all_gather`` / ``comm.reduce_scatter`` extend it with a new
  **dimension-wise gather family** (Mortensen et al.'s advanced-MPI
  transposes, Träff et al.'s isomorphic collectives): d per-axis stages
  through the same double-buffered round machinery (``core.overlap
  .run_pipelined``), validated against the ``core.simulator`` oracles on
  the paper's 5x4 and 2x3x4 tori.
* lifecycle: ``comm.free()`` (or the context-manager form) is the
  delete callback — it drops the comm's plans from the registry (nested
  entries included) and releases its factorization refs; ``comm.stats()``
  unifies what used to take three calls (``cache_stats`` +
  ``plan_cache_stats`` + ``autotune_stats``) plus the tuning-DB
  generation into one report.

``plan_all_to_all`` / ``plan_ragged_all_to_all`` remain as thin
delegators that build or reuse an *implicit* comm, so the PR 2
deprecation story (legacy free functions -> plans) is preserved
unchanged one level down.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from . import plan as _planmod
from . import telemetry
from .cache import (
    LRUCache,
    TorusFactorization,
    cache_stats,
    cart_create,
    device_fingerprint,
    get_factorization,
)
from .factorized import _as_tuple, _axis_sizes, _skip_trivial
from .overlap import _check_order, _split_chunks, run_pipelined
from .tuning import (
    choose_dimwise_algorithm,
    predict_allgather,
    predict_direct,
    predict_reduce_scatter,
    resolve_links,
    slowest_active_link,
)

GATHER_BACKENDS = ("tuned", "direct", "factorized")


# ---------------------------------------------------------------------------
# Dimension-wise gather kernels (run inside jax.shard_map)
# ---------------------------------------------------------------------------


def _allgather_stages(names, sizes, order):
    """One tiled per-axis gather per round, on the d-dim block view
    (axes ``[dim d-1, ..., dim 0, *payload]``; processed dims grow from
    extent 1 to ``D[k]``, ordered by the peer's digit)."""
    d = len(sizes)
    pos = lambda m: d - 1 - m

    def stage(k):
        def run(view, _c):
            return lax.all_gather(view, names[k], axis=pos(k), tiled=True)
        return run
    return [stage(k) for k in order]


def _reduce_scatter_stages(names, sizes, order):
    """The mirror: one tiled per-axis psum-scatter per round (processed
    dims shrink from ``D[k]`` to extent 1; each member keeps the tile at
    its own digit, summed over the group)."""
    d = len(sizes)
    pos = lambda m: d - 1 - m

    def stage(k):
        def run(view, _c):
            return lax.psum_scatter(view, names[k],
                                    scatter_dimension=pos(k), tiled=True)
        return run
    return [stage(k) for k in order]


def _allgather_impl(x, axis_names, *, round_order=None, n_chunks: int = 1):
    """d-stage dimension-wise all-gather (the ``core.simulator`` oracle's
    JAX form).

    Args:
      x: this device's ``(*block)`` contribution.
      axis_names: torus dimensions, fastest digit first.
      round_order: permutation of the active rounds (stages commute).
      n_chunks: payload chunks run through the software pipeline
        (``run_pipelined``) so stages of different chunks interleave on
        different dimension links, exactly like the overlap engine.

    Returns ``(p, *block)``: ``out[i]`` = the block contributed by torus
    rank ``i``.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    p = math.prod(dims)
    names, sizes = _skip_trivial(axis_names, dims)
    d = len(sizes)
    if d == 0:
        return x[None]
    order = _check_order(round_order, d)
    flat = x.reshape(-1)
    chunks = _split_chunks(flat, 0, max(1, n_chunks))
    stages = _allgather_stages(names, sizes, order)
    views = [c.reshape((1,) * d + c.shape) for c in chunks]
    outs = [v.reshape(p, -1) for v in run_pipelined(views, stages)]
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    return out.reshape((p,) + x.shape)


def _direct_allgather_impl(x, axis_names):
    """Baseline: one tiled gather over the product communicator."""
    names, _ = _skip_trivial(_as_tuple(axis_names),
                             _axis_sizes(_as_tuple(axis_names)))
    if not names:
        return x[None]
    return lax.all_gather(x[None], tuple(reversed(names)), axis=0,
                          tiled=True)


def _reduce_scatter_impl(x, axis_names, *, round_order=None,
                         n_chunks: int = 1):
    """d-stage dimension-wise reduce-scatter.

    Args:
      x: ``(p, *block)`` — block ``i`` is this device's contribution to
        torus rank ``i``'s reduction.
      round_order / n_chunks: as in :func:`_allgather_impl`.

    Returns ``(*block)``: the sum over all ranks ``r`` of rank ``r``'s
    block destined here.  Summation order differs from the direct
    single-collective form, so cross-backend bit-exactness holds for
    exact dtypes (ints); floats agree to rounding.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    p = math.prod(dims)
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != prod(dims)={p} "
                         f"({dims})")
    names, sizes = _skip_trivial(axis_names, dims)
    d = len(sizes)
    if d == 0:
        return x[0]
    order = _check_order(round_order, d)
    flat = x.reshape(p, -1)
    chunks = _split_chunks(flat, 1, max(1, n_chunks))
    stages = _reduce_scatter_stages(names, sizes, order)
    view_prefix = tuple(reversed(sizes))
    views = [c.reshape(view_prefix + c.shape[1:]) for c in chunks]
    outs = [v.reshape(-1) for v in run_pipelined(views, stages)]
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return out.reshape(x.shape[1:])


def _direct_reduce_scatter_impl(x, axis_names):
    """Baseline: one tiled psum-scatter over the product communicator."""
    names, _ = _skip_trivial(_as_tuple(axis_names),
                             _axis_sizes(_as_tuple(axis_names)))
    if not names:
        return x[0]
    out = lax.psum_scatter(x, tuple(reversed(names)), scatter_dimension=0,
                           tiled=True)
    return out.reshape(x.shape[1:])


# ---------------------------------------------------------------------------
# The gather-family plan objects
# ---------------------------------------------------------------------------


class _DimwisePlan:
    """Shared plumbing of the gather-family plans (identity, describe,
    host_fn caching); resolved and cached like every other plan."""

    kind = "dimwise"

    def __init__(self, fact: TorusFactorization, *, requested_backend: str,
                 backend: str, order: tuple[int, ...], n_chunks: int,
                 block_shape, dtype, links, predicted_seconds, mesh,
                 tuned_from, parent):
        self.fact = fact
        self.requested_backend = requested_backend
        self.backend = backend
        self.order = order
        self.n_chunks = n_chunks
        self.block_shape = None if block_shape is None \
            else tuple(block_shape)
        self.dtype = dtype
        self.links = links
        self.predicted_seconds = predicted_seconds
        self.tuned_from = tuned_from
        # Axis names of the comm this plan's owner was split from (a
        # sub-communicator lineage marker), or None for top-level comms.
        self.parent = parent
        self._mesh = mesh
        self._from_cache = False
        self._fetches = 1
        self._host_fns: dict[Mesh, object] = {}

    # -- identity ----------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.fact.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.fact.dims

    @property
    def p(self) -> int:
        return self.fact.p

    @property
    def d(self) -> int:
        return self.fact.d

    @property
    def variant(self) -> str:
        return self.fact.variant

    @property
    def block_bytes(self) -> int | None:
        if self.block_shape is None or self.dtype is None:
            return None
        return math.prod(self.block_shape) * jnp.dtype(self.dtype).itemsize

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the resolved plan."""
        return {
            "kind": self.kind,
            "axes": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "backend": self.backend,
            "requested_backend": self.requested_backend,
            "variant": self.variant,
            "round_order": list(self.order),
            "n_chunks": self.n_chunks,
            "block_shape": None if self.block_shape is None
            else list(self.block_shape),
            "dtype": None if self.dtype is None
            else jnp.dtype(self.dtype).name,
            "block_bytes": self.block_bytes,
            "predicted_seconds": self.predicted_seconds,
            "links": [{"alpha": l.alpha, "bandwidth": l.bandwidth}
                      for l in self.links],
            "tuned_from": self.tuned_from,
            "parent": None if self.parent is None else list(self.parent),
            "cache": "hit" if self._from_cache else "miss",
        }

    def __repr__(self):
        return (f"{type(self).__name__}(dims={self.dims}, "
                f"axes={self.axis_names}, backend={self.backend!r}, "
                f"n_chunks={self.n_chunks})")

    def _host_fn(self, mesh, local):
        mesh = self._mesh if mesh is None else mesh
        if mesh is None:
            raise ValueError("plan was built without a Mesh; pass one")
        if mesh not in self._host_fns:
            import jax
            axes = tuple(reversed(self.axis_names))
            self._host_fns[mesh] = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=P(axes), out_specs=P(axes)))
        return self._host_fns[mesh]


class AllGatherPlan(_DimwisePlan):
    """A resolved, reusable dimension-wise all-gather plan.

    Construct via :meth:`TorusComm.all_gather`; never directly.
    ``forward`` runs inside ``jax.shard_map`` over the torus axes.
    """

    kind = "allgather"

    def forward(self, x):
        """``x`` is this device's ``(*block)`` contribution; returns
        ``(p, *block)`` with ``out[i]`` = rank ``i``'s block."""
        if self.backend == "direct":
            return _direct_allgather_impl(x, self.axis_names)
        return _allgather_impl(x, self.axis_names, round_order=self.order,
                               n_chunks=self.n_chunks)

    def host_fn(self, mesh: Mesh | None = None):
        """Jitted host-level all-gather over a global ``(p, *block)``
        operand (``x[r]`` = rank r's contribution); returns
        ``(p, p, *block)`` — every rank's gathered buffer."""
        return self._host_fn(mesh, lambda xl: self.forward(xl[0])[None])


class ReduceScatterPlan(_DimwisePlan):
    """A resolved, reusable dimension-wise reduce-scatter plan.

    Construct via :meth:`TorusComm.reduce_scatter`; never directly.
    The d-stage form reduces in a different association order than the
    direct collective: exact dtypes are bit-identical, floats agree to
    rounding.
    """

    kind = "reduce_scatter"

    def forward(self, x):
        """``x`` is ``(p, *block)``, block ``i`` this device's term for
        rank ``i``'s reduction; returns ``(*block)`` = the full sum for
        this rank."""
        if self.backend == "direct":
            return _direct_reduce_scatter_impl(x, self.axis_names)
        return _reduce_scatter_impl(x, self.axis_names,
                                    round_order=self.order,
                                    n_chunks=self.n_chunks)

    def host_fn(self, mesh: Mesh | None = None):
        """Jitted host-level reduce-scatter over a global ``(p, p,
        *block)`` operand (``x[r, i]`` = rank r's term for rank i);
        returns ``(p, *block)`` — ``out[r] = sum_s x[s, r]``."""
        return self._host_fn(mesh, lambda xl: self.forward(xl[0])[None])


def _build_dimwise_plan(cls, source, axis_names, block_shape, dtype, *,
                        backend, variant, round_order, n_chunks, links,
                        parent):
    """Resolution + registry for the gather-family plans (shares the
    ``core.plan`` LRU, stats, and teardown machinery)."""
    axis_names = _as_tuple(axis_names)
    if isinstance(source, Mesh):
        mesh = source
        fact = get_factorization(mesh, axis_names, variant=variant)
        dims = fact.dims
        dev_key = device_fingerprint(mesh)
    else:
        dims = tuple(int(s) for s in source)
        fact = TorusFactorization(axis_names, dims, variant)
        mesh, dev_key = None, None
    if backend not in GATHER_BACKENDS:
        raise ValueError(f"unknown {cls.kind} backend {backend!r}; "
                         f"expected one of {GATHER_BACKENDS}")
    link_models = resolve_links(links, dims, axis_names)
    _, active = _skip_trivial(axis_names, dims)
    order = _check_order(round_order, len(active))

    p = math.prod(dims)
    block_bytes = None
    if block_shape is not None and dtype is not None:
        block_bytes = math.prod(tuple(block_shape)) \
            * jnp.dtype(dtype).itemsize

    links_key = None if links is None else link_models
    key = (cls.kind, dev_key, dims, axis_names,
           None if block_shape is None else tuple(block_shape),
           None if dtype is None else jnp.dtype(dtype).name,
           backend, variant,
           None if round_order is None else tuple(round_order),
           int(n_chunks), links_key, parent)
    cached = _planmod._registry_fetch(key)
    if cached is not None:
        return cached

    tuned_from = None
    predicted = None
    if backend == "tuned":
        if block_bytes is None:
            raise ValueError(f'backend="tuned" needs block_shape and dtype '
                             f"for the {cls.kind} cost model")
        sched = choose_dimwise_algorithm(cls.kind, dims, link_models,
                                         float(block_bytes),
                                         round_order=round_order)
        resolved, tuned_from = sched.kind, "model"
        predicted = sched.predicted_seconds
    else:
        resolved = backend
        if block_bytes is not None:
            if resolved == "direct":
                slowest = slowest_active_link(dims, link_models)
                predicted = predict_direct(p, float(block_bytes), slowest)
            else:
                predict = predict_allgather if cls.kind == "allgather" \
                    else predict_reduce_scatter
                predicted = predict(dims, link_models, float(block_bytes),
                                    p, round_order=round_order)
    plan = cls(fact, requested_backend=backend, backend=resolved,
               order=order, n_chunks=max(1, int(n_chunks)),
               block_shape=block_shape, dtype=dtype, links=link_models,
               predicted_seconds=predicted, mesh=mesh,
               tuned_from=tuned_from, parent=parent)
    return _planmod._registry_store(key, plan)


# ---------------------------------------------------------------------------
# The communicator
# ---------------------------------------------------------------------------

_COMMS: LRUCache = LRUCache(capacity=64)


class TorusComm:
    """A cached Cartesian communicator over a torus factorization.

    Construct via :func:`torus_comm`; never directly.  The comm owns the
    factorization descriptor (``fact``), the mesh (when device-backed),
    the device fingerprint key, the tuning-DB handle, and the registry
    keys of every plan resolved through it — its slice of the plan LRU,
    released by :meth:`free`.  All collective construction goes through
    the factory methods; execution stays on the returned plan objects.
    """

    def __init__(self, fact: TorusFactorization, *, mesh: Mesh | None,
                 dev_key, parent: "TorusComm | None" = None, db=None):
        self.fact = fact
        self.mesh = mesh
        self.dev_key = dev_key
        self.parent = parent
        self._db = db
        self._source = mesh if mesh is not None else fact.dims
        self._plan_keys: set = set()
        self._subs: dict[tuple, TorusComm] = {}
        self._parts: dict[tuple, tuple] = {}
        # registry slot (cleared on free) and immutable identity (never
        # cleared — children key their lineage on it)
        self._comm_key = None
        self._identity = None
        self._freed = False
        # elastic lineage: set by rebuild() on the comm it returns
        self.rebuilt_from: dict | None = None
        self.tuning_migrated: int = 0

    # -- identity ----------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.fact.axis_names

    @property
    def dims(self) -> tuple[int, ...]:
        return self.fact.dims

    @property
    def p(self) -> int:
        return self.fact.p

    @property
    def d(self) -> int:
        return self.fact.d

    @property
    def variant(self) -> str:
        return self.fact.variant

    def __repr__(self):
        par = f", parent={self.parent.axis_names}" if self.parent else ""
        return (f"TorusComm(dims={self.dims}, axes={self.axis_names}, "
                f"variant={self.variant!r}{par})")

    # -- the dimension-wise split (user-visible, recursive) ----------------

    def sub(self, axes) -> "TorusComm":
        """The paper's dimension-wise communicator split: a child comm
        over a subset of this comm's axes (any order; recursive).

        Child plans share the global plan registry with top-level comms
        over the same axes — ``comm.sub(axes).all_to_all(...)`` returns
        the identical cached plan object ``torus_comm(mesh, axes)
        .all_to_all(...)`` does, so sub-comm collectives are bit-exact
        with top-level ones by construction.  (The gather-family plans
        additionally key on the split lineage so their
        ``describe()["parent"]`` is stable: a sub-comm all-gather is a
        distinct — still bit-exact — registry entry from the top-level
        one.)
        """
        axes = _as_tuple(axes)
        if len(set(axes)) != len(axes):
            raise ValueError(f"duplicate axes in {axes}")
        missing = [a for a in axes if a not in self.axis_names]
        if missing:
            raise ValueError(f"axes {missing} not in communicator axes "
                             f"{self.axis_names}")
        if axes in self._subs and not self._subs[axes]._freed:
            return self._subs[axes]
        if self.mesh is not None:
            source = self.mesh
        else:
            source = tuple(self.dims[self.axis_names.index(a)]
                           for a in axes)
        child = torus_comm(source, axes, variant=self.variant,
                           db=self._db, _parent=self)
        self._subs[axes] = child
        return child

    def partition(self, n_first: int, *, d: int | None = None,
                  prefixes: tuple[str, str] = ("pre", "dec")
                  ) -> "tuple[TorusComm, TorusComm]":
        """The ``MPI_Comm_split`` analogue by device range: split this
        comm's ``p`` ranks into a leading group of ``n_first`` and the
        remaining ``p - n_first``, each re-factorized into its own
        balanced torus (``dims_create``) — the serving spine's
        prefill/decode domain split.

        Unlike :meth:`sub` (an *axis*-subset split, every rank a member
        of some child), partition divides the *device* range: rank ``r``
        belongs to the first child iff ``r < n_first``.  Children are
        full comms in the registry, cached on this comm and freed with
        it; their axes are named ``{prefix}0..`` from ``prefixes`` so two
        equal halves stay distinct registry entries.  Device-agnostic
        comms (dims tuples) yield device-agnostic children.

        Args:
          n_first: rank count of the first child, ``0 < n_first < p``.
          d: factorization degree of each child torus (default: this
            comm's own ``d``, capped by each child's size).
          prefixes: axis-name prefixes for the two children.

        Returns ``(first, rest)``.
        """
        from .dims import dims_create
        n_first = int(n_first)
        if not 0 < n_first < self.p:
            raise ValueError(f"n_first {n_first} outside (0, p={self.p}); "
                             "both partitions need at least one rank")
        if len(prefixes) != 2 or prefixes[0] == prefixes[1]:
            raise ValueError(f"need two distinct prefixes, got {prefixes}")
        key = (n_first, d, tuple(prefixes))
        if key in self._parts and not any(c._freed
                                          for c in self._parts[key]):
            return self._parts[key]
        devices = None if self.mesh is None \
            else list(self.mesh.devices.flat)
        children = []
        for prefix, count, devs in (
                (prefixes[0], n_first,
                 None if devices is None else devices[:n_first]),
                (prefixes[1], self.p - n_first,
                 None if devices is None else devices[n_first:])):
            dk = min(self.d if d is None else int(d), count)
            dims = tuple(reversed(dims_create(count, dk)))
            names = tuple(f"{prefix}{i}" for i in range(len(dims)))
            source = dims if devs is None \
                else cart_create(devs, dims, names)
            children.append(torus_comm(source, names, variant=self.variant,
                                       db=self._db, _parent=self))
        pair = (children[0], children[1])
        self._parts[key] = pair
        return pair

    # -- collective factories ----------------------------------------------

    def _note(self, plan):
        key = getattr(plan, "_registry_key", None)
        if key is not None:
            self._plan_keys.add(key)
            # A long-lived comm resolving many distinct shapes must not
            # outgrow the plan registry it indexes into: prune keys whose
            # plans the LRU has already evicted.
            if len(self._plan_keys) > 2 * _planmod._PLANS.capacity:
                self._plan_keys = {k for k in self._plan_keys
                                   if k in _planmod._PLANS}
        return plan

    def all_to_all(self, block_shape=None, dtype=None, *,
                   backend: str = "tuned", round_order=None,
                   reverse_round_order=None, n_chunks: int = 0,
                   max_chunks: int = 8, links=None,
                   compute_seconds: float = 0.0, db=None):
        """Build (or fetch) the :class:`~repro.core.plan.A2APlan` for one
        per-rank ``(block_shape, dtype)`` block — see
        :func:`~repro.core.plan.plan_all_to_all` for the knobs."""
        return self._note(_planmod._build_dense_plan(
            self._source, self.axis_names, block_shape, dtype,
            backend=backend, variant=self.variant, round_order=round_order,
            reverse_round_order=reverse_round_order, n_chunks=n_chunks,
            max_chunks=max_chunks, links=links,
            compute_seconds=compute_seconds,
            db=self._db if db is None else db))

    def ragged_all_to_all(self, row_shape=(), dtype="float32", *,
                          max_count: int, avg_count: float | None = None,
                          backend: str = "tuned", round_order=None,
                          reverse_round_order=None, n_chunks: int = 0,
                          max_chunks: int = 8, links=None,
                          compute_seconds: float = 0.0, db=None):
        """Build (or fetch) the :class:`~repro.core.plan.RaggedA2APlan`
        (Alltoallv semantics) — see
        :func:`~repro.core.plan.plan_ragged_all_to_all` for the knobs."""
        return self._note(_planmod._build_ragged_plan(
            self._source, self.axis_names, row_shape, dtype,
            max_count=max_count, avg_count=avg_count, backend=backend,
            variant=self.variant, round_order=round_order,
            reverse_round_order=reverse_round_order, n_chunks=n_chunks,
            max_chunks=max_chunks, links=links,
            compute_seconds=compute_seconds,
            db=self._db if db is None else db))

    def sparse_all_to_all(self, row_shape=(), dtype="float32", *,
                          max_count: int, avg_count: float | None = None,
                          density: float | None = None, round_order=None,
                          reverse_round_order=None, links=None):
        """Build (or fetch) the :class:`~repro.core.plan.SparseA2APlan`
        (message-combining sparse-neighborhood Alltoallv): the ragged
        counts phase plus skippable per-peer lanes per dimension-wise
        round — see :func:`~repro.core.plan.plan_sparse_all_to_all` for
        the knobs (``density`` is the expected non-zero fraction of the
        count matrix)."""
        return self._note(_planmod._build_sparse_plan(
            self._source, self.axis_names, row_shape, dtype,
            max_count=max_count, avg_count=avg_count, density=density,
            variant=self.variant, round_order=round_order,
            reverse_round_order=reverse_round_order, links=links))

    def kv_migration(self, row_shape=(), dtype="float32", *,
                     max_count: int, n_prefill: int,
                     avg_count: float | None = None,
                     migrations_per_tick: float = 1.0,
                     backend: str = "tuned", round_order=None,
                     reverse_round_order=None, links=None, db=None):
        """Build (or fetch) the :class:`~repro.core.plan.KVMigrationPlan`
        for the prefill->decode KV-cache handoff over this comm: an
        Alltoallv whose count matrix is non-zero only in the
        prefill->decode block — see
        :func:`~repro.core.plan.plan_kv_migration` for the knobs."""
        return self._note(_planmod._build_kv_plan(
            self._source, self.axis_names, row_shape, dtype,
            max_count=max_count, n_prefill=n_prefill, avg_count=avg_count,
            migrations_per_tick=migrations_per_tick, backend=backend,
            variant=self.variant, round_order=round_order,
            reverse_round_order=reverse_round_order, links=links,
            db=self._db if db is None else db))

    def transpose(self, local_shape, dtype="float32", *,
                  split_axis: int, concat_axis: int, backend: str = "tuned",
                  round_order=None, reverse_round_order=None,
                  n_chunks: int = 0, max_chunks: int = 8, links=None,
                  db=None):
        """Build (or fetch) a :class:`~repro.core.plan.TransposePlan` —
        the pencil↔pencil re-shard of a distributed FFT
        (``workloads.fft``) as a tiled all-to-all over this comm's torus:
        the local ``local_shape`` pencil is split into ``p`` chunks along
        ``split_axis`` and received chunks concatenate source-major along
        ``concat_axis``.  Resolves through any dense backend (including
        ``autotune`` against this comm's tuning DB); the plan's inner
        dense A2APlan is shared with the inverse transpose (swapped
        axes), so a forward/inverse pair costs one resolution."""
        return self._note(_planmod._build_transpose_plan(
            self._source, self.axis_names, local_shape, dtype,
            split_axis=split_axis, concat_axis=concat_axis, backend=backend,
            variant=self.variant, round_order=round_order,
            reverse_round_order=reverse_round_order, n_chunks=n_chunks,
            max_chunks=max_chunks, links=links,
            db=self._db if db is None else db,
            parent=self._parent_axes()))

    def all_gather(self, block_shape=None, dtype=None, *,
                   backend: str = "tuned", round_order=None,
                   n_chunks: int = 1, links=None) -> AllGatherPlan:
        """Build (or fetch) an :class:`AllGatherPlan`: each rank
        contributes one ``(block_shape, dtype)`` block, every rank ends
        with all ``p`` in torus-rank order — d per-axis stages
        (``backend="factorized"``), one product-communicator collective
        (``"direct"``), or the cost-model choice (``"tuned"``)."""
        return self._note(_build_dimwise_plan(
            AllGatherPlan, self._source, self.axis_names, block_shape,
            dtype, backend=backend, variant=self.variant,
            round_order=round_order, n_chunks=n_chunks, links=links,
            parent=self._parent_axes()))

    def reduce_scatter(self, block_shape=None, dtype=None, *,
                       backend: str = "tuned", round_order=None,
                       n_chunks: int = 1, links=None) -> ReduceScatterPlan:
        """Build (or fetch) a :class:`ReduceScatterPlan`: each rank
        contributes ``p`` blocks, rank ``i`` ends with the sum of every
        rank's block ``i`` — same backend family as :meth:`all_gather`."""
        return self._note(_build_dimwise_plan(
            ReduceScatterPlan, self._source, self.axis_names, block_shape,
            dtype, backend=backend, variant=self.variant,
            round_order=round_order, n_chunks=n_chunks, links=links,
            parent=self._parent_axes()))

    def _parent_axes(self):
        return None if self.parent is None else self.parent.axis_names

    # -- lifecycle ----------------------------------------------------------

    def free(self) -> None:
        """The delete callback (Listing 2's ``torusdel``): recursively
        free sub-comms, drop every plan resolved through this comm from
        the registry (their nested entries and factorization refs go with
        them via the shared teardown), and retire the comm's own registry
        entry.  Idempotent; the comm object stays usable for lookups but
        a later ``torus_comm`` call builds a fresh one."""
        for child in list(self._subs.values()):
            child.free()
        self._subs.clear()
        for pair in list(self._parts.values()):
            for child in pair:
                child.free()
        self._parts.clear()
        for key in self._plan_keys:
            _planmod._drop_plan(key)
        self._plan_keys.clear()
        if self._comm_key is not None:
            # only retire our own registry entry: a fresh comm may have
            # taken the key since a previous free() of this object
            if _COMMS._data.get(self._comm_key) is self:
                _COMMS.pop(self._comm_key)
            self._comm_key = None
        self._freed = True

    def __enter__(self) -> "TorusComm":
        return self

    def __exit__(self, *exc) -> None:
        self.free()

    def rebuild(self, surviving_devices, *, d: int | None = None,
                migrate_tuning: bool = True) -> "TorusComm":
        """The elastic rebuild step of detect → degrade → rebuild →
        resume: after device loss, re-create the communicator over the
        survivors.

        * re-factorizes ``p' = len(surviving_devices)`` into ``d``
          balanced factors (``MPI_Dims_create`` semantics via
          ``core.dims.dims_create``) and builds the survivor Cartesian
          mesh through ``core.cache.cart_create``;
        * frees exactly *this* comm's slice of the plan LRU and its
          factorization refs (``free()`` — other comms' cached plans are
          untouched, so a co-resident serving comm keeps its warm state);
        * migrates tuning-DB winners whose device fingerprint belonged to
          the dead comm and whose per-axis extents still hold on the new
          torus (``autotune.migrate_records``; marked ``migrated`` — a
          warm start, re-measured by the next explicit autotune);
        * returns the fresh comm.  Plans re-resolve **lazily** on first
          use — nothing is eagerly rebuilt, exactly like a cold comm.

        ``surviving_devices`` is a device list (order defines the new
        torus linearization), or an int: the survivor count, taking the
        first ``p'`` devices of the old mesh (device-backed comms) or
        staying device-agnostic (dims-tuple comms).  Axis names are
        reused, so call sites keyed on axis names survive the rebuild.
        """
        from .dims import dims_create
        d = self.d if d is None else int(d)
        if isinstance(surviving_devices, int):
            survivors = None if self.mesh is None \
                else list(self.mesh.devices.flat)[:surviving_devices]
            p2 = surviving_devices
        else:
            survivors = list(surviving_devices)
            p2 = len(survivors)
        if p2 <= 0:
            raise ValueError(f"no surviving devices (p'={p2})")
        if self.p == p2 and survivors is None and d == self.d:
            raise ValueError("rebuild needs a changed device set; "
                             f"p'={p2} == p={self.p} with no device list")
        dims2 = tuple(reversed(dims_create(p2, d)))
        names = self.axis_names if len(self.axis_names) == len(dims2) \
            else tuple(f"t{i}" for i in range(len(dims2)))
        with telemetry.get_tracer().span(
                "comm.rebuild", cat="comm", p_old=self.p, p_new=p2, d=d,
                dims_old=str(self.dims), dims_new=str(dims2)) as sp:
            source = dims2 if survivors is None \
                else cart_create(survivors, dims2, names)
            old = {"dims": self.dims, "axes": self.axis_names, "p": self.p,
                   "dev_key": self.dev_key}
            # invalidate exactly the dead comm's plan slice + fact refs
            self.free()
            fresh = torus_comm(source, names, variant=self.variant,
                               db=self._db)
            fresh.rebuilt_from = {"dims": list(old["dims"]),
                                  "axes": list(old["axes"]), "p": old["p"]}
            if migrate_tuning and old["dev_key"] is not None \
                    and fresh.dev_key is not None:
                from .autotune import get_default_db, migrate_records
                db = self._db if self._db is not None else get_default_db()
                fresh.tuning_migrated = migrate_records(
                    db, old["dev_key"], fresh.dev_key, fresh.dims,
                    fresh.axis_names)
                sp.set(tuning_migrated=fresh.tuning_migrated)
        telemetry.metrics().counter("comm.rebuilds").inc()
        return fresh

    # -- introspection ------------------------------------------------------

    def describe(self) -> dict:
        """Stable, JSON-serializable summary of the communicator."""
        return {
            "kind": "comm",
            "axes": list(self.axis_names),
            "dims": list(self.dims),
            "p": self.p,
            "d": self.d,
            "variant": self.variant,
            "parent": None if self.parent is None
            else list(self.parent.axis_names),
            "device_backed": self.mesh is not None,
            "plans": len(self._plan_keys),
            "subs": sorted(list(a) for a in self._subs),
            "rebuilt_from": self.rebuilt_from,
            "tuning_migrated": self.tuning_migrated,
        }

    def stats(self) -> dict:
        """One call for the whole cache picture: this comm's identity and
        plan slice, plus the unified factorization / plan / autotune /
        tuning-DB state that used to take three separate calls."""
        live = sum(1 for k in self._plan_keys if k in _planmod._PLANS)
        out = unified_stats(db=self._db)
        out["comm"] = {**self.describe(),
                       "plans_live": live,
                       "freed": self._freed}
        return out


def unified_stats(db=None) -> dict:
    """Registry-wide cache state in one dict: factorization descriptors
    (``cache_stats``), the plan LRU (``plan_cache_stats``), autotune
    counters (``autotune_stats``), the tuning-DB identity/generation, the
    communicator registry itself, and the merged telemetry view — the
    flat ``MetricsRegistry`` snapshot (every registered stats provider
    under its namespace plus ad-hoc counters), tracer state, and the
    measured-vs-model drift summary."""
    from .autotune import autotune_stats, get_default_db
    from .plan import plan_cache_stats
    db = db if db is not None else get_default_db()
    return {
        "factorization": cache_stats(),
        "plans": plan_cache_stats(),
        "autotune": autotune_stats(),
        "tuning_db": {"path": db.path_key, "generation": db.generation()},
        "comms": comm_registry_stats(),
        "telemetry": {
            "metrics": telemetry.metrics_snapshot(),
            "tracer": telemetry.get_tracer().stats(),
            "drift": telemetry.drift_detector().summary(),
        },
    }


def torus_comm(mesh_or_dims, axis_names=None, *, d: int | None = None,
               variant: str = "natural", db=None,
               _parent: TorusComm | None = None) -> TorusComm:
    """Build (or fetch from the LRU registry) a :class:`TorusComm`.

    Args:
      mesh_or_dims: a ``Mesh`` (the comm is keyed by the stable device
        fingerprint), an explicit per-axis size tuple, fastest digit
        first (device-agnostic — the inside-``shard_map`` path), or an
        int ``p`` with ``d=`` (the ``MPI_Dims_create`` +
        ``MPI_Cart_create`` path: ``p`` is factorized into ``d`` balanced
        dims and a Cartesian mesh is built over the first ``p`` local
        devices).
      axis_names: torus dimensions, fastest digit first.  May be omitted
        with ``d=``: the product of the mesh axes (or ``p``) is
        factorized via ``dims_create`` and a fresh Cartesian mesh with
        synthetic ``t0..t{d-1}`` axes is created over the same devices.
      d: balanced-factorization degree when ``axis_names`` is omitted.
      variant: per-round formulation for the comm's collectives,
        "natural" (zero-copy) or "paper".
      db: tuning-DB handle the comm's ``backend="autotune"`` plans
        consult (default: the process-wide default DB).
    """
    if isinstance(mesh_or_dims, Mesh) and axis_names is None:
        if d is None:
            raise ValueError("need either axis_names or d")
        seed = get_factorization(mesh_or_dims, None, d=d, variant=variant)
        mesh_or_dims = cart_create(mesh_or_dims, seed.dims, seed.axis_names)
        axis_names = seed.axis_names
    if isinstance(mesh_or_dims, int):
        if d is None:
            raise ValueError("an int p needs d= (the dims_create path)")
        from .dims import dims_create
        dims = tuple(reversed(dims_create(mesh_or_dims, d)))
        if axis_names is None:
            axis_names = tuple(f"t{i}" for i in range(len(dims)))
        mesh_or_dims = cart_create(mesh_or_dims, dims, _as_tuple(axis_names))

    axis_names = _as_tuple(axis_names)
    if isinstance(mesh_or_dims, Mesh):
        mesh = mesh_or_dims
        fact = get_factorization(mesh, axis_names, variant=variant)
        dev_key = device_fingerprint(mesh)
    else:
        dims = tuple(int(s) for s in mesh_or_dims)
        if len(dims) != len(axis_names):
            raise ValueError(f"{len(dims)} dims for {len(axis_names)} axes")
        fact = TorusFactorization(axis_names, dims, variant)
        mesh, dev_key = None, None

    # A child is keyed by the parent's full identity chain (not just its
    # axis names): two parents over different tori may split into
    # same-axes children, and those must be distinct comms with the
    # right lineage.  The DB handle is part of the identity too: a comm
    # bound to a custom tuning DB must not be returned to (or shadowed
    # by) callers using the process default — autotune records would
    # silently land in the wrong database.
    parent_key = None if _parent is None else _parent._identity
    db_key = None if db is None else db.path_key
    key = (dev_key, fact.dims, axis_names, variant, parent_key, db_key)
    cached = _COMMS.get(key)
    if cached is not None and not cached._freed:
        return cached
    comm = TorusComm(fact, mesh=mesh, dev_key=dev_key, parent=_parent,
                     db=db)
    comm._comm_key = comm._identity = key
    _COMMS.put(key, comm)
    return comm


def free_comms() -> None:
    """Drop every cached communicator (their plans stay in the plan
    registry — use ``TorusComm.free`` for the full per-comm teardown, or
    ``core.plan.free_plans`` for the registry-wide one)."""
    _COMMS.clear()


def comm_registry_stats() -> dict:
    out = dict(_COMMS.stats)
    out["size"] = len(_COMMS)
    out["capacity"] = _COMMS.capacity
    return out


# The communicator-registry slice of the unified telemetry snapshot
# (core.telemetry.metrics_snapshot -> "comms.*").
telemetry.register_stats_provider("comms", comm_registry_stats)


__all__ = [
    "AllGatherPlan",
    "GATHER_BACKENDS",
    "ReduceScatterPlan",
    "TorusComm",
    "comm_registry_stats",
    "free_comms",
    "torus_comm",
    "unified_stats",
]
