"""Deterministic fault injection for the elastic TorusComm stack.

At production scale the cached Cartesian communicator is long-lived state
that must survive device loss, hung collectives, and corrupted
persistence.  This module is the *injection* half of that story: every
failure mode the detect→degrade→rebuild→resume control loop
(``runtime.watchdog`` → ``runtime.trainer`` / ``runtime.serving`` →
``TorusComm.rebuild``) must handle can be produced on demand,
deterministically, from a seed — so the recovery paths are exercised by
ordinary tests instead of waiting for real hardware to die.

Injectable faults:

* **device loss** — :class:`DeviceLossError` raised at a chosen guarded
  call, naming the dead device ids (what a real runtime surfaces as an
  unreachable peer / ICI timeout).
* **slow / hung rounds** — a deterministic ``time.sleep`` around a
  guarded execution, sized to trip the watchdog's straggler or hang
  thresholds.
* **corrupted checkpoint leaves** — flip one byte of a stored leaf file
  (:func:`corrupt_checkpoint_leaf`), exercising the
  ``checkpoint.store`` sha256/next-newest fallback.
* **corrupted / contended TuningDB files** —
  :func:`corrupt_tuning_db` writes deterministic garbage;
  :func:`hold_tuning_db_lock` holds the advisory flock so a writer must
  time out and degrade to in-memory tuning.

Injectors hook the *host-level* execution surface
(``plan.host_fn(mesh)(...)`` for any ``A2APlan`` / ``RaggedA2APlan`` /
gather-family plan, or any callable via :meth:`FaultInjector.wrap` /
:meth:`FaultInjector.guard`) — faults fire between jitted executions,
never inside a trace, so the injected failure looks exactly like a
runtime fault (an exception or a stalled wall clock), not a compiled-in
behavior change.
"""

from __future__ import annotations

import contextlib
import random
import time
from dataclasses import dataclass, field
from pathlib import Path


class FaultError(RuntimeError):
    """Base class for injected (and, in production, detected) faults."""


class DeviceLossError(FaultError):
    """A device subset became unreachable mid-collective.

    ``devices`` is the tuple of dead device ids; the surviving set is the
    complement — what :meth:`TorusComm.rebuild` takes.
    """

    def __init__(self, devices=(), message: str | None = None):
        self.devices = tuple(devices)
        super().__init__(message or
                         f"device loss: devices {list(self.devices)} "
                         f"unreachable")


FAULT_KINDS = ("device_loss", "slow", "hang")


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: *what* fires and *when*.

    Firing condition (evaluated per guarded call, in order):
      ``at_call`` — fire on exactly the Nth call (1-based) of the
      matching label; ``every`` — fire on every Nth call;
      ``probability`` — fire when the injector's seeded RNG draws below
      it.  Conditions compose with OR; all-default specs never fire.
    """

    kind: str                          # "device_loss" | "slow" | "hang"
    at_call: int | None = None
    every: int | None = None
    probability: float = 0.0
    delay_seconds: float = 0.0         # sleep for slow/hang kinds
    devices: tuple[int, ...] = ()      # dead device ids for device_loss
    label: str | None = None           # restrict to one guard label

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")

    def _fires(self, call: int, rng: random.Random) -> bool:
        if self.at_call is not None and call == self.at_call:
            return True
        if self.every is not None and self.every > 0 \
                and call % self.every == 0:
            return True
        return self.probability > 0.0 and rng.random() < self.probability


@dataclass
class FaultInjector:
    """Seeded, replayable fault schedule over labeled guard points.

    The same ``(specs, seed)`` pair always produces the same fault
    sequence — probabilistic specs draw from one ``random.Random(seed)``
    in call order, so a failing fuzz run is reproducible from its seed
    alone.  ``fired`` records every injected fault as ``(kind, label,
    call_index)``.
    """

    specs: tuple[FaultSpec, ...] = ()
    seed: int = 0
    calls: dict = field(default_factory=dict)      # label -> call count
    fired: list = field(default_factory=list)      # (kind, label, call)

    def __post_init__(self):
        self.specs = tuple(self.specs)
        self._rng = random.Random(self.seed)
        self._installed: dict[int, tuple] = {}

    # -- the guard points ---------------------------------------------------

    def check(self, label: str = "a2a") -> None:
        """One guarded call: bump the label's counter and fire any spec
        whose condition matches (sleep for slow/hang, raise for
        device_loss)."""
        call = self.calls.get(label, 0) + 1
        self.calls[label] = call
        for spec in self.specs:
            if spec.label is not None and spec.label != label:
                continue
            if not spec._fires(call, self._rng):
                continue
            self.fired.append((spec.kind, label, call))
            if spec.kind in ("slow", "hang"):
                time.sleep(max(0.0, spec.delay_seconds))
            else:
                raise DeviceLossError(spec.devices)

    @contextlib.contextmanager
    def guard(self, label: str = "a2a"):
        """Context-manager guard around an arbitrary region (a train
        step, a serving tick): the fault fires on entry."""
        self.check(label)
        yield

    def wrap(self, fn, label: str = "a2a"):
        """Wrap any callable so each invocation is a guarded call."""
        def guarded(*args, **kwargs):
            self.check(label)
            return fn(*args, **kwargs)
        return guarded

    # -- plan installation --------------------------------------------------

    def install(self, plan, label: str = "a2a"):
        """Install the injector around a plan's host-level execution:
        every callable ``plan.host_fn(mesh)`` returns is guarded.  Works
        for any plan kind (dense, ragged, gather family) — they all
        expose ``host_fn``.  Idempotent per plan; undo with
        :meth:`uninstall`."""
        if id(plan) in self._installed:
            return plan
        orig = plan.host_fn

        def host_fn(mesh=None):
            return self.wrap(orig(mesh), label)

        self._installed[id(plan)] = (plan, orig)
        plan.host_fn = host_fn          # instance attr shadows the method
        # Telemetry hook: when the tracer steps a factorized plan
        # round-by-round, each round calls this check *inside* its span,
        # so an injected slow round shows up as per-round drift (the
        # host_fn wrapper above fires before the span opens and would be
        # invisible to round timing).  Distinct label — round-level specs
        # target "<label>.round" without perturbing outer call counts.
        plan._round_fault_check = lambda: self.check(f"{label}.round")
        return plan

    def uninstall(self, plan=None) -> None:
        """Remove the injector from one plan (or all installed plans)."""
        items = [self._installed.pop(id(plan))] if plan is not None \
            else [self._installed.pop(k) for k in list(self._installed)]
        for target, _orig in items:
            target.__dict__.pop("host_fn", None)
            target.__dict__.pop("_round_fault_check", None)


# ---------------------------------------------------------------------------
# Persistence faults: checkpoint leaves and the tuning DB
# ---------------------------------------------------------------------------


def corrupt_checkpoint_leaf(directory, step: int | None = None,
                            leaf_index: int = 0, seed: int = 0) -> Path:
    """Flip one byte of a stored checkpoint leaf file (deterministic from
    ``seed``), so restore hits either a sha256 mismatch or a codec
    decompression error — both of which ``checkpoint.store`` must treat
    as "this checkpoint is unusable, fall back to the next-newest".

    Returns the corrupted file's path.
    """
    import json
    directory = Path(directory)
    if step is None:
        from repro.checkpoint.store import latest_step
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    base = directory / f"step_{step:08d}"
    with open(base / "manifest.json") as f:
        manifest = json.load(f)
    files = sorted(info["file"] for info in manifest["leaves"].values())
    target = base / files[leaf_index % len(files)]
    data = bytearray(target.read_bytes())
    if not data:
        raise ValueError(f"empty leaf file {target}")
    pos = random.Random(seed).randrange(len(data))
    data[pos] ^= 0xFF
    target.write_bytes(bytes(data))
    return target


def corrupt_tuning_db(db_or_path, seed: int = 0,
                      mode: str = "garbage") -> Path:
    """Corrupt a TuningDB file in place: ``"garbage"`` overwrites it with
    deterministic non-JSON bytes, ``"truncate"`` cuts it mid-document.
    The DB's robustness contract is that both load as empty with a
    warning — plan construction must never crash on tuning state."""
    path = Path(getattr(db_or_path, "path", db_or_path))
    if mode == "truncate":
        raw = path.read_bytes() if path.exists() else b'{"version": 1'
        path.write_bytes(raw[:max(1, len(raw) // 2)])
    elif mode == "garbage":
        rng = random.Random(seed)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_bytes(bytes(rng.randrange(256) for _ in range(64)))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path


@contextlib.contextmanager
def hold_tuning_db_lock(db):
    """Hold the TuningDB's advisory flock for the duration of the block
    (a wedged lock-holder): any concurrent ``put``/``clear`` must hit its
    acquisition timeout and degrade to in-memory tuning instead of
    hanging the trainer.  No-op (still yields) where flock is
    unavailable."""
    try:
        import fcntl
    except ImportError:                       # non-POSIX: nothing to hold
        yield None
        return
    lockfile = db.path.with_name(db.path.name + ".lock")
    lockfile.parent.mkdir(parents=True, exist_ok=True)
    with open(lockfile, "w") as fh:
        fcntl.flock(fh, fcntl.LOCK_EX)
        try:
            yield lockfile
        finally:
            fcntl.flock(fh, fcntl.LOCK_UN)


__all__ = [
    "FAULT_KINDS",
    "DeviceLossError",
    "FaultError",
    "FaultInjector",
    "FaultSpec",
    "corrupt_checkpoint_leaf",
    "corrupt_tuning_db",
    "hold_tuning_db_lock",
]
