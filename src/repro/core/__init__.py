"""Core contribution: factorized zero-copy all-to-all for d-dim tori.

JAX reproduction of Träff, "Effective MPI: User-defined Datatypes and
Cartesian Communicators for Zero-copy All-to-all Communication in
Multidimensional Tori" (CS.DC 2026).
"""

from .dims import dims_create, max_dims, prime_factorization
from .factorized import (
    direct_all_to_all,
    direct_all_to_all_tiled,
    factorized_all_to_all,
    factorized_all_to_all_tiled,
    host_alltoall,
)
from .cache import (
    TorusFactorization,
    cache_stats,
    cart_create,
    free,
    free_all,
    get_factorization,
    set_cache_capacity,
)
from .plan import (
    A2APlan,
    KVMigrationPlan,
    RaggedA2APlan,
    SparseA2APlan,
    TransposePlan,
    free_plans,
    plan_all_to_all,
    plan_cache_entries,
    plan_cache_stats,
    plan_kv_migration,
    plan_ragged_all_to_all,
    plan_sparse_all_to_all,
    plan_transpose,
    set_plan_cache_capacity,
)
from .comm import (
    AllGatherPlan,
    ReduceScatterPlan,
    TorusComm,
    free_comms,
    torus_comm,
    unified_stats,
)
from .ragged import (
    bucket_occupancy,
    exact_alltoallv,
    next_pow2,
    torus_rank,
)
from .sparse import (
    round_message_masks,
    sparse_exact_alltoallv,
    sparse_traffic_stats,
)
from .autotune import (
    TuningDB,
    autotune,
    autotune_ragged,
    autotune_stats,
    default_db_path,
    fingerprint_digest,
    lookup_ragged_measured,
    migrate_records,
    plan_db_key,
    ragged_db_key,
    reset_autotune_stats,
)
from .faults import (
    DeviceLossError,
    FaultError,
    FaultInjector,
    FaultSpec,
    corrupt_checkpoint_leaf,
    corrupt_tuning_db,
    hold_tuning_db_lock,
)
from .simulator import (
    PAPER_EXAMPLES,
    SparseVolumeCount,
    check_correct_pencil_transpose,
    check_correct_sparse_alltoallv,
    example_index_table,
    pencil_transpose_reference,
    round_datatype,
    simulate_direct_alltoall,
    simulate_direct_alltoallv,
    simulate_factorized_allgather,
    simulate_factorized_alltoall,
    simulate_factorized_alltoallv,
    simulate_factorized_reduce_scatter,
    simulate_kv_migration,
    simulate_pencil_transpose,
    simulate_sparse_alltoallv,
)
from .tuning import (
    DCN,
    ICI,
    LinkModel,
    Schedule,
    ServingSplit,
    choose_algorithm,
    choose_chunks,
    choose_dimwise_algorithm,
    choose_ragged_algorithm,
    choose_serving_split,
    crossover_block_bytes,
    predict_allgather,
    predict_kv_migration,
    predict_overlapped,
    predict_ragged,
    predict_reduce_scatter,
    predict_sparse,
    predict_transpose,
)
from .guidelines import Measurement, Violation, check_guidelines, format_report
from .hlo_inspect import collective_bytes_of, interleave_report, parse_hlo
from .overlap import (
    overlapped_all_to_all,
    overlapped_all_to_all_tiled,
    pipeline_order,
    pipelined_all_to_all,
    run_pipelined,
)

__all__ = [
    "A2APlan", "AllGatherPlan", "DCN", "ICI", "KVMigrationPlan",
    "LinkModel", "Measurement",
    "PAPER_EXAMPLES", "RaggedA2APlan", "ReduceScatterPlan", "Schedule",
    "ServingSplit", "SparseA2APlan", "SparseVolumeCount", "TorusComm",
    "TorusFactorization", "TransposePlan", "TuningDB",
    "check_correct_pencil_transpose", "check_correct_sparse_alltoallv",
    "DeviceLossError", "FaultError", "FaultInjector", "FaultSpec",
    "Violation", "autotune", "autotune_ragged", "autotune_stats",
    "bucket_occupancy",
    "cache_stats", "cart_create", "check_guidelines", "choose_algorithm",
    "choose_chunks", "choose_dimwise_algorithm", "choose_ragged_algorithm",
    "collective_bytes_of", "corrupt_checkpoint_leaf", "corrupt_tuning_db",
    "choose_serving_split",
    "crossover_block_bytes", "default_db_path", "dims_create",
    "direct_all_to_all", "direct_all_to_all_tiled", "exact_alltoallv",
    "example_index_table", "factorized_all_to_all",
    "factorized_all_to_all_tiled", "fingerprint_digest", "format_report",
    "free", "free_all",
    "free_comms", "free_plans", "get_factorization", "hold_tuning_db_lock",
    "host_alltoall", "lookup_ragged_measured", "migrate_records",
    "interleave_report", "max_dims", "next_pow2", "overlapped_all_to_all",
    "overlapped_all_to_all_tiled", "parse_hlo", "pipeline_order",
    "pipelined_all_to_all", "plan_all_to_all", "plan_cache_entries",
    "plan_cache_stats", "plan_db_key", "plan_kv_migration",
    "pencil_transpose_reference",
    "plan_ragged_all_to_all",
    "plan_sparse_all_to_all", "plan_transpose",
    "predict_allgather", "predict_kv_migration", "predict_overlapped",
    "predict_ragged",
    "predict_reduce_scatter", "predict_sparse", "predict_transpose",
    "prime_factorization",
    "ragged_db_key",
    "reset_autotune_stats", "round_datatype", "round_message_masks",
    "run_pipelined",
    "set_cache_capacity", "set_plan_cache_capacity",
    "simulate_direct_alltoall", "simulate_direct_alltoallv",
    "simulate_factorized_allgather", "simulate_factorized_alltoall",
    "simulate_factorized_alltoallv", "simulate_factorized_reduce_scatter",
    "simulate_kv_migration", "simulate_pencil_transpose",
    "simulate_sparse_alltoallv", "sparse_exact_alltoallv",
    "sparse_traffic_stats",
    "torus_comm", "torus_rank", "unified_stats",
]
