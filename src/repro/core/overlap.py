"""Overlap engine: double-buffered round/compute software pipelining.

The paper's algorithm is a sequence of d per-dimension collectives glued by
double buffering; its §5 conclusion is that the win comes from tuning the
schedule to the machine.  This module is that tuning knob taken one step
further: a chunked, software-pipelined scheduler that interleaves the
dimension-wise *rounds* of independent payload chunks with an optional
per-chunk *compute stage*, so XLA's async collectives
(``all-to-all-start``/``-done``) can hide the rounds behind consumer
compute (MoE expert FFN, Ulysses attention) as well as behind each other.

Per chunk the stage list is::

    [round k0, ..., round k_{d-1}]  (+ [compute])  (+ [rev k'0, ..., rev k'_{d-1}])

and the engine emits stage ``s`` of chunk ``c`` at pipeline step ``t = c +
s``, deepest stage first within a step, i.e. the program order

    chunk c-2 reverse-round k' ; chunk c-1 compute ; chunk c round k ; ...

Chunk ``c``'s stages depend only on chunk ``c``'s earlier stages, so every
step's ops are mutually independent: adjacent in program order, they are
exactly what XLA's latency-hiding scheduler overlaps.  On a d-dim torus the
per-dimension rounds of different chunks use *different dimension links*,
giving up to d-fold link-level overlap on top of the comm/compute overlap.
Correctness is independent of scheduling — the interleaving only reorders
independent ops (property- and parity-tested against ``factorized`` and
``direct``).

Cost model: see ``tuning.predict_overlapped`` — perfect overlap divides
the bandwidth term by ~min(d, n_chunks) while stretching the latency term
by the pipeline fill ``(d + n - 1)/d``; ``tuning.choose_chunks`` picks the
argmin.
"""

from __future__ import annotations

import math
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np
from jax import lax

from .factorized import (
    _as_tuple,
    _axis_sizes,
    _factorized_impl,
    _skip_trivial,
    _warn_deprecated,
)


# ---------------------------------------------------------------------------
# Generic software-pipeline scheduler
# ---------------------------------------------------------------------------

def pipeline_order(n_chunks: int, n_stages: int):
    """Emission order of the software pipeline: yields ``(chunk, stage)``.

    Stage ``s`` of chunk ``c`` runs at step ``t = c + s``; within a step the
    deepest stage (oldest chunk) is emitted first, so a 2-chunk, 5-stage
    program (2 fwd rounds, compute, 2 rev rounds) reads

        c0.r0 | c0.r1 c1.r0 | c0.comp c1.r1 | c0.rev0 c1.comp | ...

    — chunk 1's forward round and chunk 0's reverse round sit *between* the
    two compute stages, which is the structure ``hlo_inspect
    .interleave_report`` verifies on the lowered program.
    """
    for t in range(n_chunks + n_stages - 1):
        for c in range(n_chunks):
            s = t - c
            if 0 <= s < n_stages:
                yield c, s


def run_pipelined(states: Sequence, stages: Sequence[Callable]):
    """Run every chunk state through every stage in pipelined program order.

    ``stages[s]`` is called as ``stages[s](state, chunk_index)`` and returns
    the new state.  Pure program-order transformation: the result is
    identical to running each chunk's stages back to back.
    """
    states = list(states)
    for c, s in pipeline_order(len(states), len(stages)):
        states[c] = stages[s](states[c], c)
    return states


# ---------------------------------------------------------------------------
# Per-round stage construction (the torus round schedule)
# ---------------------------------------------------------------------------

def _round_stages(names, sizes, variant, order):
    """One closure per round, operating on the d-dim block *view*
    (axes ``[dim d-1, ..., dim 0, *block]``, dim 0 fastest)."""
    d = len(sizes)
    pos = lambda m: d - 1 - m

    def natural(k):
        def stage(view, _c):
            return lax.all_to_all(view, names[k], split_axis=pos(k),
                                  concat_axis=pos(k), tiled=False)
        return stage

    def paper(k):
        def stage(view, _c):
            nb = view.ndim - d
            perm = ([pos(k)]
                    + [pos(m) for m in range(k + 1, d)]
                    + [pos(m) for m in range(k - 1, -1, -1)]
                    + [d + i for i in range(nb)])
            inv = tuple(int(i) for i in np.argsort(perm))
            out = view.transpose(perm)
            out = lax.all_to_all(out, names[k], split_axis=0, concat_axis=0,
                                 tiled=False)
            return out.transpose(inv)
        return stage

    if variant == "natural":
        return [natural(k) for k in order]
    if variant == "paper":
        return [paper(k) for k in order]
    raise ValueError(f"unknown variant {variant!r}")


def _check_order(order, d):
    order = tuple(order) if order is not None else tuple(range(d))
    if sorted(order) != list(range(d)):
        raise ValueError(f"round_order {order} is not a permutation of 0..{d-1}")
    return order


def _split_chunks(x, axis, n_chunks):
    """Split ``x`` along ``axis`` into the largest feasible number of equal
    chunks <= ``n_chunks`` (shrink until the axis size divides)."""
    size = x.shape[axis]
    n = max(1, min(n_chunks, size))
    while size % n:
        n -= 1
    step = size // n
    idx = [slice(None)] * x.ndim
    out = []
    for c in range(n):
        idx[axis] = slice(c * step, (c + 1) * step)
        out.append(x[tuple(idx)])
    return out


# ---------------------------------------------------------------------------
# The overlapped all-to-all
# ---------------------------------------------------------------------------

def _overlapped_impl(x, axis_names, *, n_chunks: int = 2,
                     variant: str = "natural", round_order=None,
                     compute_fn: Callable | None = None,
                     reverse: bool = False, reverse_round_order=None,
                     chunk_axis: int | None = None):
    """Chunked, software-pipelined factorized all-to-all with an optional
    per-chunk compute stage and reverse (combine) all-to-all.

    Args:
      x: local ``(p, *block)`` array, ``p`` = product of the named axis
        sizes; block ``i`` is destined for torus rank ``i``.
      axis_names: torus dimensions, fastest digit first.
      n_chunks: target chunk count (shrunk to a divisor of the chunked
        extent; 1 disables pipelining but still runs fwd/compute/reverse).
      variant: per-round formulation, "natural" (zero-copy) or "paper".
      round_order: forward round permutation (default ``range(d)``).
      compute_fn: optional ``f(chunk, chunk_index) -> chunk`` applied to
        each chunk *after* its forward rounds; must preserve the chunk's
        shape.  Called on the ``(p, *chunk_block)`` layout.
      reverse: append a second (combine-direction) all-to-all after the
        compute stage — the MoE dispatch/combine shape.
      reverse_round_order: round permutation for the reverse all-to-all
        (default: forward order reversed, so the pipeline drains the
        dimension links in the opposite order it filled them).
      chunk_axis: which axis of ``x`` (>= 1) to chunk.  Default: the
        trailing payload is flattened and chunked (the
        ``pipelined_all_to_all`` semantics).

    Returns ``(p, *block)`` with the same semantics as composing
    ``factorized_all_to_all`` (+ ``compute_fn`` + ``factorized_all_to_all``)
    on the whole payload — bit-exact, since chunks never interact.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    p = math.prod(dims)
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != prod(dims)={p} ({dims})")
    names, sizes = _skip_trivial(axis_names, dims)
    d = len(sizes)
    order = _check_order(round_order, d)
    rev_order = (tuple(reversed(order)) if reverse_round_order is None
                 else _check_order(reverse_round_order, d))

    # Fast path: nothing to pipeline and nothing to interleave.
    if compute_fn is None and not reverse:
        if d <= 1 or n_chunks <= 1 or x.ndim == 1:
            return _factorized_impl(x, axis_names, variant=variant,
                                    round_order=round_order)

    # ---- chunking ----
    if chunk_axis is None:
        payload = math.prod(x.shape[1:]) if x.ndim > 1 else 1
        flat = x.reshape(p, payload)
        chunks = _split_chunks(flat, 1, n_chunks if payload else 1)
    else:
        if not 1 <= chunk_axis < x.ndim:
            raise ValueError(f"chunk_axis {chunk_axis} out of range for "
                             f"rank-{x.ndim} operand")
        chunks = _split_chunks(x, chunk_axis, n_chunks)

    # ---- per-chunk stage list ----
    view_prefix = tuple(reversed(sizes))

    def to_view(chunk):
        return chunk.reshape(view_prefix + chunk.shape[1:])

    def to_blocks(view):
        return view.reshape((p,) + view.shape[d:])

    stages = list(_round_stages(names, sizes, variant, order))
    if compute_fn is not None:
        def compute_stage(view, c):
            return to_view(compute_fn(to_blocks(view), c))
        stages.append(compute_stage)
    if reverse:
        stages.extend(_round_stages(names, sizes, variant, rev_order))
    if not stages:                       # d == 0 and no compute/reverse
        return x

    views = run_pipelined([to_view(c) for c in chunks], stages)
    outs = [to_blocks(v) for v in views]
    if chunk_axis is None:
        out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
        return out.reshape((p,) + x.shape[1:])
    return outs[0] if len(outs) == 1 else \
        jnp.concatenate(outs, axis=chunk_axis)


def _overlapped_tiled_impl(x, axis_names, split_axis, concat_axis, *,
                           n_chunks: int = 2, variant: str = "natural",
                           round_order=None):
    """Tiled-semantics overlapped all-to-all.

    Drop-in for ``lax.all_to_all(..., tiled=True)`` /
    ``_factorized_tiled_impl`` — the MoE-dispatch and Ulysses re-shard
    form — with the payload chunked and the per-dimension rounds of
    different chunks interleaved in program order.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    p = math.prod(dims)
    if p == 1:
        return x
    S = x.shape[split_axis]
    if S % p:
        raise ValueError(f"split axis size {S} not divisible by p={p}")
    shape = x.shape
    xb = x.reshape(shape[:split_axis] + (p, S // p) + shape[split_axis + 1:])
    xb = jnp.moveaxis(xb, split_axis, 0)
    out = _overlapped_impl(xb, axis_names, n_chunks=n_chunks,
                           variant=variant, round_order=round_order)
    out = jnp.moveaxis(out, 0, concat_axis)
    sh = out.shape
    return out.reshape(sh[:concat_axis]
                       + (sh[concat_axis] * sh[concat_axis + 1],)
                       + sh[concat_axis + 2:])


# ---------------------------------------------------------------------------
# Deprecated free-function shims (see core.factorized for the policy): each
# builds-or-fetches an A2APlan and delegates, staying bit-exact with plan
# execution.  Internal call sites must use plans directly.
# ---------------------------------------------------------------------------


def overlapped_all_to_all(x, axis_names, *, n_chunks: int = 2,
                          variant: str = "natural", round_order=None,
                          compute_fn: Callable | None = None,
                          reverse: bool = False, reverse_round_order=None,
                          chunk_axis: int | None = None):
    """Deprecated: use ``plan_all_to_all(..., backend="overlap")
    .overlap`` (or ``.forward`` when there is no compute stage)."""
    _warn_deprecated("overlapped_all_to_all", "plan.overlap")
    from .plan import plan_all_to_all
    names = _as_tuple(axis_names)
    plan = plan_all_to_all(_axis_sizes(names), names, x.shape[1:], x.dtype,
                           backend="overlap", variant=variant,
                           round_order=round_order,
                           reverse_round_order=reverse_round_order,
                           n_chunks=max(1, n_chunks))
    if compute_fn is None and not reverse:
        return plan.forward(x)
    return plan.overlap(x, compute_fn, reverse=reverse,
                        chunk_axis=chunk_axis)


def overlapped_all_to_all_tiled(x, axis_names, split_axis, concat_axis, *,
                                n_chunks: int = 2, variant: str = "natural",
                                round_order=None):
    """Deprecated: use ``plan_all_to_all(..., backend="overlap").tiled``."""
    _warn_deprecated("overlapped_all_to_all_tiled", "plan.tiled")
    from .plan import plan_all_to_all
    names = _as_tuple(axis_names)
    plan = plan_all_to_all(_axis_sizes(names), names, None, x.dtype,
                           backend="overlap", variant=variant,
                           round_order=round_order,
                           n_chunks=max(1, n_chunks))
    return plan.tiled(x, split_axis, concat_axis)


def pipelined_all_to_all(x, axis_names, *, n_chunks: int = 2,
                         variant: str = "natural", round_order=None):
    """Deprecated: use ``plan_all_to_all(..., backend="pipelined")
    .forward`` — the chunk-interleaved schedule with no compute stage."""
    _warn_deprecated("pipelined_all_to_all", "plan.forward")
    from .plan import plan_all_to_all
    names = _as_tuple(axis_names)
    plan = plan_all_to_all(_axis_sizes(names), names, x.shape[1:], x.dtype,
                           backend="pipelined", variant=variant,
                           round_order=round_order,
                           n_chunks=max(1, n_chunks))
    return plan.forward(x)
