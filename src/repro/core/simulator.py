"""Pure-Python/numpy oracle of the paper's Algorithm 1 with *MPI semantics*.

This module models the MPI implementation (Listing 3) faithfully:

* a flat per-rank buffer of ``p`` blocks,
* the per-round *derived datatype* as an explicit list of block offsets plus
  a tiled extent (``MPI_Type_contiguous`` + ``MPI_Type_create_resized``),
* ``MPI_Alltoall`` with identical send/recv datatypes on the dimension-wise
  sub-communicators (groups of ranks differing only in torus coordinate k),
* the double-buffering parity scheme of Listing 3 (``sendbuf`` read in the
  first round, ``recvbuf`` written in the last round; one temporary buffer).

Conventions follow Algorithm 1 of the paper: dimension 0 is the
fastest-varying digit, with strides ``sigma(i) = prod(D[:i])`` and rounds
``k = 0, 1, ..., d-1``.  (Listing 1/3 use the mirrored MPI row-major
convention; the two are identical up to relabeling of the dimensions.)

The simulator is the correctness oracle for the JAX implementation and for
the paper's three worked examples (5x4, 2x3x4, 4x3x3x4) and Theorem 1's
communication-volume formula.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field


def strides(dims: tuple[int, ...]) -> tuple[int, ...]:
    """sigma(i) = prod(D[:i]); sigma(0) = 1 (dimension 0 varies fastest)."""
    out, acc = [], 1
    for d in dims:
        out.append(acc)
        acc *= d
    return tuple(out)


def rank_to_coords(rank: int, dims: tuple[int, ...]) -> tuple[int, ...]:
    """Digit decomposition: rank = sum_i O[i] * sigma(i)."""
    out = []
    for d in dims:
        out.append(rank % d)
        rank //= d
    return tuple(out)


def coords_to_rank(coords: tuple[int, ...], dims: tuple[int, ...]) -> int:
    sig = strides(dims)
    return sum(c * s for c, s in zip(coords, sig))


def round_datatype(dims: tuple[int, ...], k: int) -> tuple[list[int], int]:
    """The derived datatype for round ``k`` (one instance = one peer).

    Returns ``(positions, extent)``: block offsets of instance 0, in message
    order, and the tiled extent in blocks.  Instance ``j`` (peer ``j`` in the
    dimension-``k`` communicator) is ``positions`` shifted by ``j * extent``.

    This is the traversal
    ``S'_[sigma(k)][sigma(k+1)]...[sigma(d-1)] [D[k]][D[k+1]]...[D[d-1]]``
    of the paper: column-major over the not-yet-processed dimensions
    (index ``i_{k+1}`` slowest, ``i_{d-1}`` fastest), each innermost item a
    run of ``sigma(k)`` consecutive blocks.
    """
    d = len(dims)
    sig = strides(dims)
    uppers = list(range(k + 1, d))  # i_{k+1} slowest ... i_{d-1} fastest
    positions: list[int] = []
    for idx in itertools.product(*[range(dims[m]) for m in uppers]):
        base = sum(i * sig[m] for i, m in zip(idx, uppers))
        positions.extend(range(base, base + sig[k]))
    return positions, sig[k]


@dataclass
class VolumeCount:
    """Per-rank communication volume bookkeeping (Theorem 1)."""

    dims: tuple[int, ...]
    blocks_sent_per_round: list[int] = field(default_factory=list)

    @property
    def total_blocks_sent(self) -> int:
        return sum(self.blocks_sent_per_round)

    @property
    def theorem1_formula(self) -> int:
        d, p = len(self.dims), math.prod(self.dims)
        return d * p - sum(p // Dk for Dk in self.dims)


def simulate_factorized_alltoall(
    dims: tuple[int, ...],
    round_order: tuple[int, ...] | None = None,
) -> tuple[dict[int, list], VolumeCount]:
    """Run Algorithm 1 with MPI flat-buffer semantics for every rank.

    Block payloads are ``(source_rank, dest_rank)`` tuples.  Returns the
    final ``recvbuf`` of every rank plus the volume count.  Correct iff
    ``recv[r][i] == (i, r)`` for all ranks r and block indices i.
    """
    d = len(dims)
    p = math.prod(dims)
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    assert sorted(order) == list(range(d))

    send = {r: [(r, b) for b in range(p)] for r in range(p)}
    temp = {r: [None] * p for r in range(p)}
    recv = {r: [None] * p for r in range(p)}
    buffers = {"send": send, "temp": temp, "recv": recv}

    # Listing 3 buffer parity: out starts at sendbuf; in = tempbuf if d is
    # even else recvbuf, so that the final round receives into recvbuf.
    out_name = "send"
    in_name = "temp" if d % 2 == 0 else "recv"

    vol = VolumeCount(dims)
    coords = {r: rank_to_coords(r, dims) for r in range(p)}

    for k in order:
        positions, extent = round_datatype(dims, k)
        Dk = dims[k]
        outb, inb = buffers[out_name], buffers[in_name]
        # Communicator groups: ranks sharing all coords except digit k.
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])  # group rank = digit k
            assert len(members) == Dk
            # MPI_Alltoall: receiver g_r instance g_s <- sender g_s instance g_r
            staged = {}
            for g_r, r in enumerate(members):
                newbuf = [None] * p
                for g_s, s in enumerate(members):
                    for m, pos in enumerate(positions):
                        newbuf[pos + g_s * extent] = outb[s][pos + g_r * extent]
                staged[r] = newbuf
            for r, newbuf in staged.items():
                inb[r] = newbuf
        vol.blocks_sent_per_round.append((Dk - 1) * (p // Dk))
        # Buffer switch (Listing 3).
        if out_name == "send":
            if in_name == "recv":
                out_name, in_name = "recv", "temp"
            else:
                out_name, in_name = "temp", "recv"
        else:
            out_name, in_name = in_name, out_name

    final = buffers[out_name]  # after the swap, 'out' holds the last result
    return final, vol


def simulate_direct_alltoall(p: int) -> dict[int, list]:
    """Reference: the trivial direct all-to-all."""
    return {r: [(i, r) for i in range(p)] for r in range(p)}


def check_correct(dims: tuple[int, ...], round_order=None) -> bool:
    final, vol = simulate_factorized_alltoall(dims, round_order)
    p = math.prod(dims)
    ok = all(final[r] == [(i, r) for i in range(p)] for r in range(p))
    ok = ok and vol.total_blocks_sent == vol.theorem1_formula
    return ok


# ----------------------------------------------------------------------------
# The paper's three worked examples (§3).  Values corrected for obvious
# typos in the paper's tables: 5x4 round 1 row 3 prints "28" for 18;
# 2x3x4 round 2 row 2 prints "23" for 13; 4x3x3x4 round 0 rows print a
# duplicated "104" where 105/106 follow by the pattern.
# ----------------------------------------------------------------------------

PAPER_EXAMPLES = {
    (5, 4): {
        0: [[0, 5, 10, 15], [1, 6, 11, 16], [2, 7, 12, 17], [3, 8, 13, 18],
            [4, 9, 14, 19]],
        1: [[0, 1, 2, 3, 4], [5, 6, 7, 8, 9], [10, 11, 12, 13, 14],
            [15, 16, 17, 18, 19]],
    },
    (2, 3, 4): {
        0: [[0, 6, 12, 18, 2, 8, 14, 20, 4, 10, 16, 22],
            [1, 7, 13, 19, 3, 9, 15, 21, 5, 11, 17, 23]],
        1: [[0, 1, 6, 7, 12, 13, 18, 19],
            [2, 3, 8, 9, 14, 15, 20, 21],
            [4, 5, 10, 11, 16, 17, 22, 23]],
        2: [[0, 1, 2, 3, 4, 5], [6, 7, 8, 9, 10, 11],
            [12, 13, 14, 15, 16, 17], [18, 19, 20, 21, 22, 23]],
    },
}


def example_index_table(dims: tuple[int, ...], k: int) -> list[list[int]]:
    """R'[j] index sequences for round k — the paper's example tables."""
    positions, extent = round_datatype(dims, k)
    return [[pos + j * extent for pos in positions] for j in range(dims[k])]
