"""Pure-Python/numpy oracle of the paper's Algorithm 1 with *MPI semantics*.

This module models the MPI implementation (Listing 3) faithfully:

* a flat per-rank buffer of ``p`` blocks,
* the per-round *derived datatype* as an explicit list of block offsets plus
  a tiled extent (``MPI_Type_contiguous`` + ``MPI_Type_create_resized``),
* ``MPI_Alltoall`` with identical send/recv datatypes on the dimension-wise
  sub-communicators (groups of ranks differing only in torus coordinate k),
* the double-buffering parity scheme of Listing 3 (``sendbuf`` read in the
  first round, ``recvbuf`` written in the last round; one temporary buffer).

Conventions follow Algorithm 1 of the paper: dimension 0 is the
fastest-varying digit, with strides ``sigma(i) = prod(D[:i])`` and rounds
``k = 0, 1, ..., d-1``.  (Listing 1/3 use the mirrored MPI row-major
convention; the two are identical up to relabeling of the dimensions.)

The simulator is the correctness oracle for the JAX implementation and for
the paper's three worked examples (5x4, 2x3x4, 4x3x3x4) and Theorem 1's
communication-volume formula.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field


def strides(dims: tuple[int, ...]) -> tuple[int, ...]:
    """sigma(i) = prod(D[:i]); sigma(0) = 1 (dimension 0 varies fastest)."""
    out, acc = [], 1
    for d in dims:
        out.append(acc)
        acc *= d
    return tuple(out)


def rank_to_coords(rank: int, dims: tuple[int, ...]) -> tuple[int, ...]:
    """Digit decomposition: rank = sum_i O[i] * sigma(i)."""
    out = []
    for d in dims:
        out.append(rank % d)
        rank //= d
    return tuple(out)


def coords_to_rank(coords: tuple[int, ...], dims: tuple[int, ...]) -> int:
    sig = strides(dims)
    return sum(c * s for c, s in zip(coords, sig))


def round_datatype(dims: tuple[int, ...], k: int) -> tuple[list[int], int]:
    """The derived datatype for round ``k`` (one instance = one peer).

    Returns ``(positions, extent)``: block offsets of instance 0, in message
    order, and the tiled extent in blocks.  Instance ``j`` (peer ``j`` in the
    dimension-``k`` communicator) is ``positions`` shifted by ``j * extent``.

    This is the traversal
    ``S'_[sigma(k)][sigma(k+1)]...[sigma(d-1)] [D[k]][D[k+1]]...[D[d-1]]``
    of the paper: column-major over the not-yet-processed dimensions
    (index ``i_{k+1}`` slowest, ``i_{d-1}`` fastest), each innermost item a
    run of ``sigma(k)`` consecutive blocks.
    """
    d = len(dims)
    sig = strides(dims)
    uppers = list(range(k + 1, d))  # i_{k+1} slowest ... i_{d-1} fastest
    positions: list[int] = []
    for idx in itertools.product(*[range(dims[m]) for m in uppers]):
        base = sum(i * sig[m] for i, m in zip(idx, uppers))
        positions.extend(range(base, base + sig[k]))
    return positions, sig[k]


@dataclass
class VolumeCount:
    """Per-rank communication volume bookkeeping (Theorem 1)."""

    dims: tuple[int, ...]
    blocks_sent_per_round: list[int] = field(default_factory=list)

    @property
    def total_blocks_sent(self) -> int:
        return sum(self.blocks_sent_per_round)

    @property
    def theorem1_formula(self) -> int:
        d, p = len(self.dims), math.prod(self.dims)
        return d * p - sum(p // Dk for Dk in self.dims)


def simulate_factorized_alltoall(
    dims: tuple[int, ...],
    round_order: tuple[int, ...] | None = None,
) -> tuple[dict[int, list], VolumeCount]:
    """Run Algorithm 1 with MPI flat-buffer semantics for every rank.

    Block payloads are ``(source_rank, dest_rank)`` tuples.  Returns the
    final ``recvbuf`` of every rank plus the volume count.  Correct iff
    ``recv[r][i] == (i, r)`` for all ranks r and block indices i.
    """
    d = len(dims)
    p = math.prod(dims)
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    assert sorted(order) == list(range(d))

    send = {r: [(r, b) for b in range(p)] for r in range(p)}
    temp = {r: [None] * p for r in range(p)}
    recv = {r: [None] * p for r in range(p)}
    buffers = {"send": send, "temp": temp, "recv": recv}

    # Listing 3 buffer parity: out starts at sendbuf; in = tempbuf if d is
    # even else recvbuf, so that the final round receives into recvbuf.
    out_name = "send"
    in_name = "temp" if d % 2 == 0 else "recv"

    vol = VolumeCount(dims)
    coords = {r: rank_to_coords(r, dims) for r in range(p)}

    for k in order:
        positions, extent = round_datatype(dims, k)
        Dk = dims[k]
        outb, inb = buffers[out_name], buffers[in_name]
        # Communicator groups: ranks sharing all coords except digit k.
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])  # group rank = digit k
            assert len(members) == Dk
            # MPI_Alltoall: receiver g_r instance g_s <- sender g_s instance g_r
            staged = {}
            for g_r, r in enumerate(members):
                newbuf = [None] * p
                for g_s, s in enumerate(members):
                    for m, pos in enumerate(positions):
                        newbuf[pos + g_s * extent] = outb[s][pos + g_r * extent]
                staged[r] = newbuf
            for r, newbuf in staged.items():
                inb[r] = newbuf
        vol.blocks_sent_per_round.append((Dk - 1) * (p // Dk))
        # Buffer switch (Listing 3).
        if out_name == "send":
            if in_name == "recv":
                out_name, in_name = "recv", "temp"
            else:
                out_name, in_name = "temp", "recv"
        else:
            out_name, in_name = in_name, out_name

    final = buffers[out_name]  # after the swap, 'out' holds the last result
    return final, vol


def simulate_direct_alltoall(p: int) -> dict[int, list]:
    """Reference: the trivial direct all-to-all."""
    return {r: [(i, r) for i in range(p)] for r in range(p)}


# ----------------------------------------------------------------------------
# Ragged (MPI_Alltoallv) oracle.
#
# Träff et al.'s message-combining observation: the dimension-wise
# decomposition of Algorithm 1 never inspects block *contents*, only block
# *slots* — so it extends verbatim to non-uniform per-pair volumes.  Round k
# still moves whole slots between group members; raggedness lives entirely
# in the per-slot payload length, which makes the per-round composite
# message a concatenation of variable-length slot payloads (the isomorphic
# sparse collective).  The oracle below runs that slot movement with
# element-tagged payloads and count-weighted volume accounting; it is the
# correctness reference for ``core.ragged`` (both the bucketed JAX mode and
# the exact host mode).
# ----------------------------------------------------------------------------


@dataclass
class RaggedVolumeCount:
    """Per-round *element* volume bookkeeping for the ragged algorithm.

    ``elements_sent_per_round[k]`` sums, over all ranks in round ``k``, the
    payload elements that actually crossed a link (slots kept by their
    owner — group rank sending to itself — are free).  Under a bucket of
    ``b`` elements per slot the same movement ships
    ``slots_sent_per_round[k] * b`` elements; ``occupancy(b)`` is the
    useful fraction — the statistic the bucketed executor reports.
    """

    dims: tuple[int, ...]
    elements_sent_per_round: list[int] = field(default_factory=list)
    slots_sent_per_round: list[int] = field(default_factory=list)

    @property
    def total_elements_sent(self) -> int:
        return sum(self.elements_sent_per_round)

    @property
    def total_slots_sent(self) -> int:
        return sum(self.slots_sent_per_round)

    def occupancy(self, bucket: int) -> float:
        """Useful fraction of a bucketed execution's traffic: ragged
        elements over ``slots * bucket`` padded elements (1.0 when every
        slot carries exactly ``bucket`` elements)."""
        padded = self.total_slots_sent * bucket
        return self.total_elements_sent / padded if padded else 1.0


def _counts_matrix(counts, p: int):
    counts = [list(row) for row in counts]
    if len(counts) != p or any(len(row) != p for row in counts):
        raise ValueError(f"counts must be a {p}x{p} matrix")
    if any(c < 0 for row in counts for c in row):
        raise ValueError("counts must be non-negative")
    return counts


def simulate_factorized_alltoallv(
    dims: tuple[int, ...],
    counts,
    round_order: tuple[int, ...] | None = None,
) -> tuple[dict[int, list], RaggedVolumeCount]:
    """Run Algorithm 1 with MPI_Alltoallv semantics for every rank.

    ``counts[s][d]`` is the number of elements rank ``s`` sends to rank
    ``d``.  Slot ``(s, d)``'s payload is ``[(s, d, 0), ..., (s, d,
    counts[s][d]-1)]`` — element order within a pair must be preserved,
    exactly the MPI contract.  Returns the final per-rank slot lists plus
    the element-volume count.  Correct iff ``recv[r][i] == [(i, r, j) for
    j in range(counts[i][r])]`` for all ranks r and slots i (checked
    against :func:`simulate_direct_alltoallv` by the tests).
    """
    d = len(dims)
    p = math.prod(dims)
    counts = _counts_matrix(counts, p)
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    assert sorted(order) == list(range(d))

    send = {r: [[(r, b, j) for j in range(counts[r][b])] for b in range(p)]
            for r in range(p)}
    temp = {r: [None] * p for r in range(p)}
    recv = {r: [None] * p for r in range(p)}
    buffers = {"send": send, "temp": temp, "recv": recv}
    out_name = "send"
    in_name = "temp" if d % 2 == 0 else "recv"

    vol = RaggedVolumeCount(dims)
    coords = {r: rank_to_coords(r, dims) for r in range(p)}

    for k in order:
        positions, extent = round_datatype(dims, k)
        Dk = dims[k]
        outb, inb = buffers[out_name], buffers[in_name]
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        elems = slots = 0
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            assert len(members) == Dk
            staged = {}
            for g_r, r in enumerate(members):
                newbuf = [None] * p
                for g_s, s in enumerate(members):
                    for pos in positions:
                        slot = outb[s][pos + g_r * extent]
                        newbuf[pos + g_s * extent] = slot
                        if g_s != g_r:       # self-slots never cross a link
                            elems += len(slot)
                            slots += 1
                staged[r] = newbuf
            for r, newbuf in staged.items():
                inb[r] = newbuf
        vol.elements_sent_per_round.append(elems)
        vol.slots_sent_per_round.append(slots)
        if out_name == "send":
            if in_name == "recv":
                out_name, in_name = "recv", "temp"
            else:
                out_name, in_name = "temp", "recv"
        else:
            out_name, in_name = in_name, out_name

    return buffers[out_name], vol


def simulate_direct_alltoallv(counts) -> dict[int, list]:
    """Brute-force MPI_Alltoallv reference: a plain pairwise permutation."""
    p = len(counts)
    counts = _counts_matrix(counts, p)
    return {r: [[(i, r, j) for j in range(counts[i][r])] for i in range(p)]
            for r in range(p)}


def simulate_kv_migration(
    dims: tuple[int, ...],
    n_prefill: int,
    lengths,
    round_order: tuple[int, ...] | None = None,
) -> tuple[dict[int, list], RaggedVolumeCount]:
    """The KV-cache handoff oracle: an Alltoallv whose count matrix is
    non-zero only in the prefill->decode block.

    ``lengths`` maps ``(src, dst) -> rows`` (per-sequence KV lengths
    summed per placement pair); every source must be a prefill rank
    (``src < n_prefill``) and every destination a decode rank
    (``n_prefill <= dst < p``) — the block structure
    ``KVMigrationPlan.pair_counts`` enforces on the live path.  Delegates
    to :func:`simulate_factorized_alltoallv`, so correctness is the same
    MPI contract: ``recv[r][s] == [(s, r, j) for j in range(counts[s][r])]``.
    """
    p = math.prod(dims)
    n_prefill = int(n_prefill)
    if not 0 < n_prefill < p:
        raise ValueError(f"n_prefill {n_prefill} outside (0, p={p})")
    counts = [[0] * p for _ in range(p)]
    for (src, dst), n in lengths.items():
        src, dst, n = int(src), int(dst), int(n)
        if not 0 <= src < n_prefill:
            raise ValueError(f"migration source {src} is not a prefill "
                             f"rank (n_prefill={n_prefill})")
        if not n_prefill <= dst < p:
            raise ValueError(f"migration destination {dst} is not a decode "
                             f"rank (n_prefill={n_prefill}, p={p})")
        if n < 0:
            raise ValueError(f"negative count {n} for pair ({src}, {dst})")
        counts[src][dst] = n
    return simulate_factorized_alltoallv(dims, counts,
                                         round_order=round_order)


# ----------------------------------------------------------------------------
# Sparse (neighborhood) Alltoallv oracle.
#
# The second half of Träff et al.'s isomorphic-collectives observation:
# because round k's composite message to a peer is a fixed *slot set*
# whose contents are never inspected, the per-round neighborhood of
# non-empty exchanges is fully determined by the initial count matrix —
# a message whose slots all carry zero-count pairs can be skipped
# entirely without changing any delivered payload.  The oracle below
# runs the identical slot movement as ``simulate_factorized_alltoallv``
# but elides empty composite messages from the send schedule, counting
# what was combined and what was skipped; it is the correctness and
# stats reference for ``core.sparse`` (the jit kernel's skip masks, the
# exact sparse host mode, and ``SparseA2APlan.analyze``).
# ----------------------------------------------------------------------------


@dataclass
class SparseVolumeCount:
    """Per-round *message* bookkeeping for the sparse algorithm.

    Round ``k`` has ``p * (D[k] - 1)`` potential peer exchanges (every
    rank sends one composite message to each of its ``D[k] - 1``
    dimension-``k`` group peers; self-slots never cross a link).  An
    exchange whose combined payload is empty — every slot it would move
    carries a zero-count pair — is *skipped*; the rest are the
    *combined messages* actually sent.
    """

    dims: tuple[int, ...]
    exchanges_per_round: list[int] = field(default_factory=list)
    skipped_per_round: list[int] = field(default_factory=list)
    elements_sent_per_round: list[int] = field(default_factory=list)

    @property
    def total_exchanges(self) -> int:
        return sum(self.exchanges_per_round)

    @property
    def skipped_exchanges(self) -> int:
        return sum(self.skipped_per_round)

    @property
    def combined_messages(self) -> int:
        return self.total_exchanges - self.skipped_exchanges

    @property
    def skipped_rounds(self) -> int:
        """Rounds whose every peer exchange was empty (the whole round
        could be elided)."""
        return sum(1 for e, s in zip(self.exchanges_per_round,
                                     self.skipped_per_round)
                   if e > 0 and s == e)

    @property
    def skip_fraction(self) -> float:
        t = self.total_exchanges
        return self.skipped_exchanges / t if t else 0.0

    @property
    def total_elements_sent(self) -> int:
        return sum(self.elements_sent_per_round)


def simulate_sparse_alltoallv(
    dims: tuple[int, ...],
    counts,
    round_order: tuple[int, ...] | None = None,
) -> tuple[dict[int, list], SparseVolumeCount]:
    """Run Algorithm 1 with sparse-Alltoallv semantics for every rank.

    Identical slot movement and payload convention to
    :func:`simulate_factorized_alltoallv`, but each per-round composite
    message is first sized from the slots it would carry: empty messages
    are skipped (the receiver's slots materialize as the zero-length
    payloads the count matrix already implies), non-empty ones are
    counted as combined messages.  Correct iff the final buffers equal
    :func:`simulate_direct_alltoallv` — skipping may only ever elide
    messages that carry nothing.
    """
    d = len(dims)
    p = math.prod(dims)
    counts = _counts_matrix(counts, p)
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    assert sorted(order) == list(range(d))

    buf = {r: [[(r, b, j) for j in range(counts[r][b])] for b in range(p)]
           for r in range(p)}
    vol = SparseVolumeCount(dims)
    coords = {r: rank_to_coords(r, dims) for r in range(p)}

    for k in order:
        positions, extent = round_datatype(dims, k)
        Dk = dims[k]
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        exchanges = skipped = elems = 0
        staged = {}
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            assert len(members) == Dk
            for g_r, r in enumerate(members):
                newbuf = [None] * p
                for g_s, s in enumerate(members):
                    slots = [buf[s][pos + g_r * extent]
                             for pos in positions]
                    if g_s != g_r:
                        exchanges += 1
                        payload = sum(len(sl) for sl in slots)
                        if payload == 0:
                            # the skipped message: no slot crosses the
                            # link; the receiver's slots are the empty
                            # payloads the counts already promised
                            skipped += 1
                            slots = [[] for _ in positions]
                        else:
                            elems += payload
                    for pos, sl in zip(positions, slots):
                        newbuf[pos + g_s * extent] = sl
                staged[r] = newbuf
        for r, newbuf in staged.items():
            buf[r] = newbuf
        vol.exchanges_per_round.append(exchanges)
        vol.skipped_per_round.append(skipped)
        vol.elements_sent_per_round.append(elems)

    return buf, vol


def check_correct_sparse_alltoallv(dims, counts, round_order=None) -> bool:
    final, _ = simulate_sparse_alltoallv(dims, counts, round_order)
    want = simulate_direct_alltoallv(counts)
    p = math.prod(dims)
    return all(final[r] == want[r] for r in range(p))


def check_correct_alltoallv(dims, counts, round_order=None) -> bool:
    final, _ = simulate_factorized_alltoallv(dims, counts, round_order)
    want = simulate_direct_alltoallv(counts)
    p = math.prod(dims)
    return all(final[r] == want[r] for r in range(p))


# ----------------------------------------------------------------------------
# Dimension-wise gather-collective oracles (the TorusComm family).
#
# Once the per-dimension sub-communicators are explicit, a whole family of
# collectives falls out of the same d-stage machinery (Mortensen et al.'s
# advanced-MPI transposes, Träff et al.'s isomorphic collectives): an
# all-gather is d concatenating stages, a reduce-scatter d reducing/
# scattering stages.  The oracles below model both with MPI group
# semantics — group membership from torus coordinates, per-stage digit
# assignment from group rank, final placement from the package's fixed
# fastest-digit-first linearization — and are the correctness reference
# for ``core.comm``'s JAX implementations (``tests/device_scripts/
# check_comm.py``) and the paper's worked tori (5x4, 2x3x4).
# ----------------------------------------------------------------------------


def simulate_factorized_allgather(
    dims: tuple[int, ...],
    round_order: tuple[int, ...] | None = None,
) -> tuple[dict[int, list], VolumeCount]:
    """Run the d-stage dimension-wise all-gather for every rank.

    Each rank starts with one block (payload = its own rank id); stage
    ``k`` is an MPI_Allgather on the dimension-``k`` communicator — the
    contribution of group member ``j`` lands at digit-``k`` coordinate
    ``j`` of every member's buffer.  The final buffer is linearized by
    torus rank (digit 0 fastest).  Correct iff ``out[r] == list(range(p))``
    for every rank ``r``.

    Volume: stage ``k`` sends the ``prod(D_j, earlier j)`` blocks held so
    far to each of ``D[k]-1`` peers; the total telescopes to ``p - 1``
    blocks for *any* round order — all-gather has no combining win to
    factorize (unlike Theorem 1), the d-stage form wins on message count.
    """
    d = len(dims)
    p = math.prod(dims)
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    assert sorted(order) == list(range(d))

    coords = {r: rank_to_coords(r, dims) for r in range(p)}
    # buf[r]: {partial source coords (digit or None per dim) -> payload}
    buf: dict[int, dict] = {r: {(None,) * d: r} for r in range(p)}
    vol = VolumeCount(dims)

    for k in order:
        Dk = dims[k]
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        held = len(buf[0])
        staged = {}
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            assert len(members) == Dk
            merged = {}
            for g_s, s in enumerate(members):
                for key, payload in buf[s].items():
                    assert key[k] is None
                    merged[key[:k] + (g_s,) + key[k + 1:]] = payload
            for r in members:
                staged[r] = dict(merged)
        buf = staged
        vol.blocks_sent_per_round.append((Dk - 1) * held)

    out = {}
    for r in range(p):
        slots = [None] * p
        for key, payload in buf[r].items():
            slots[coords_to_rank(key, dims)] = payload
        out[r] = slots
    return out, vol


def simulate_factorized_reduce_scatter(
    dims: tuple[int, ...],
    round_order: tuple[int, ...] | None = None,
) -> tuple[dict[int, list], VolumeCount]:
    """Run the d-stage dimension-wise reduce-scatter for every rank.

    Rank ``s`` contributes one block per destination ``t`` with payload
    term ``(s, t)``; reduction is modeled as term concatenation (sorted at
    the end) so dropped, duplicated, or misrouted contributions are all
    visible.  Stage ``k`` is an MPI_Reduce_scatter on the dimension-``k``
    communicator: each member keeps (and reduces) the destinations whose
    digit ``k`` matches its own coordinate.  Correct iff ``out[r] ==
    [(s, r) for s in range(p)]`` for every rank ``r``.

    Volume: stage ``k`` ships the ``(D[k]-1)/D[k]`` fraction of the
    destination blocks still held (the held set shrinks ``D[k]``-fold per
    stage), so the per-rank total telescopes to ``p - 1`` blocks for any
    round order — the exact dual of the all-gather.  Like it, the d-stage
    form wins on the message count, not the volume.
    """
    d = len(dims)
    p = math.prod(dims)
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    assert sorted(order) == list(range(d))

    coords = {r: rank_to_coords(r, dims) for r in range(p)}
    # buf[r]: {destination rank -> list of (source, dest) payload terms}
    buf = {r: {t: [(r, t)] for t in range(p)} for r in range(p)}
    vol = VolumeCount(dims)

    for k in order:
        Dk = dims[k]
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        sent = 0
        staged = {}
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            assert len(members) == Dk
            for g_r, r in enumerate(members):
                new = {}
                for g_s, s in enumerate(members):
                    for t, terms in buf[s].items():
                        if coords[t][k] != g_r:
                            continue
                        new.setdefault(t, []).extend(terms)
                        if g_s != g_r:     # kept-by-owner blocks are free
                            sent += 1
                staged[r] = new
        buf = staged
        # `sent` sums over all ranks; VolumeCount is per rank (the stage
        # is symmetric, so the division is exact)
        vol.blocks_sent_per_round.append(sent // p)

    out = {}
    for r in range(p):
        assert set(buf[r]) == {r}, f"rank {r} kept foreign destinations"
        out[r] = sorted(buf[r][r])
    return out, vol


def check_correct(dims: tuple[int, ...], round_order=None) -> bool:
    final, vol = simulate_factorized_alltoall(dims, round_order)
    p = math.prod(dims)
    ok = all(final[r] == [(i, r) for i in range(p)] for r in range(p))
    ok = ok and vol.total_blocks_sent == vol.theorem1_formula
    return ok


# ----------------------------------------------------------------------------
# Pencil-transpose oracle (distributed-FFT re-shard).
#
# The global transpose of a pencil-decomposed FFT (Dalcin et al., arXiv
# 1804.09536) is exactly an all-to-all of *uniform* blocks: each rank
# splits its local pencil into p chunks along ``split_axis`` (chunk t
# destined for torus rank t) and concatenates the p received chunks
# source-major along ``concat_axis``.  The oracle below runs the paper's
# d dimension-wise rounds on element-tagged chunks, so both the routing
# (block t of rank r must land in slot r of rank t — Algorithm 1) and the
# pencil *index math* (which global elements end up where) are checked.
# ----------------------------------------------------------------------------


def _c_strides(shape: tuple[int, ...]) -> tuple[int, ...]:
    """Row-major (C-order) strides, matching the JAX kernels' reshape."""
    out = [1] * len(shape)
    for i in range(len(shape) - 2, -1, -1):
        out[i] = out[i + 1] * shape[i + 1]
    return tuple(out)


def _pencil_flat(coords, shape) -> int:
    return sum(c * s for c, s in zip(coords, _c_strides(shape)))


def pencil_transpose_reference(p: int, in_pencil: tuple[int, ...],
                               split_axis: int, concat_axis: int,
                               rank: int) -> list[int]:
    """Expected post-transpose local buffer of ``rank``: global flat ids
    (C-order over the global in-shape, ``concat_axis`` scaled by ``p``) in
    local out-pencil C-order.  Rank ``r`` starts with concat-block ``r``
    and ends with split-chunk ``r`` of the full concat axis."""
    in_pencil = tuple(in_pencil)
    sp = in_pencil[split_axis] // p
    global_shape = list(in_pencil)
    global_shape[concat_axis] *= p
    out_pencil = list(in_pencil)
    out_pencil[split_axis] = sp
    out_pencil[concat_axis] *= p
    ids = []
    for q in itertools.product(*[range(n) for n in out_pencil]):
        g = list(q)
        g[split_axis] += rank * sp
        ids.append(_pencil_flat(tuple(g), tuple(global_shape)))
    return ids


def simulate_pencil_transpose(
    dims: tuple[int, ...],
    in_pencil: tuple[int, ...],
    split_axis: int,
    concat_axis: int,
    round_order: tuple[int, ...] | None = None,
    contents: dict[int, list] | None = None,
) -> tuple[dict[int, list], VolumeCount]:
    """Run the d-round pencil transpose for every rank.

    Each rank holds a local pencil of shape ``in_pencil`` (rank ``r`` =
    concat-block ``r`` of the global array); the transpose splits
    ``split_axis`` into ``p`` chunks (chunk ``t`` -> torus rank ``t``) via
    the dimension-wise rounds and concatenates received chunks
    source-major along ``concat_axis`` — the tiled all-to-all semantics of
    ``core.factorized._factorized_tiled_impl``.

    ``contents`` optionally supplies each rank's local buffer (flat
    C-order payload list, e.g. a previous transpose's output, enabling
    round-trip composition); default is the identity labeling — global
    flat ids — for which correctness is ``out[r] ==
    pencil_transpose_reference(p, in_pencil, split_axis, concat_axis, r)``.

    Volume: uniform blocks of ``prod(in_pencil)/p`` elements, so round
    ``k`` sends ``(D[k]-1) * p/D[k]`` blocks per rank and the total obeys
    Theorem 1 exactly (returned as block counts in ``VolumeCount``).
    """
    d = len(dims)
    p = math.prod(dims)
    in_pencil = tuple(int(n) for n in in_pencil)
    if split_axis == concat_axis:
        raise ValueError("split_axis and concat_axis must differ")
    if in_pencil[split_axis] % p:
        raise ValueError(f"split axis size {in_pencil[split_axis]} not "
                         f"divisible by p={p}")
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    assert sorted(order) == list(range(d))
    sp = in_pencil[split_axis] // p
    block_shape = list(in_pencil)
    block_shape[split_axis] = sp
    block_shape = tuple(block_shape)
    global_shape = list(in_pencil)
    global_shape[concat_axis] *= p
    global_shape = tuple(global_shape)
    c = in_pencil[concat_axis]

    def identity_contents(r):
        ids = []
        for q in itertools.product(*[range(n) for n in in_pencil]):
            g = list(q)
            g[concat_axis] += r * c
            ids.append(_pencil_flat(tuple(g), global_shape))
        return ids

    # buf[r]: flat buffer of p chunk slots (slot t = chunk destined for
    # rank t), exactly the (p, *block) form of the tiled kernel.  The
    # rounds below are simulate_factorized_alltoall's slot movement with
    # chunk payloads, so final slot s = the chunk received from source s.
    buf: dict[int, list] = {}
    for r in range(p):
        flat = contents[r] if contents is not None else identity_contents(r)
        if len(flat) != math.prod(in_pencil):
            raise ValueError(f"rank {r} contents length {len(flat)} != "
                             f"prod(in_pencil)={math.prod(in_pencil)}")
        chunks = [[] for _ in range(p)]
        for q, payload in zip(
                itertools.product(*[range(n) for n in in_pencil]), flat):
            chunks[q[split_axis] // sp].append(payload)
        buf[r] = chunks

    coords = {r: rank_to_coords(r, dims) for r in range(p)}
    vol = VolumeCount(dims)
    for k in order:
        positions, extent = round_datatype(dims, k)
        Dk = dims[k]
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(x for i, x in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        staged = {}
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            assert len(members) == Dk
            for g_r, r in enumerate(members):
                new = [None] * p
                for g_s, s in enumerate(members):
                    for pos in positions:
                        new[pos + g_s * extent] = buf[s][pos + g_r * extent]
                staged[r] = new
        buf = staged
        vol.blocks_sent_per_round.append((Dk - 1) * (p // Dk))

    # Assemble: the chunk in slot s fills concat positions [s*c, (s+1)*c)
    # of the out pencil (source-major concatenation).
    out_pencil = list(block_shape)
    out_pencil[concat_axis] = c * p
    out = {}
    for r in range(p):
        res = []
        for q in itertools.product(*[range(n) for n in out_pencil]):
            s, j = divmod(q[concat_axis], c)
            b = list(q)
            b[concat_axis] = j
            res.append(buf[r][s][_pencil_flat(tuple(b), block_shape)])
        out[r] = res
    return out, vol


def check_correct_pencil_transpose(dims, in_pencil, split_axis, concat_axis,
                                   round_order=None) -> bool:
    """True iff the d-round pencil transpose delivers exactly the expected
    re-shard on every rank, the round-trip (transpose then inverse
    transpose) is the identity, and the block volume obeys Theorem 1."""
    p = math.prod(dims)
    out, vol = simulate_pencil_transpose(dims, in_pencil, split_axis,
                                         concat_axis, round_order)
    ok = all(out[r] == pencil_transpose_reference(p, in_pencil, split_axis,
                                                  concat_axis, r)
             for r in range(p))
    ok = ok and vol.total_blocks_sent == vol.theorem1_formula
    sp = in_pencil[split_axis] // p
    out_pencil = list(in_pencil)
    out_pencil[split_axis] = sp
    out_pencil[concat_axis] *= p
    back, _ = simulate_pencil_transpose(dims, tuple(out_pencil), concat_axis,
                                        split_axis, round_order,
                                        contents=out)
    c = in_pencil[concat_axis]
    g_shape = list(in_pencil)
    g_shape[concat_axis] *= p
    for r in range(p):
        ids = []
        for q in itertools.product(*[range(n) for n in in_pencil]):
            g = list(q)
            g[concat_axis] += r * c
            ids.append(_pencil_flat(tuple(g), tuple(g_shape)))
        if back[r] != ids:
            return False
    return ok


# ----------------------------------------------------------------------------
# The paper's three worked examples (§3).  Values corrected for obvious
# typos in the paper's tables: 5x4 round 1 row 3 prints "28" for 18;
# 2x3x4 round 2 row 2 prints "23" for 13; 4x3x3x4 round 0 rows print a
# duplicated "104" where 105/106 follow by the pattern.
# ----------------------------------------------------------------------------

PAPER_EXAMPLES = {
    (5, 4): {
        0: [[0, 5, 10, 15], [1, 6, 11, 16], [2, 7, 12, 17], [3, 8, 13, 18],
            [4, 9, 14, 19]],
        1: [[0, 1, 2, 3, 4], [5, 6, 7, 8, 9], [10, 11, 12, 13, 14],
            [15, 16, 17, 18, 19]],
    },
    (2, 3, 4): {
        0: [[0, 6, 12, 18, 2, 8, 14, 20, 4, 10, 16, 22],
            [1, 7, 13, 19, 3, 9, 15, 21, 5, 11, 17, 23]],
        1: [[0, 1, 6, 7, 12, 13, 18, 19],
            [2, 3, 8, 9, 14, 15, 20, 21],
            [4, 5, 10, 11, 16, 17, 22, 23]],
        2: [[0, 1, 2, 3, 4, 5], [6, 7, 8, 9, 10, 11],
            [12, 13, 14, 15, 16, 17], [18, 19, 20, 21, 22, 23]],
    },
}


def example_index_table(dims: tuple[int, ...], k: int) -> list[list[int]]:
    """R'[j] index sequences for round k — the paper's example tables."""
    positions, extent = round_datatype(dims, k)
    return [[pos + j * extent for pos in positions] for j in range(dims[k])]
