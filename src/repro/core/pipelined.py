"""Software-pipelined factorized all-to-all — compatibility facade.

The chunk-interleaved scheduler that used to live here has been absorbed
into the general overlap engine (``core.overlap``), which adds arbitrary
``round_order``, per-chunk compute stages, reverse (combine) rounds, and
tiled semantics.  ``pipelined_all_to_all`` remains the no-compute-stage
specialization and is re-exported here for existing callers; like every
legacy free function it is now a ``DeprecationWarning`` shim over
``core.plan.plan_all_to_all(..., backend="pipelined").forward``.

``choose_chunks`` now delegates to the tuning model's
``predict_overlapped``, which prices the factorized bandwidth term
``(D_k - 1) * (p / D_k) * block_bytes`` per round — consistent with
``tuning.predict_factorized`` — instead of the direct-algorithm
``(p - 1) * block_bytes`` the old local model used.
"""

from __future__ import annotations

from .dims import dims_create
from .overlap import overlapped_all_to_all, pipelined_all_to_all
from .tuning import LinkModel, resolve_links
from .tuning import choose_chunks as _choose_chunks

__all__ = ["choose_chunks", "overlapped_all_to_all", "pipelined_all_to_all"]


def choose_chunks(p: int, d: int, block_bytes: float,
                  link: LinkModel, max_chunks: int = 4, *,
                  links=None) -> int:
    """Pick n_chunks minimizing the overlapped alpha-beta estimate for a
    d-way factorization of ``p`` (legacy signature; see
    ``tuning.choose_chunks`` for the native per-axis form).

    ``link`` prices every axis uniformly; ``links=`` (a length-d
    sequence) overrides per axis — e.g. the measured fits recorded by
    ``core.autotune``.  Both spellings merge in ``tuning.resolve_links``,
    the single link-plumbing helper.
    """
    dims = dims_create(p, d)
    return _choose_chunks(dims,
                          resolve_links(link if links is None else links,
                                        dims),
                          block_bytes, max_chunks=max_chunks)
