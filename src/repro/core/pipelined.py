"""Beyond-paper optimization: software-pipelined factorized all-to-all.

The paper's rounds are strictly sequential: round k+1 cannot start until
round k's collective has fully completed, because the composite blocks of
round k+1 contain data received in round k.  On a one-ported network this
is optimal.  TPU ICI is *multi-ported*: each torus dimension has its own
links, and XLA's async collectives (``all-to-all-start``/``-done``) let
independent collectives overlap.

We therefore split the block payload into ``n_chunks`` independent chunks
and interleave the per-chunk round schedules round-robin:

    chunk0.round0; chunk1.round0; chunk0.round1; chunk1.round1; ...

Chunk c's round k+1 depends only on chunk c's round k, so chunk c+1's
round k can run concurrently with chunk c's round k+1 — on a d-dim torus
these use *different dimension links*, giving up to d-fold link-level
overlap the paper's formulation leaves idle.  Emitting the collectives in
this interleaved program order lets XLA's latency-hiding scheduler form
the overlap; correctness is independent of scheduling.

Cost model: perfect overlap divides the bandwidth term by ~min(d, chunks)
while adding (chunks-1) extra per-round latencies — profitable for large
payloads, counterproductive for tiny ones (`choose_chunks`).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from .factorized import factorized_all_to_all, _as_tuple, _axis_sizes
from .tuning import LinkModel


def pipelined_all_to_all(x, axis_names, *, n_chunks: int = 2,
                         variant: str = "natural"):
    """Chunked-and-interleaved factorized all-to-all.

    ``x``: ``(p, *block)``; the block payload (trailing axes, flattened) is
    split into ``n_chunks`` equal chunks.  Interleaves the d rounds of the
    per-chunk schedules so independent collectives are adjacent in program
    order.  Result identical to ``factorized_all_to_all``.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    d = len([s for s in dims if s > 1])
    p = math.prod(dims)
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != p={p}")
    payload = math.prod(x.shape[1:]) if x.ndim > 1 else 1
    n_chunks = max(1, min(n_chunks, payload))
    while payload % n_chunks:
        n_chunks -= 1
    if n_chunks == 1 or d <= 1:
        return factorized_all_to_all(x, axis_names, variant=variant)

    flat = x.reshape(p, payload)
    chunks = [flat[:, i * (payload // n_chunks):(i + 1) * (payload // n_chunks)]
              for i in range(n_chunks)]
    # Interleave: emit chunk c's round k right after chunk c-1's round k.
    # We realize this by running the full per-chunk schedule but relying on
    # program-order interleaving of the emitted collectives: build each
    # chunk's rounds lazily, advancing all chunks one round at a time.
    states = chunks
    # Reuse the internal round structure by calling the single-round helper.
    from . import factorized as _f
    views = []
    block_shapes = [(payload // n_chunks,)] * n_chunks
    names, sizes = _f._skip_trivial(axis_names, dims)
    for c in range(n_chunks):
        views.append(states[c].reshape(tuple(reversed(sizes))
                                       + block_shapes[c]))
    import jax.lax as lax
    for k in range(len(sizes)):
        ax = len(sizes) - 1 - k
        for c in range(n_chunks):
            views[c] = lax.all_to_all(views[c], names[k], split_axis=ax,
                                      concat_axis=ax, tiled=False)
    outs = [v.reshape(p, payload // n_chunks) for v in views]
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(x.shape)


def choose_chunks(p: int, d: int, block_bytes: float,
                  link: LinkModel, max_chunks: int = 4) -> int:
    """Pick n_chunks minimizing the overlapped alpha-beta estimate."""
    best_n, best_t = 1, float("inf")
    for n in range(1, max_chunks + 1):
        bw_term = (p - 1) * block_bytes / link.bandwidth
        overlap = min(d, n)
        t = (d + n - 1) * link.alpha + d * bw_term / overlap
        if t < best_t:
            best_n, best_t = n, t
    return best_n
