"""Sparse neighborhood Alltoallv on the factorized torus.

``core.ragged`` extends the paper's Algorithm 1 to non-uniform volumes
but still executes every dimension-wise round *densely*: each device
exchanges a padded bucket window with all ``D[k] - 1`` group peers per
round even when the traffic matrix is mostly empty (dropless MoE at low
router occupancy).  Träff et al.'s message-combining algorithms for
isomorphic sparse collectives (arXiv 1606.07676) observe that because
the rounds move fixed slot *sets* without inspecting contents, the
per-round neighborhood of non-empty exchanges is fully determined by
the initial ``p x p`` count matrix — which every device already holds
after the ragged counts phase.  This module is that sparse family:

* **message masks** (:func:`round_message_masks`) — plan-time symbolic
  slot tracking.  For each executed round ``k`` and peer offset
  ``delta`` (group digit distance), the ``(p, p)`` boolean mask of
  *original* count-matrix cells whose payload any rank's composite
  message at that (round, delta) lane would carry.  A lane is empty —
  skippable by every rank simultaneously — iff no masked cell is
  non-zero.

* **bucketed sparse rounds** (``_sparse_rounds_impl``) — the jit path.
  Each dense round is decomposed into its ``D[k] - 1`` peer lanes
  (``lax.ppermute`` of the bucket windows destined ``delta`` hops along
  the dimension), and each lane is wrapped in a ``lax.cond`` on the
  *replicated* predicate ``any(matrix > 0 & mask)``.  The predicate is
  identical on every device (the counts phase replicates the matrix),
  so all devices take the same branch — SPMD-safe skipping with no
  per-device divergence.  Skipped lanes leave the receiver's windows
  zero (the double-buffer output is zero-initialized per round), which
  is exact because an empty lane's windows carry only zero-count pairs'
  padding.  The bucket double-buffer bound of the dense path is kept:
  one input and one (zeroed) output view per round.

* **exact sparse** (:func:`sparse_exact_alltoallv`) — the host/debug
  path mirroring ``ragged.exact_alltoallv`` at per-(sender, peer)
  message granularity: a composite message whose slots are all empty is
  elided from the round's send schedule and counted as skipped.  This
  is the finest skipping the algorithm admits (the jit path's lane
  predicates are the SPMD-safe coarsening of it) and the path that
  realizes the acceptance bound: at <=10% occupancy well over half the
  per-round peer exchanges vanish.

Contract (relaxation vs. ragged): receivers may rely only on rows
``recv[i, :recv_counts[i]]``; window rows beyond the count are
*unspecified* (zeros when the carrying exchange was skipped, the
sender's padding otherwise).  Under uniform non-zero counts nothing is
ever skipped and the bucketed sparse path is bit-exact with the dense
ragged path, padding included.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .factorized import _as_tuple, _skip_trivial
from .ragged import (_counts_matrix_impl, _pad_to_bucket,
                     _recv_counts_from_matrix)
from .simulator import (SparseVolumeCount, rank_to_coords, round_datatype,
                        simulate_sparse_alltoallv)


# ---------------------------------------------------------------------------
# Plan-time neighborhood analysis
# ---------------------------------------------------------------------------


def round_message_masks(dims, round_order=None):
    """Symbolic slot tracking -> per-(round, delta) count-matrix masks.

    Args:
      dims: *active* torus factors (all > 1), fastest digit first.
      round_order: executed permutation of ``range(d)``.

    Returns a list aligned with the executed order; entry ``e`` is a
    boolean ``(dims[order[e]] - 1, p, p)`` array whose ``[delta - 1]``
    slice marks every original ``(src, dst)`` cell carried by *some*
    rank's composite message to its ``+delta`` group peer in that round.
    ``matrix[mask[delta - 1]].sum() == 0`` iff every such message is
    empty — the jit path's skip predicate for that lane.
    """
    dims = tuple(int(s) for s in dims)
    if any(s < 2 for s in dims):
        raise ValueError(f"dims must be active factors (all > 1), "
                         f"got {dims} — drop trivial axes first")
    d = len(dims)
    p = math.prod(dims)
    order = tuple(round_order) if round_order is not None \
        else tuple(range(d))
    if sorted(order) != list(range(d)):
        raise ValueError(f"round_order {order} is not a permutation "
                         f"of 0..{d - 1}")

    # owner[r][b] = the original (src, dst) pair whose payload currently
    # sits in slot b of rank r's buffer; movement mirrors the simulator.
    owner = {r: [(r, b) for b in range(p)] for r in range(p)}
    coords = {r: rank_to_coords(r, dims) for r in range(p)}
    out = []
    for k in order:
        positions, extent = round_datatype(dims, k)
        Dk = dims[k]
        masks = np.zeros((Dk - 1, p, p), dtype=bool)
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        staged = {}
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            for g_r, r in enumerate(members):
                newbuf = [None] * p
                for g_s, s in enumerate(members):
                    if g_s != g_r:
                        delta = (g_r - g_s) % Dk
                        for pos in positions:
                            src, dst = owner[s][pos + g_r * extent]
                            masks[delta - 1, src, dst] = True
                    for pos in positions:
                        newbuf[pos + g_s * extent] = \
                            owner[s][pos + g_r * extent]
                staged[r] = newbuf
        for r, newbuf in staged.items():
            owner[r] = newbuf
        out.append(masks)
    return out


def sparse_traffic_stats(dims, counts, round_order=None) -> dict:
    """Host-side traffic analysis of a concrete count matrix.

    Runs the :mod:`core.simulator` sparse oracle (slot movement + skip
    accounting, no payload) and flattens the result into the stats dict
    ``SparseA2APlan.describe()`` reports: density (non-zero fraction of
    the count matrix), per-message skip accounting, and the number of
    whole rounds whose every exchange was empty.
    """
    counts = np.asarray(counts, dtype=np.int64)
    p = math.prod(tuple(int(s) for s in dims))
    _, vol = simulate_sparse_alltoallv(tuple(dims), counts.tolist(),
                                       round_order)
    nnz = int(np.count_nonzero(counts))
    return {
        "density": nnz / float(p * p),
        "total_exchanges": vol.total_exchanges,
        "skipped_exchanges": vol.skipped_exchanges,
        "combined_messages": vol.combined_messages,
        "skipped_rounds": vol.skipped_rounds,
        "skip_fraction": vol.skip_fraction,
        "elements_sent": vol.total_elements_sent,
    }


# ---------------------------------------------------------------------------
# Bucketed execution mode (jit path)
# ---------------------------------------------------------------------------


def _sparse_rounds_impl(x, matrix, *, axis_names, dims, order, masks):
    """The d sparse rounds on bucket-padded windows.

    Each dense round-``k`` exchange (``lax.all_to_all`` on block-view
    axis ``pos(k)``) is decomposed into its ``D[k] - 1`` peer lanes: the
    lane at offset ``delta`` permutes the window slice destined for the
    ``+delta`` group peer (``ppermute`` with ``i -> i + delta``), guarded
    by a ``lax.cond`` on the lane's replicated emptiness predicate.  The
    self lane (``delta = 0``) is a local copy.  ``matrix`` is the
    replicated ``(p, p)`` int32 counts matrix; ``masks`` aligns with the
    executed ``order`` (see :func:`round_message_masks`).
    """
    axis_names = _as_tuple(axis_names)
    names, sizes = _skip_trivial(axis_names, tuple(dims))
    d = len(sizes)
    p = math.prod(tuple(dims))
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != prod(dims)={p}")
    if d == 0:
        return x
    block = x.shape[1:]
    A = x.reshape(tuple(reversed(sizes)) + block)
    pos = lambda m: d - 1 - m  # array axis holding torus dimension m
    nonzero = matrix > 0

    for e, k in enumerate(order):
        # named_scope labels each round (and its peer lanes) in device
        # profiles — free at runtime, visible in jax.profiler traces.
        with jax.named_scope(f"sparse_round[{names[k]}]"):
            Dk = sizes[k]
            ax = pos(k)
            me = lax.axis_index(names[k])
            out = jnp.zeros_like(A)
            keep = lax.dynamic_slice_in_dim(A, me, 1, ax)
            out = lax.dynamic_update_slice_in_dim(out, keep, me, ax)
            for delta in range(1, Dk):
                mask = jnp.asarray(masks[e][delta - 1])
                pred = jnp.any(nonzero & mask)
                perm = [(i, (i + delta) % Dk) for i in range(Dk)]

                def lane(o, A=A, me=me, delta=delta, Dk=Dk, ax=ax,
                         perm=perm, name=names[k]):
                    piece = lax.dynamic_slice_in_dim(
                        A, (me + delta) % Dk, 1, ax)
                    got = lax.ppermute(piece, name, perm)
                    return lax.dynamic_update_slice_in_dim(
                        o, got, (me - delta) % Dk, ax)

                with jax.named_scope(f"lane[delta={delta}]"):
                    out = lax.cond(pred, lane, lambda o: o, out)
            A = out

    return A.reshape(x.shape)


def _sparse_bucketed_impl(x, send_counts, *, plan, reverse: bool = False):
    """Fixed-shape sparse all-to-all: counts phase + skippable rounds.

    Same signature and return convention as ``ragged._bucketed_impl``
    (``(recv, recv_counts)``), with the relaxed window contract from the
    module docstring: rows beyond ``recv_counts[i]`` are unspecified.
    """
    p = plan.p
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != p={p}")
    matrix = _counts_matrix_impl(send_counts, plan.counts_plan)
    recv_counts = _recv_counts_from_matrix(matrix, plan.axis_names)
    padded = _pad_to_bucket(x, plan.bucket)
    order = plan.reverse_round_order if reverse else plan.round_order
    masks = plan._masks_rev if reverse else plan._masks_fwd
    out = _sparse_rounds_impl(padded, matrix, axis_names=plan.axis_names,
                              dims=plan.dims, order=order, masks=masks)
    return out, recv_counts


# ---------------------------------------------------------------------------
# Exact sparse mode (host/debug path)
# ---------------------------------------------------------------------------


def sparse_exact_alltoallv(rows, dims, round_order=None):
    """Exact sparse Alltoallv over the torus — host/debug path.

    Identical delivered payloads to ``ragged.exact_alltoallv`` (the MPI
    contract: ``recv[r][s]`` is what ``s`` addressed to ``r``), but each
    round's send schedule contains only the *non-empty* composite
    messages: a message whose slots all carry zero rows is elided and
    counted, at per-(sender, peer) granularity.  Skipped messages'
    slots materialize on the receiver as the zero-length payloads the
    phase-one count matrix already promised (metadata only — no payload
    crosses the link).

    Returns ``(recv, counts, vol)`` with ``vol`` a
    :class:`~repro.core.simulator.SparseVolumeCount`.
    """
    dims = tuple(int(s) for s in dims)
    d = len(dims)
    p = math.prod(dims)
    if len(rows) != p or any(len(per_dst) != p for per_dst in rows):
        raise ValueError(f"rows must be a {p}x{p} nested list")
    order = tuple(round_order) if round_order is not None \
        else tuple(range(d))
    if sorted(order) != list(range(d)):
        raise ValueError(f"round_order {order} is not a permutation "
                         f"of 0..{d - 1}")

    counts = [[int(np.shape(rows[s][t])[0]) for t in range(p)]
              for s in range(p)]

    buf = {r: [np.asarray(rows[r][t]) for t in range(p)] for r in range(p)}
    coords = {r: rank_to_coords(r, dims) for r in range(p)}
    vol = SparseVolumeCount(dims)
    for k in order:
        positions, extent = round_datatype(dims, k)
        Dk = dims[k]
        groups: dict[tuple, list[int]] = {}
        for r in range(p):
            key = tuple(c for i, c in enumerate(coords[r]) if i != k)
            groups.setdefault(key, []).append(r)
        exchanges = skipped = elems = 0
        staged = {}
        for members in groups.values():
            members.sort(key=lambda r: coords[r][k])
            for g_r, r in enumerate(members):
                newbuf = [None] * p
                for g_s, s in enumerate(members):
                    slots = [buf[s][pos + g_r * extent]
                             for pos in positions]
                    if g_s != g_r:
                        exchanges += 1
                        payload = sum(int(np.shape(sl)[0]) for sl in slots)
                        if payload == 0:
                            # elided message: reconstruct the empty slots
                            # from sender-side metadata (shape/dtype), the
                            # host analogue of skipping the MPI send
                            skipped += 1
                            slots = [sl[:0] for sl in slots]
                        else:
                            elems += payload
                    for pos, sl in zip(positions, slots):
                        newbuf[pos + g_s * extent] = sl
                staged[r] = newbuf
        for r, newbuf in staged.items():
            buf[r] = newbuf
        vol.exchanges_per_round.append(exchanges)
        vol.skipped_per_round.append(skipped)
        vol.elements_sent_per_round.append(elems)

    recv = [[buf[r][s] for s in range(p)] for r in range(p)]
    for r in range(p):
        for s in range(p):
            if np.shape(recv[r][s])[0] != counts[s][r]:
                raise AssertionError(
                    f"sparse alltoallv postcondition violated at "
                    f"recv[{r}][{s}]")
    return recv, counts, vol


__all__ = [
    "round_message_masks",
    "sparse_exact_alltoallv",
    "sparse_traffic_stats",
]
