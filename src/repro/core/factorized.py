"""Factorized (torus) all-to-all — Algorithm 1 of the paper, in JAX.

The kernels here (``_direct_impl``, ``_factorized_impl`` and their tiled
forms) are executed through ``core.plan.A2APlan``, the cached plan-object
API; the public free functions at the bottom are deprecation shims that
build-or-fetch a plan and delegate.

These functions run *inside* ``jax.shard_map`` over a mesh whose axes play
the role of the torus dimensions (the Cartesian communicator).  The local
operand is an array of ``p`` blocks; block ``i`` is destined for the device
with *torus rank* ``i``, where

    rank = sum_i coords[axis_names[i]] * sigma(i),   sigma(i) = prod(D[:i])

i.e. ``axis_names[0]`` is the fastest-varying digit (Algorithm 1's
dimension 0).  The equivalent single-collective form is
``lax.all_to_all(x, tuple(reversed(axis_names)), 0, 0)`` (JAX linearizes
tuple axis names with the first name most significant).

Two variants are provided:

* ``variant="natural"`` — the TPU-native zero-copy formulation.  The local
  buffer is *viewed* as a d-dimensional array of blocks (a reshape: pure
  metadata) and round ``k`` is a single ``lax.all_to_all`` splitting and
  concatenating **in place** along the digit-``k`` axis.  No transposes at
  all; the only data movement is the collectives themselves.  This relies
  on a property the paper cannot use (MPI datatypes fix a *flat* buffer
  layout, forcing the column-major composite construction): inside a
  multidimensional view, *any* within-message enumeration order cancels
  between the identical send and receive traversals, so the natural axis
  order is as correct as the paper's column-major order.  Proof sketch:
  for every message slot ``m``, receiver position ``tau(a, m)`` receives
  sender position ``tau(j, m)``; the induced state transformation depends
  only on ``tau``'s peer digit, not on the slot enumeration.  This is
  property-tested against the MPI-faithful simulator and the direct
  collective.

* ``variant="paper"`` — the literal Algorithm 1 traversal: before round
  ``k`` the block view is transposed to
  ``[dim k | dim k+1 ... dim d-1 | dim k-1 ... dim 0]`` (peer axis leading,
  column-major over unprocessed dimensions, natural over processed ones —
  exactly ``S'_[sigma(k)][sigma(k+1)]...[D[k]][D[k+1]]...``), the
  collective splits axis 0, and the inverse transpose restores the layout.
  XLA cancels the adjacent inverse transposes, recovering the natural
  variant's HLO; verified structurally in ``tests/test_zero_copy.py``.

Theorem 1 cost: round ``k`` moves ``(D[k]-1)/D[k]`` of the ``p`` blocks, so
the factorized algorithm sends ``d*p - sum_k p/D[k]`` blocks per device vs.
``p - 1`` for the direct algorithm, in exchange for ``D[k]``-fold message
aggregation per round and dimension-local (single-torus-axis) traffic.
"""

from __future__ import annotations

import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

Variant = str  # "natural" | "paper"


def _warn_deprecated(old: str, new: str) -> None:
    """The free functions below are legacy shims over ``core.plan``."""
    warnings.warn(
        f"repro.core.{old} is deprecated; build a plan once via "
        f"repro.core.plan.plan_all_to_all(...) and call {new} on it",
        DeprecationWarning, stacklevel=3)


def _axis_sizes(axis_names: tuple[str, ...]) -> tuple[int, ...]:
    return tuple(lax.axis_size(n) for n in axis_names)


def _as_tuple(axis_names) -> tuple[str, ...]:
    return (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)


def _skip_trivial(axis_names, dims):
    """Size-1 torus dimensions are no-op rounds; drop them."""
    kept = [(n, s) for n, s in zip(axis_names, dims) if s > 1]
    if not kept:
        return (), ()
    names, sizes = zip(*kept)
    return tuple(names), tuple(sizes)


def _direct_impl(x, axis_names):
    """Baseline: one collective over the full (product) communicator."""
    axis_names = _as_tuple(axis_names)
    return lax.all_to_all(x, tuple(reversed(axis_names)), split_axis=0,
                          concat_axis=0, tiled=False)


def _factorized_impl(x, axis_names, *, variant: Variant = "natural",
                     round_order=None):
    """d-round torus all-to-all of ``p`` blocks (Algorithm 1).

    Args:
      x: local ``(p, *block)`` array; ``p`` = product of the named axis sizes.
      axis_names: torus dimensions, fastest digit first.
      variant: "natural" (zero-copy axis form) or "paper" (literal
        column-major composite construction).
      round_order: permutation of ``range(d)``; rounds commute (each round
        exchanges only digit ``k`` between buffer position and device
        coordinate), so any order is correct — the knob exists for tuning
        (e.g. put the slow DCN axis first or last).
    Returns:
      ``(p, *block)``: ``out[i]`` = block received from torus rank ``i``.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    p = math.prod(dims)
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != prod(dims)={p} ({dims})")
    axis_names, dims = _skip_trivial(axis_names, dims)
    d = len(dims)
    if d == 0:
        return x
    order = tuple(round_order) if round_order is not None else tuple(range(d))
    if sorted(order) != list(range(d)):
        raise ValueError(f"round_order {order} is not a permutation of 0..{d-1}")

    block = x.shape[1:]
    nb = len(block)
    # Block view: axes [dim d-1, ..., dim 1, dim 0, *block]  (dim 0 fastest).
    A = x.reshape(tuple(reversed(dims)) + block)
    pos = lambda m: d - 1 - m  # array axis holding torus dimension m

    # named_scope per round: free at runtime, but the device profile
    # (jax.profiler) shows each dimension-wise round as its own scope —
    # lining the XLA timeline up with the host-side telemetry spans.
    if variant == "natural":
        for k in order:
            with jax.named_scope(f"a2a_round[{axis_names[k]}]"):
                A = lax.all_to_all(A, axis_names[k], split_axis=pos(k),
                                   concat_axis=pos(k), tiled=False)
    elif variant == "paper":
        for k in order:
            with jax.named_scope(f"a2a_round[{axis_names[k]}]"):
                perm = ([pos(k)]
                        + [pos(m) for m in range(k + 1, d)]
                        + [pos(m) for m in range(k - 1, -1, -1)]
                        + [d + i for i in range(nb)])
                inv = tuple(int(i) for i in np.argsort(perm))
                A = A.transpose(perm)
                A = lax.all_to_all(A, axis_names[k], split_axis=0,
                                   concat_axis=0, tiled=False)
                A = A.transpose(inv)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    return A.reshape((p,) + block)


def _factorized_round_impl(x, axis_names, k: int, *,
                           variant: Variant = "natural"):
    """Exactly one dimension-wise round (active round index ``k``) of
    :func:`_factorized_impl`.

    Every round returns the buffer to the canonical ``(p, *block)``
    layout, so composing the per-round kernels over any ``round_order``
    is bit-identical to the fused d-round kernel — this is what lets the
    telemetry-traced execution path dispatch one jitted step per round
    (each with its own measured host span) without changing results.
    The split costs the per-round reshape fusion XLA would otherwise do,
    which is why the stepped path only runs when tracing is enabled.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    p = math.prod(dims)
    if x.shape[0] != p:
        raise ValueError(f"leading dim {x.shape[0]} != prod(dims)={p} ({dims})")
    axis_names, dims = _skip_trivial(axis_names, dims)
    d = len(dims)
    if not 0 <= k < d:
        raise ValueError(f"round index {k} outside 0..{d - 1}")
    block = x.shape[1:]
    nb = len(block)
    A = x.reshape(tuple(reversed(dims)) + block)
    pos = lambda m: d - 1 - m
    with jax.named_scope(f"a2a_round[{axis_names[k]}]"):
        if variant == "natural":
            A = lax.all_to_all(A, axis_names[k], split_axis=pos(k),
                               concat_axis=pos(k), tiled=False)
        elif variant == "paper":
            perm = ([pos(k)]
                    + [pos(m) for m in range(k + 1, d)]
                    + [pos(m) for m in range(k - 1, -1, -1)]
                    + [d + i for i in range(nb)])
            inv = tuple(int(i) for i in np.argsort(perm))
            A = A.transpose(perm)
            A = lax.all_to_all(A, axis_names[k], split_axis=0, concat_axis=0,
                               tiled=False)
            A = A.transpose(inv)
        else:
            raise ValueError(f"unknown variant {variant!r}")
    return A.reshape((p,) + block)


def _factorized_tiled_impl(x, axis_names, split_axis, concat_axis, *,
                           variant: Variant = "natural",
                           round_order=None):
    """Tiled-semantics factorized all-to-all.

    Drop-in for ``lax.all_to_all(x, tuple(reversed(axis_names)), split_axis,
    concat_axis, tiled=True)`` — the form used by MoE token dispatch and
    Ulysses sequence<->head re-sharding — but decomposed into the paper's d
    per-dimension rounds.  ``x.shape[split_axis]`` must be divisible by p.
    """
    axis_names = _as_tuple(axis_names)
    dims = _axis_sizes(axis_names)
    p = math.prod(dims)
    if p == 1:
        return x
    S = x.shape[split_axis]
    if S % p:
        raise ValueError(f"split axis size {S} not divisible by p={p}")
    shape = x.shape
    # View the split axis as (p, S//p); bring the p-axis to the front.
    xb = x.reshape(shape[:split_axis] + (p, S // p) + shape[split_axis + 1:])
    xb = jnp.moveaxis(xb, split_axis, 0)
    out = _factorized_impl(xb, axis_names, variant=variant,
                           round_order=round_order)
    # out: [p(source), orig axes with split axis shrunk to S//p].
    # Place the source axis just before the payload's concat content and
    # merge: concatenation along concat_axis is source-major, matching the
    # tiled collective's semantics.
    out = jnp.moveaxis(out, 0, concat_axis)
    sh = out.shape
    return out.reshape(sh[:concat_axis]
                       + (sh[concat_axis] * sh[concat_axis + 1],)
                       + sh[concat_axis + 2:])


def _direct_tiled_impl(x, axis_names, split_axis, concat_axis):
    """Direct tiled collective over the product communicator (baseline)."""
    axis_names = _as_tuple(axis_names)
    return lax.all_to_all(x, tuple(reversed(axis_names)), split_axis,
                          concat_axis, tiled=True)


# ---------------------------------------------------------------------------
# Deprecated free-function shims.
#
# The public entry points below predate ``core.plan``; they now build (or
# fetch from the LRU registry) an ``A2APlan`` per call and delegate, so
# they stay bit-exact with plan execution while existing external callers
# keep working.  Internal code must construct plans directly — CI errors
# on DeprecationWarning raised from ``repro.*`` call sites.
# ---------------------------------------------------------------------------


def direct_all_to_all(x, axis_names):
    """Deprecated: use ``plan_all_to_all(..., backend="direct").forward``."""
    _warn_deprecated("direct_all_to_all", "plan.forward")
    from .plan import plan_all_to_all
    names = _as_tuple(axis_names)
    plan = plan_all_to_all(_axis_sizes(names), names, x.shape[1:], x.dtype,
                           backend="direct")
    return plan.forward(x)


def factorized_all_to_all(x, axis_names, *, variant: Variant = "natural",
                          round_order=None):
    """Deprecated: use ``plan_all_to_all(..., backend="factorized")
    .forward``."""
    _warn_deprecated("factorized_all_to_all", "plan.forward")
    from .plan import plan_all_to_all
    names = _as_tuple(axis_names)
    plan = plan_all_to_all(_axis_sizes(names), names, x.shape[1:], x.dtype,
                           backend="factorized", variant=variant,
                           round_order=round_order)
    return plan.forward(x)


def factorized_all_to_all_tiled(x, axis_names, split_axis, concat_axis, *,
                                variant: Variant = "natural",
                                round_order=None):
    """Deprecated: use ``plan_all_to_all(..., backend="factorized")
    .tiled``."""
    _warn_deprecated("factorized_all_to_all_tiled", "plan.tiled")
    from .plan import plan_all_to_all
    names = _as_tuple(axis_names)
    plan = plan_all_to_all(_axis_sizes(names), names, None, x.dtype,
                           backend="factorized", variant=variant,
                           round_order=round_order)
    return plan.tiled(x, split_axis, concat_axis)


def direct_all_to_all_tiled(x, axis_names, split_axis, concat_axis):
    """Deprecated: use ``plan_all_to_all(..., backend="direct").tiled``."""
    _warn_deprecated("direct_all_to_all_tiled", "plan.tiled")
    from .plan import plan_all_to_all
    names = _as_tuple(axis_names)
    plan = plan_all_to_all(_axis_sizes(names), names, None, x.dtype,
                           backend="direct")
    return plan.tiled(x, split_axis, concat_axis)


def host_alltoall(mesh: Mesh, axis_names, *, variant: Variant = "natural",
                  round_order=None, backend="factorized", n_chunks: int = 2):
    """Deprecated: use ``plan_all_to_all(mesh, ...).host_fn()``.

    Host-level jitted all-to-all over a global ``(p, p, *block)`` operand:
    ``x[r, i]`` is rank r's block for rank i; result ``y[r, i]`` is the
    block rank r received from rank i.  The rank axis is sharded over the
    torus axes (most significant digit first, matching the convention).
    """
    _warn_deprecated("host_alltoall", "plan.host_fn()")
    from .plan import plan_all_to_all
    plan = plan_all_to_all(mesh, axis_names, backend=backend,
                           variant=variant, round_order=round_order,
                           n_chunks=max(1, n_chunks))
    return plan.host_fn(mesh)
