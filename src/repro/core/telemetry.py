"""Zero-dependency tracing + metrics + drift detection — the telemetry spine.

The paper's tuning story ("ample opportunities for algorithm tuning")
only pays off in production if every dimension-wise round's real cost is
*visible* continuously — cross-implementation DDT studies show zero-copy
datatype paths routinely underperforming their analytic model on real
hardware, so measurement can't be a one-shot autotune.  This module is
the single observability surface for the whole stack:

* :class:`Tracer` — span-based tracing on the monotonic clock with
  nested-span attribution, a bounded ring buffer, and thread safety.
  **Disabled by default**: ``tracer.span(...)`` returns a shared no-op
  context manager when off, so instrumented hot paths pay one attribute
  check.  Enabled, plan execution switches to a *stepped* per-round host
  path (bit-exact — the rounds commute) so every dimension-wise round
  gets a genuinely measured span.
* :class:`MetricsRegistry` — namespaced counters / gauges / histograms,
  plus registered *stat providers* that fold the pre-existing scattered
  dicts (``cache_stats`` / ``plan_cache_stats`` / ``autotune_stats`` /
  comm registry) into one flat snapshot, ``metrics_snapshot()`` — what
  ``TorusComm.unified_stats()`` surfaces under ``"telemetry"``.
* :func:`Tracer.export_chrome_trace` — Chrome ``trace_event`` (Perfetto)
  JSON so host spans line up with ``jax.profiler`` device timelines (the
  jitted round bodies carry matching ``jax.named_scope`` annotations).
* :class:`DriftDetector` — measured-vs-model ratios per plan and per
  torus axis, fed by the traced execution path; ``drift_ratio`` above
  ``threshold`` produces a re-tune recommendation that
  ``runtime.watchdog`` routes through its :class:`EscalationPolicy`
  (``Action(kind="retune")``) and ``runtime.serving`` admission reads to
  shed load while the tuning record is stale.

Stdlib only — importable from every layer without cycles; the rest of
the stack registers providers / emits spans into the module singletons
(:func:`get_tracer`, :func:`metrics`, :func:`drift_detector`).
"""

from __future__ import annotations

import json
import math
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path

__all__ = [
    "DriftDetector",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "disable_tracing",
    "drift_detector",
    "enable_tracing",
    "get_tracer",
    "metrics",
    "metrics_snapshot",
    "register_stats_provider",
    "reset_telemetry",
]


# ---------------------------------------------------------------------------
# Spans + Tracer
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Span:
    """One completed span: ``[start, start + duration)`` on the
    ``time.perf_counter`` clock, with the attributes set during the
    span's body.  ``parent_id`` is the enclosing span on the same thread
    (``None`` at top level), giving the export a proper nesting tree."""

    name: str
    start: float                   # perf_counter seconds
    duration: float                # seconds
    span_id: int
    parent_id: int | None
    thread_id: int
    attrs: dict


class _NullSpan:
    """The disabled-tracer span: a shared, stateless no-op context
    manager — entering, exiting, and ``set()`` all cost one method
    dispatch and allocate nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class _ActiveSpan:
    """A live span (enabled tracer): records itself into the ring buffer
    on exit.  Exceptions propagate — the span still closes, tagged with
    the exception type so the trace shows *where* a run died."""

    __slots__ = ("_tracer", "name", "attrs", "start", "span_id",
                 "parent_id", "thread_id")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        tr = self._tracer
        stack = tr._stack()
        self.parent_id = stack[-1] if stack else None
        self.span_id = tr._next_id()
        self.thread_id = threading.get_ident()
        stack.append(self.span_id)
        self.start = time.perf_counter()
        return self

    def set(self, **attrs):
        """Attach attributes mid-span (e.g. a result size known only
        after the body ran)."""
        self.attrs.update(attrs)
        return self

    def __exit__(self, exc_type, exc, tb):
        end = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        if exc_type is not None:
            self.attrs["exception"] = exc_type.__name__
        self._tracer._record(Span(self.name, self.start, end - self.start,
                                  self.span_id, self.parent_id,
                                  self.thread_id, dict(self.attrs)))
        return False


class Tracer:
    """Span recorder over a bounded ring buffer.

    ``enabled`` gates everything: when ``False`` (the default),
    :meth:`span` returns the shared :data:`_NULL_SPAN` and no state is
    touched — the documented overhead contract is <5% on a tight
    plan-execute loop (``tests/test_telemetry.py`` enforces it).  The
    ring buffer (``capacity`` completed spans) makes a week-long run
    safe to trace: overflow evicts the oldest span and bumps
    ``dropped`` instead of growing without bound.
    """

    def __init__(self, capacity: int = 4096, enabled: bool = False):
        self.enabled = bool(enabled)
        self.dropped = 0
        self._buf: deque[Span] = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._local = threading.local()
        self._id = 0
        self._epoch = time.perf_counter()

    # -- internals ---------------------------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _next_id(self) -> int:
        with self._lock:
            self._id += 1
            return self._id

    def _record(self, span: Span) -> None:
        with self._lock:
            if self._buf.maxlen is not None \
                    and len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(span)

    # -- public surface ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self._buf.maxlen or 0

    def span(self, name: str, **attrs):
        """Open a span context manager; a no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _ActiveSpan(self, name, attrs)

    def spans(self) -> list[Span]:
        """Snapshot of the completed spans, oldest first."""
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0
            self._epoch = time.perf_counter()

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": self.enabled, "spans": len(self._buf),
                    "capacity": self._buf.maxlen or 0,
                    "dropped": self.dropped}

    def export_chrome_trace(self, path=None) -> dict:
        """The spans as a Chrome ``trace_event`` document (Perfetto /
        ``chrome://tracing`` loadable).  Complete spans map to ``"X"``
        (duration) events; timestamps are microseconds since the
        tracer's epoch so the timeline starts near zero.  Writes JSON to
        ``path`` when given; always returns the document."""
        events = []
        for s in self.spans():
            args = {k: v for k, v in s.attrs.items()
                    if isinstance(v, (str, int, float, bool, type(None)))}
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            events.append({
                "name": s.name,
                "ph": "X",
                "ts": (s.start - self._epoch) * 1e6,
                "dur": s.duration * 1e6,
                "pid": 1,
                "tid": s.thread_id % (1 << 31),
                "cat": str(s.attrs.get("cat", s.name.split(".")[0])),
                "args": args,
            })
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"exporter": "repro.core.telemetry",
                             "dropped_spans": self.dropped}}
        if path is not None:
            Path(path).write_text(json.dumps(doc, indent=1))
        return doc


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic counter.  Mutation holds the registry lock — metric
    updates happen at host-level events (plan execute, watchdog verdict,
    serving tick), never inside a traced computation."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock):
        self._lock = lock
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock):
        self._lock = lock
        self.value = None

    def set(self, v) -> None:
        with self._lock:
            self.value = v


class Histogram:
    """Streaming summary: count / total / min / max / last (no buckets —
    the snapshot is for dashboards and regression gates, not quantile
    estimation)."""

    __slots__ = ("_lock", "count", "total", "min", "max", "last")

    def __init__(self, lock):
        self._lock = lock
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.last = None

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self.last = v

    def summary(self) -> dict:
        with self._lock:
            if not self.count:
                return {"count": 0}
            return {"count": self.count, "total": self.total,
                    "mean": self.total / self.count,
                    "min": self.min, "max": self.max, "last": self.last}


class MetricsRegistry:
    """Namespaced metric store: ``registry.counter("plan.exec").inc()``.

    Names are dotted namespaces (``watchdog.events_dropped``,
    ``serving.admitted``); :meth:`snapshot` returns the flat
    ``{name: value}`` dict (histograms expand to summary sub-dicts).
    Re-requesting a name returns the same metric; requesting it as a
    different type raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self._lock)
            elif type(m) is not cls:
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        with self._lock:
            items = list(self._metrics.items())
        out = {}
        for name, m in sorted(items):
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# Stat providers: fold the pre-existing scattered stats dicts in
# ---------------------------------------------------------------------------


_PROVIDERS: dict[str, object] = {}
_PROVIDERS_LOCK = threading.Lock()


def register_stats_provider(namespace: str, fn) -> None:
    """Register ``fn() -> dict`` so its flat keys appear in
    :func:`metrics_snapshot` as ``<namespace>.<key>``.  Later
    registrations under the same namespace replace earlier ones
    (module reload safety)."""
    with _PROVIDERS_LOCK:
        _PROVIDERS[str(namespace)] = fn


def metrics_snapshot() -> dict:
    """The unified namespaced snapshot: every registered provider's dict
    flattened under its namespace, merged with the live registry.
    Scalar provider values keep ``ns.key``; nested dicts flatten one
    more level (``ns.key.subkey``).  A crashing provider contributes an
    ``ns.error`` string instead of taking the snapshot down."""
    with _PROVIDERS_LOCK:
        providers = list(_PROVIDERS.items())
    out = {}
    for ns, fn in sorted(providers):
        try:
            stats = fn()
        except Exception as e:                      # pragma: no cover
            out[f"{ns}.error"] = f"{type(e).__name__}: {e}"
            continue
        for k, v in stats.items():
            if isinstance(v, dict):
                for k2, v2 in v.items():
                    out[f"{ns}.{k}.{k2}"] = v2
            else:
                out[f"{ns}.{k}"] = v
    out.update(metrics().snapshot())
    return out


# ---------------------------------------------------------------------------
# Drift detection: measured vs model
# ---------------------------------------------------------------------------


class DriftDetector:
    """Measured-vs-model drift per key (a plan, or one plan axis).

    :meth:`observe` records ``measured / predicted`` ratios into a
    per-key window; the key's ``drift_ratio`` is the *median* ratio once
    ``min_samples`` have arrived (median, not mean — one GC pause must
    not flag a re-tune).  A key whose ratio crosses ``threshold``
    becomes *drifted* and yields exactly one re-tune recommendation via
    :meth:`recommendations` until it recovers below threshold (then it
    re-arms), so the watchdog isn't spammed every step while the
    condition persists.
    """

    def __init__(self, threshold: float = 1.5, window: int = 32,
                 min_samples: int = 3):
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1, got {threshold}")
        self.threshold = float(threshold)
        self.window = int(window)
        self.min_samples = int(min_samples)
        self._lock = threading.Lock()
        self._ratios: dict[str, deque] = {}
        self._last: dict[str, tuple] = {}       # key -> (pred, meas)
        self._recommended: set[str] = set()

    def observe(self, key: str, predicted_seconds: float,
                measured_seconds: float) -> float | None:
        """Record one execution; returns the key's current drift ratio
        (``None`` until ``min_samples``).  Non-positive predictions are
        ignored — an unfitted model must not divide by zero."""
        if predicted_seconds is None or predicted_seconds <= 0.0:
            return None
        key = str(key)
        ratio = float(measured_seconds) / float(predicted_seconds)
        with self._lock:
            dq = self._ratios.get(key)
            if dq is None:
                dq = self._ratios[key] = deque(maxlen=self.window)
            dq.append(ratio)
            self._last[key] = (float(predicted_seconds),
                               float(measured_seconds))
        metrics().counter("drift.observations").inc()
        return self.drift_ratio(key)

    def drift_ratio(self, key: str) -> float | None:
        """Median measured/predicted ratio, or ``None`` below
        ``min_samples``."""
        with self._lock:
            dq = self._ratios.get(str(key))
            if dq is None or len(dq) < self.min_samples:
                return None
            ratios = sorted(dq)
        n = len(ratios)
        mid = n // 2
        return ratios[mid] if n % 2 else 0.5 * (ratios[mid - 1]
                                                + ratios[mid])

    def drifted(self, key: str) -> bool:
        r = self.drift_ratio(key)
        return r is not None and r > self.threshold

    def summary(self) -> dict:
        """``{key: {ratio, samples, drifted, predicted_seconds,
        measured_seconds}}`` for every observed key."""
        with self._lock:
            keys = list(self._ratios)
        out = {}
        for key in sorted(keys):
            r = self.drift_ratio(key)
            with self._lock:
                dq = self._ratios.get(key) or ()
                pred, meas = self._last.get(key, (None, None))
            out[key] = {"ratio": r, "samples": len(dq),
                        "drifted": r is not None and r > self.threshold,
                        "predicted_seconds": pred,
                        "measured_seconds": meas}
        return out

    def recommendations(self) -> list[dict]:
        """Drain newly drifted keys as re-tune recommendations:
        ``[{key, ratio, threshold, action: "retune"}]``.  Each key
        recommends once per drift episode; a ratio back under threshold
        re-arms it."""
        out = []
        for key, info in self.summary().items():
            with self._lock:
                if info["drifted"] and key not in self._recommended:
                    self._recommended.add(key)
                    fresh = True
                elif not info["drifted"]:
                    self._recommended.discard(key)
                    fresh = False
                else:
                    fresh = False
            if fresh:
                out.append({"key": key, "ratio": info["ratio"],
                            "threshold": self.threshold,
                            "action": "retune"})
                metrics().counter("drift.retune_recommendations").inc()
        return out

    def clear(self) -> None:
        with self._lock:
            self._ratios.clear()
            self._last.clear()
            self._recommended.clear()


# ---------------------------------------------------------------------------
# Module singletons
# ---------------------------------------------------------------------------


_TRACER = Tracer()
_METRICS = MetricsRegistry()
_DRIFT = DriftDetector()


def get_tracer() -> Tracer:
    return _TRACER


def metrics() -> MetricsRegistry:
    return _METRICS


def drift_detector() -> DriftDetector:
    return _DRIFT


def enable_tracing(capacity: int | None = None) -> Tracer:
    """Turn the global tracer on (optionally resizing the ring buffer);
    returns it."""
    if capacity is not None and capacity != _TRACER.capacity:
        _TRACER._buf = deque(_TRACER._buf, maxlen=int(capacity))
    _TRACER.enabled = True
    return _TRACER


def disable_tracing() -> Tracer:
    _TRACER.enabled = False
    return _TRACER


def reset_telemetry() -> None:
    """Clear spans, metrics, and drift state (providers stay registered)
    — test isolation, and dryrun's per-cell reset."""
    _TRACER.enabled = False
    _TRACER.clear()
    _METRICS.reset()
    _DRIFT.clear()


def warn_once(flag_holder, flag: str, message: str) -> None:
    """Emit ``message`` as a ``RuntimeWarning`` the first time
    ``flag_holder``'s ``flag`` attribute is falsy, then latch it — the
    one-time-warning idiom for bounded-loss pathologies (ring-buffer /
    event-deque overflow)."""
    if not getattr(flag_holder, flag, False):
        try:
            setattr(flag_holder, flag, True)
        except AttributeError:      # frozen dataclass etc.: warn anyway
            pass
        warnings.warn(message, RuntimeWarning, stacklevel=3)
