"""Flash attention Pallas kernel (TPU target, validated in interpret mode).

Online-softmax attention with BlockSpec VMEM tiling, supporting causal and
sliding-window masks and GQA (the KV head is selected in the *index map*,
so grouped heads re-read the same KV tiles from HBM — no materialized
``repeat``).  Grid: (batch, q_heads, q_blocks, kv_blocks) with the KV block
innermost; running max / sum / accumulator live in VMEM scratch across the
kv-block loop (the classic FlashAttention-2 schedule, re-tiled for the MXU:
block shapes default to multiples of 128 on the contraction dims).

The kernel is the *target* implementation for real TPUs; on this CPU-only
container it is exercised with ``interpret=True`` against
``ref.ref_attention``.  The model stack selects between this kernel and
the XLA path via ``AttentionImpl`` in ``ops.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale, causal, window, block_q, block_k, kv_len, kv_offset):
    """One (q-block, kv-block) step of online-softmax attention."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)           # (bk, dh)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + kv_offset
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] \
        + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows -> 0
        o_ref[0, 0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (MXU-friendly when n is)."""
    b = min(preferred, n)
    while n % b:
        b -= 1
    return b


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "kv_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, kv_offset: int = 0,
                    interpret: bool = False):
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh); Hq % Hkv == 0.

    Returns (B, Hq, Sq, Dh) attention output in q's dtype.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(Dh)
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Skv, block_k)
    grid = (B, Hq, Sq // bq, Skv // bk)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, kv_len=Skv, kv_offset=kv_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            # GQA: the kv head is h // group — the "derived datatype" of
            # this kernel: grouped q heads address the same KV tiles.
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running sum l
        ],
        interpret=interpret,
    )(q, k, v)
