"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the mathematical specification its kernel is tested
against with ``assert_allclose`` over shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ref_attention(q, k, v, *, causal: bool = True,
                  window: int | None = None, scale: float | None = None,
                  kv_offset: int = 0):
    """Reference multi-head attention with GQA, causal and sliding-window
    masking.

    q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh) with Hq % Hkv == 0.
    ``window``: sliding-window size (keys within [r - window + 1, r]).
    ``kv_offset``: absolute position of q[0] relative to k[0] (decode).
    """
    B, Hq, Sq, Dh = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / np.sqrt(Dh)
    # GQA via reshape (NOT jnp.repeat): keeps the KV-head axis intact so
    # head sharding survives GSPMD, and feeds the MXU in the input dtype
    # with f32 accumulation (casting inputs to f32 first would double the
    # all-gather bytes of sharded operands — see EXPERIMENTS §Perf).
    qg = q.reshape(B, Hkv, group, Sq, Dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    Skv = k.shape[2]
    rows = jnp.arange(Sq)[:, None] + kv_offset
    cols = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, Sq, Dh).astype(q.dtype)


def ref_block_reorder(x, positions, extent: int, n_peers: int):
    """Reference of the round-k datatype *pack*: the explicit-copy block
    gather the paper's zero-copy formulation eliminates.

    x: (p, B).  out[j * len(positions) + t] = x[positions[t] + j * extent]
    for peer j in [0, n_peers) — i.e. composite messages laid out
    contiguously per peer (what an MPI implementation without derived
    datatypes would have to do with explicit packing).
    """
    positions = jnp.asarray(positions)
    idx = (positions[None, :] + jnp.arange(n_peers)[:, None] * extent)
    return x[idx.reshape(-1)]


def ref_block_unreorder(y, positions, extent: int, n_peers: int):
    """Inverse of ``ref_block_reorder`` (the unpack side)."""
    positions = jnp.asarray(positions)
    idx = (positions[None, :] + jnp.arange(n_peers)[:, None] * extent)
    p = y.shape[0]
    out = jnp.zeros_like(y)
    return out.at[idx.reshape(-1)].set(y[: idx.size])


def ref_gmm(lhs, rhs, *, preferred_dtype=jnp.float32):
    """Grouped (per-expert) matmul: (E, C, M) x (E, M, N) -> (E, C, N)."""
    out = jnp.einsum("ecm,emn->ecn", lhs.astype(preferred_dtype),
                     rhs.astype(preferred_dtype))
    return out.astype(lhs.dtype)
