"""Block-reorder Pallas kernel — the MPI *derived datatype* on TPU.

The paper's round-k datatype describes, per peer ``j``, the strided block
traversal ``positions[t] + j*extent``.  On TPU the natural home for that
descriptor is ``BlockSpec.index_map``: the DMA engine performs the strided
HBM->VMEM block gather *during the copy it must do anyway* — an index map
is a derived datatype.

Offsets of the round-k traversal are runs of ``sigma(k)`` consecutive
blocks at bases ``sum_{m>k} i_m * sigma(m)`` (see ``core.simulator``), so
in units of sigma(k)-sized *tiles* the gather is exact:

    in-tile index  (j, u) -> j + f(u),   f(u) = sum_m i_m(u)*sigma(m)/sigma(k)
    out-tile index (j, u) -> j * (p / (D_k * sigma_k)) + u

with ``i_m(u)`` the mixed-radix digits of ``u`` over ``(D[k+1]...D[d-1])``
(column-major: ``i_{d-1}`` fastest).  Both maps are closed-form functions
of the grid indices — no materialized index arrays, no gather op.

This kernel is the *explicit-copy baseline*: an MPI library without
derived-datatype support would pack composite messages exactly like this
before every component all-to-all.  The zero-copy path
(``core.factorized``, natural variant) never runs it; benchmarks compare
the two to quantify what zero-copy saves.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.simulator import strides


def _digits_to_tile(u, uppers_dims, uppers_strides_tiles):
    """f(u): mixed-radix decompose u (column-major, last dim fastest) and
    re-linearize with the round's tile strides."""
    tile = 0
    # u enumerates itertools.product(*dims) with the LAST dim fastest.
    for dim, stride in zip(reversed(uppers_dims),
                           reversed(uppers_strides_tiles)):
        tile = tile + (u % dim) * stride
        u = u // dim
    return tile


def _pack_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("dims", "k", "interpret"))
def datatype_pack(x, *, dims: tuple[int, ...], k: int,
                  interpret: bool = False):
    """Pack round-k composite messages contiguously (explicit-copy path).

    x: ``(p, B)`` block buffer.  Returns ``(p, B)`` where rows
    ``[j*p/D_k : (j+1)*p/D_k]`` are peer j's composite message in datatype
    order.  Equivalent to ``ref.ref_block_reorder`` with the round-k
    positions.
    """
    p, B = x.shape
    d = len(dims)
    if math.prod(dims) != p:
        raise ValueError(f"prod(dims)={math.prod(dims)} != p={p}")
    sig = strides(dims)
    sigma_k = sig[k]
    Dk = dims[k]
    uppers = list(range(k + 1, d))
    uppers_dims = tuple(dims[m] for m in uppers)
    # Strides of the upper digits, in units of sigma_k-row tiles; the digit
    # m contributes sigma(m)/sigma(k) tiles.
    uppers_strides = tuple(sig[m] // sigma_k for m in uppers)
    n_upper = math.prod(uppers_dims) if uppers_dims else 1
    tiles_per_peer = p // (Dk * sigma_k)
    assert tiles_per_peer == n_upper

    grid = (Dk, n_upper)

    def in_map(j, u):
        base = _digits_to_tile(u, uppers_dims, uppers_strides)
        return (base + j, 0)   # tile row (sigma_k rows), full width

    def out_map(j, u):
        return (j * tiles_per_peer + u, 0)

    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((sigma_k, B), in_map)],
        out_specs=pl.BlockSpec((sigma_k, B), out_map),
        out_shape=jax.ShapeDtypeStruct((p, B), x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("dims", "k", "interpret"))
def datatype_unpack(y, *, dims: tuple[int, ...], k: int,
                    interpret: bool = False):
    """Inverse of ``datatype_pack``: scatter contiguous composite messages
    back into datatype positions (the receive-side explicit copy)."""
    p, B = y.shape
    d = len(dims)
    sig = strides(dims)
    sigma_k = sig[k]
    Dk = dims[k]
    uppers = list(range(k + 1, d))
    uppers_dims = tuple(dims[m] for m in uppers)
    uppers_strides = tuple(sig[m] // sigma_k for m in uppers)
    n_upper = math.prod(uppers_dims) if uppers_dims else 1
    tiles_per_peer = p // (Dk * sigma_k)

    grid = (Dk, n_upper)

    def in_map(j, u):
        return (j * tiles_per_peer + u, 0)

    def out_map(j, u):
        base = _digits_to_tile(u, uppers_dims, uppers_strides)
        return (base + j, 0)

    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((sigma_k, B), in_map)],
        out_specs=pl.BlockSpec((sigma_k, B), out_map),
        out_shape=jax.ShapeDtypeStruct((p, B), y.dtype),
        interpret=interpret,
    )(y)
