"""Grouped (per-expert) matmul Pallas kernel — the MoE FFN hot spot.

After the factorized all-to-all dispatch, each device holds a dense
``(E_local, capacity, d_model)`` tile of tokens per local expert; the
expert FFN is a batch of independent matmuls with *different* weights per
group — a grouped matmul.  Grid: (experts, C-blocks, N-blocks, K-blocks)
with the contraction (K) innermost, accumulating in a VMEM f32 scratch so
the MXU sees (bc x bk) @ (bk x bn) tiles; block sizes default to 128
(MXU-aligned) and shrink to divisors for small shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _gmm_kernel(lhs_ref, rhs_ref, o_ref, acc_ref):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        lhs_ref[0].astype(jnp.float32), rhs_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(n: int, preferred: int) -> int:
    b = min(preferred, n)
    while n % b:
        b -= 1
    return b


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_n", "block_k", "interpret"))
def grouped_matmul(lhs, rhs, *, block_c: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool = False):
    """(E, C, K) @ (E, K, N) -> (E, C, N), independent matmul per expert."""
    E, C, K = lhs.shape
    E2, K2, N = rhs.shape
    if (E, K) != (E2, K2):
        raise ValueError(f"shape mismatch {lhs.shape} @ {rhs.shape}")
    bc = _pick_block(C, block_c)
    bn = _pick_block(N, block_n)
    bk = _pick_block(K, block_k)
    grid = (E, C // bc, N // bn, K // bk)

    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e, ic, jn, ik: (e, ic, ik)),
            pl.BlockSpec((1, bk, bn), lambda e, ic, jn, ik: (e, ik, jn)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda e, ic, jn, ik: (e, ic, jn)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), lhs.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        interpret=interpret,
    )(lhs, rhs)
