"""Public jit'd kernel wrappers and implementation dispatch.

The model stack calls these entry points; each selects between the Pallas
kernel (TPU target; interpret mode on CPU when forced) and the XLA
reference path.  On this CPU-only container the default is the XLA path —
Pallas kernels are validated in interpret mode by the test suite and meant
to be enabled with ``impl="pallas"`` on real TPUs.

Training note: ``attention`` exposes a ``jax.custom_vjp`` whose forward
may run the Pallas kernel while the backward uses the XLA reference
gradient (same math, so gradients are exact for the function computed);
a Pallas backward kernel is a tracked open item in ROADMAP.md.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from . import ref as _ref
from .block_reorder import datatype_pack, datatype_unpack
from .flash_attention import flash_attention
from .moe_gmm import grouped_matmul

AttentionImpl = Literal["xla", "pallas", "pallas_interpret"]


def attention(q, k, v, *, causal=True, window=None, kv_offset=0,
              impl: AttentionImpl = "xla", block_q=128, block_k=128):
    """Multi-head attention with GQA/causal/sliding-window support.

    ``impl="pallas"`` uses the trainable flash kernel (custom_vjp with the
    Pallas backward — no (S, S) residuals in HBM)."""
    if impl == "xla":
        return _ref.ref_attention(q, k, v, causal=causal, window=window,
                                  kv_offset=kv_offset)
    from .flash_attention_bwd import flash_attention_trainable
    interpret = impl == "pallas_interpret"
    return flash_attention_trainable(q, k, v, causal=causal, window=window,
                                     block_q=block_q, block_k=block_k,
                                     kv_offset=kv_offset,
                                     interpret=interpret)


def expert_matmul(lhs, rhs, *, impl: AttentionImpl = "xla",
                  block_c=128, block_n=128, block_k=128):
    """(E, C, K) @ (E, K, N) grouped matmul."""
    if impl == "xla":
        return _ref.ref_gmm(lhs, rhs)
    return grouped_matmul(lhs, rhs, block_c=block_c, block_n=block_n,
                          block_k=block_k,
                          interpret=(impl == "pallas_interpret"))


def pack_round(x, dims, k, *, impl: AttentionImpl = "pallas_interpret"):
    """Round-k datatype pack (explicit-copy baseline path)."""
    if impl == "xla":
        from repro.core.simulator import round_datatype
        pos, extent = round_datatype(tuple(dims), k)
        return _ref.ref_block_reorder(x, pos, extent, dims[k])
    return datatype_pack(x, dims=tuple(dims), k=k,
                         interpret=(impl == "pallas_interpret"))


def unpack_round(y, dims, k, *, impl: AttentionImpl = "pallas_interpret"):
    if impl == "xla":
        from repro.core.simulator import round_datatype
        pos, extent = round_datatype(tuple(dims), k)
        return _ref.ref_block_unreorder(y, pos, extent, dims[k])
    return datatype_unpack(y, dims=tuple(dims), k=k,
                           interpret=(impl == "pallas_interpret"))
