"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel module pairs with an oracle in ``ref.py`` and a jit'd public
wrapper in ``ops.py``; tests sweep shapes/dtypes in interpret mode.
"""

from .ops import attention, expert_matmul, pack_round, unpack_round

__all__ = ["attention", "expert_matmul", "pack_round", "unpack_round"]
