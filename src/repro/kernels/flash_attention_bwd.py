"""Flash attention backward Pallas kernels + custom_vjp wrapper.

Identified in EXPERIMENTS §Perf (grok train) as the next memory lever: the
XLA attention path materializes the (B, H, S, S) probability matrix in the
residuals; the flash backward recomputes tiles from (q, k, v, lse, delta)
and never touches an S x S buffer in HBM.

Standard FlashAttention-2 backward:

    p    = exp(q k^T * scale - lse)            (recomputed per tile)
    dv  += p^T dO
    dp   = dO v^T
    ds   = p * (dp - delta) * scale            (delta = rowsum(dO * O))
    dq  += ds k
    dk  += ds^T q

Two kernels: dq (grid over q blocks, kv innermost, accumulate in VMEM) and
dkv (grid over kv blocks, q innermost).  GQA: dk/dv are computed per
*query* head and group-summed outside (an (B, Hq, Skv, hd) -> (B, Hkv, ..)
reduction the compiler fuses), keeping the kernels race-free.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .flash_attention import NEG_INF, _pick_block, flash_attention


# ---------------------------------------------------------------------------
# forward returning residuals (lse)
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, scale, causal, window, block_q, block_k, kv_len,
                kv_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + kv_offset
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] \
        + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, ...] = m_ref[...] + jnp.log(safe)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, scale=None,
                        block_q=128, block_k=128, kv_offset=0,
                        interpret=False):
    """Returns (out, lse); lse: (B, Hq, Sq) f32."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(Dh)
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Skv, block_k)
    grid = (B, Hq, Sq // bq, Skv // bk)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, kv_len=Skv, kv_offset=kv_offset)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward kernels
# ---------------------------------------------------------------------------

def _bwd_tile(q, k, v, do, lse, delta, rows, cols, *, scale, causal, window,
              kv_len):
    """Recompute p and ds for one (bq, bk) tile; returns (p, ds) f32."""
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, window, block_q, block_k, kv_len,
               kv_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + kv_offset
    cols = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    _, ds = _bwd_tile(q_ref[0, 0].astype(jnp.float32),
                      k_ref[0, 0].astype(jnp.float32),
                      v_ref[0, 0].astype(jnp.float32),
                      do_ref[0, 0].astype(jnp.float32),
                      lse_ref[0, 0], delta_ref[0, 0], rows, cols,
                      scale=scale, causal=causal, window=window,
                      kv_len=kv_len)
    acc_ref[...] += jnp.dot(ds, k_ref[0, 0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        dq_ref[0, 0, ...] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                block_q, block_k, kv_len, kv_offset):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + kv_offset
    cols = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    q = q_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    p, ds = _bwd_tile(q, k_ref[0, 0].astype(jnp.float32),
                      v_ref[0, 0].astype(jnp.float32), do,
                      lse_ref[0, 0], delta_ref[0, 0], rows, cols,
                      scale=scale, causal=causal, window=window,
                      kv_len=kv_len)
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    @pl.when(iq == pl.num_programs(3) - 1)
    def _finish():
        dk_ref[0, 0, ...] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0, ...] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal=True, window=None,
                        scale=None, block_q=128, block_k=128, kv_offset=0,
                        interpret=False):
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(Dh)
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Skv, block_k)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                               # (B, Hq, Sq)

    common = dict(scale=scale, causal=causal, window=window, block_q=bq,
                  block_k=bk, kv_len=Skv, kv_offset=kv_offset)
    q_spec = pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0))
    qrow_spec = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))
    kv_spec = pl.BlockSpec((1, 1, bk, Dh),
                           lambda b, h, i, j, g=group: (b, h // g, j, 0))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(B, Hq, Sq // bq, Skv // bk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, qrow_spec, qrow_spec],
        out_specs=pl.BlockSpec((1, 1, bq, Dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, Dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv per *query* head (race-free); group-sum to KV heads after.
    q_spec2 = pl.BlockSpec((1, 1, bq, Dh), lambda b, h, j, i: (b, h, i, 0))
    qrow2 = pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i))
    kv_spec2 = pl.BlockSpec((1, 1, bk, Dh),
                            lambda b, h, j, i, g=group: (b, h // g, j, 0))
    okv_spec = pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j, i: (b, h, j, 0))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(B, Hq, Skv // bk, Sq // bq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, qrow2, qrow2],
        out_specs=[okv_spec, okv_spec],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Skv, Dh), k.dtype),
                   jax.ShapeDtypeStruct((B, Hq, Skv, Dh), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, Dh), jnp.float32),
                        pltpu.VMEM((bk, Dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dk_h.reshape(B, Hkv, group, Skv, Dh).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, group, Skv, Dh).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wrapper — the trainable flash attention
# ---------------------------------------------------------------------------

_NONDIFF = ("causal", "window", "scale", "block_q", "block_k", "kv_offset",
            "interpret")
try:        # modern API; older runtimes only know positional argnums
    _vjp_deco = functools.partial(jax.custom_vjp, nondiff_argnames=_NONDIFF)
    _vjp_deco(lambda q, k, v, **kw: q)
except TypeError:
    _vjp_deco = functools.partial(
        jax.custom_vjp, nondiff_argnums=tuple(range(3, 3 + len(_NONDIFF))))


@_vjp_deco
def flash_attention_trainable(q, k, v, causal=True, window=None, scale=None,
                              block_q=128, block_k=128, kv_offset=0,
                              interpret=False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           scale=scale, block_q=block_q, block_k=block_k,
                           kv_offset=kv_offset, interpret=interpret)


def _fa_fwd(q, k, v, causal, window, scale, block_q, block_k, kv_offset,
            interpret):
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, kv_offset=kv_offset,
        interpret=interpret)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, scale, block_q, block_k, kv_offset, interpret,
            res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, do, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, kv_offset=kv_offset,
        interpret=interpret)
    return dq, dk, dv


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)
