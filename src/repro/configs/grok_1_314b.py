"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2,
)

# Reduced same-family config for CPU smoke tests.
SMOKE = CONFIG.replace(
    name="grok-1-314b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, n_experts=4,
    param_dtype="float32", compute_dtype="float32", remat=False)
