"""whisper-tiny [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].  input_specs() provides precomputed frame
embeddings (1500 frames)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    encoder_layers=4, frontend="audio_stub", n_frontend_tokens=1500,
    norm="layernorm", act="gelu", rope_theta=0.0,
)

SMOKE = CONFIG.replace(
    name="whisper-tiny-smoke", n_layers=2, encoder_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, n_frontend_tokens=16,
    param_dtype="float32", compute_dtype="float32", remat=False)
