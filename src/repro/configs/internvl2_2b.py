"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].
The ViT frontend is a stub: input_specs() provides precomputed patch
embeddings (256 tokens after pixel-shuffle)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553,
    frontend="vit_stub", n_frontend_tokens=256,
)

SMOKE = CONFIG.replace(
    name="internvl2-2b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, n_frontend_tokens=8,
    param_dtype="float32", compute_dtype="float32", remat=False)
