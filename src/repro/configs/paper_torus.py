"""The paper's own experiment (§5) as a config: p = 36 x 32 = 1152
processes, factorizations from Table 1, message deciles 1..10^4 MPI_INT,
8 warmup + 40 measured repetitions, best-of.

``benchmarks/alltoall_cmp.py`` runs the CPU-feasible scale (p=16) with
the same protocol; this config records the full-scale plan for a real
cluster run and feeds the tuning-model predictions.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.dims import dims_create
from repro.core.tuning import DCN, ICI, LinkModel, choose_algorithm


@dataclass(frozen=True)
class AlltoallBenchConfig:
    p: int = 1152                      # 36 nodes x 32 ranks
    dims_sweep: tuple[int, ...] = (2, 3, 4, 9)
    element_deciles: tuple[int, ...] = (1, 10, 100, 1000, 10000)
    elem_bytes: int = 4                # MPI_INT
    warmup: int = 8
    reps: int = 40

    def factorizations(self):
        return {d: dims_create(self.p, d) for d in self.dims_sweep}

    def predicted_crossovers(self, link: LinkModel = ICI):
        """Tuning-model prediction of the direct/factorized crossover per
        factorization (the paper's empirical ~100-element boundary)."""
        out = {}
        for d, dims in self.factorizations().items():
            links = (link,) * d
            for n in self.element_deciles:
                s = choose_algorithm(dims, links, n * self.elem_bytes)
                out[(d, n)] = s.kind
        return out


PAPER_BENCH = AlltoallBenchConfig()

# This repo's production tori, same protocol.
SINGLE_POD_BENCH = AlltoallBenchConfig(p=256, dims_sweep=(2, 3, 4, 8))
MULTI_POD_BENCH = AlltoallBenchConfig(p=512, dims_sweep=(2, 3, 9))
