"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window
attention [arXiv:2401.16818; hf].  SWA makes long_500k decode O(window)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000,
    window=4096,
)

SMOKE = CONFIG.replace(
    name="h2o-danube-1.8b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, window=8,
    param_dtype="float32", compute_dtype="float32", remat=False)
