"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1) [arXiv:2405.04517;
unverified].  d_ff=0: xLSTM blocks carry their own projections."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm",
                   "mlstm", "mlstm", "mlstm", "slstm"),
    # optimized defaults (EXPERIMENTS §Perf): chunkwise mLSTM + step remat
    # cut the recurrent-state HBM term ~7700x vs the per-step baseline
    # (chunk sweep 32/64/128 -> 14.6/12.4/11.4 s; 128 chosen)
    # (reproduce the baseline with --set xlstm_chunk=0
    #  --set recurrent_step_remat=false)
    xlstm_chunk=128,
    recurrent_step_remat=True,
)

SMOKE = CONFIG.replace(
    name="xlstm-1.3b-smoke", n_layers=8, d_model=64,
    param_dtype="float32", compute_dtype="float32", remat=False)
