"""qwen2.5-3b [dense] — GQA kv=2, QKV bias [hf:Qwen/Qwen2.5-3B; hf]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936,
    qkv_bias=True,
)

SMOKE = CONFIG.replace(
    name="qwen2.5-3b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256,
    param_dtype="float32", compute_dtype="float32", remat=False)
