"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887; hf]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    n_experts=16, top_k=2, moe_every=2,
    # one attention layer per 8 (position 4), mamba elsewhere
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    # optimized default (EXPERIMENTS §Perf): remat each selective-scan
    # step so BPTT saves only the carried state
    recurrent_step_remat=True,
)

SMOKE = CONFIG.replace(
    name="jamba-v0.1-52b-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, n_experts=4, ssm_state=4,
    param_dtype="float32", compute_dtype="float32", remat=False)
