"""Input-shape cells and per-arch applicability.

Four shapes per LM arch (40 cells total):
  train_4k    seq=4096   global_batch=256   (training:  train_step)
  prefill_32k seq=32768  global_batch=32    (inference: prefill last-logit)
  decode_32k  seq=32768  global_batch=128   (serve_step, KV cache = seq)
  long_500k   seq=524288 global_batch=1     (serve_step, sub-quadratic only)

``long_500k`` runs only for architectures whose decode state is
sub-quadratic in context: SSM/hybrid state (jamba, xlstm) or sliding-
window KV (h2o-danube).  Pure full-attention archs skip it (a 512k dense
KV cache is the architecture's own limitation, recorded in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs with sub-quadratic long-context decode
_SUBQUADRATIC = {"jamba-v0.1-52b", "xlstm-1.3b", "h2o-danube-1.8b"}


def applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and cfg.name not in _SUBQUADRATIC:
        return False, ("full-attention KV cache at 524288 tokens is "
                       "quadratic-state; skipped per assignment rules")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCell, *, reduced: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``reduced`` scales batch/seq down for smoke testing the same code path.
    """
    S = shape.seq_len if not reduced else 32
    B = shape.global_batch if not reduced else 4
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "train":
        batch = {"tokens": tok(B, S), "labels": tok(B, S),
                 "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if cfg.frontend is not None or cfg.encoder_layers:
            nf = cfg.n_frontend_tokens if not reduced else 8
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, nf, cfg.d_model), jnp.float32)
        return batch
    if shape.kind == "prefill":
        out = {"tokens": tok(B, S)}
        if cfg.frontend is not None or cfg.encoder_layers:
            nf = cfg.n_frontend_tokens if not reduced else 8
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, nf, cfg.d_model), jnp.float32)
        return out
    if shape.kind == "decode":
        # one new token; the cache covers `seq_len` context
        out = {"tokens_t": tok(B, 1), "cache_len": S, "batch": B}
        return out
    raise ValueError(shape.kind)
