"""internlm2-20b [dense] — GQA [arXiv:2403.17297; hf]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92544,
)

SMOKE = CONFIG.replace(
    name="internlm2-20b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256,
    param_dtype="float32", compute_dtype="float32", remat=False)
