"""Architecture registry: the 10 assigned configs + shape cells."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig
from .shapes import SHAPES, ShapeCell, applicable, input_specs

_MODULES = {
    "grok-1-314b": "grok_1_314b",
    "phi3.5-moe-42b": "phi35_moe_42b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-2b": "internvl2_2b",
    "internlm2-20b": "internlm2_20b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "deepseek-7b": "deepseek_7b",
    "qwen2.5-3b": "qwen25_3b",
    "whisper-tiny": "whisper_tiny",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def list_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


__all__ = ["ARCH_NAMES", "SHAPES", "ShapeCell", "applicable", "get_config",
           "input_specs", "list_configs"]
