"""Model definitions: composable layers + the 10 assigned architectures."""

from .config import ModelConfig
from .model_api import (build_model, make_loss_fn, make_prefill_fn,
                        make_serve_step, make_train_step)

__all__ = ["ModelConfig", "build_model", "make_loss_fn", "make_prefill_fn",
           "make_serve_step", "make_train_step"]
