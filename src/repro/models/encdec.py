"""Encoder–decoder model (whisper-tiny backbone).

Encoder: bidirectional transformer over precomputed frame embeddings (the
conv frontend is a stub per the assignment: ``input_specs()`` provides
(B, n_frames, D) features).  Decoder: causal self-attention + cross
attention + GELU FFN, LayerNorm, sinusoidal positions (no RoPE).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.common import (ParamSpec, init_params, layer_norm,
                                 sinusoidal_positions,
                                 softmax_cross_entropy, stack_specs)
from repro.parallel.sharding import constrain
from .config import ModelConfig

ACT_SPEC = ("batch", None, "act_embed")


def _ln_specs(cfg):
    return {"g": ParamSpec((cfg.d_model,), (None,), init="ones"),
            "b": ParamSpec((cfg.d_model,), (None,), init="zeros")}


def _enc_layer_specs(cfg):
    return {"ln1": _ln_specs(cfg), "attn": attn.attn_specs(cfg),
            "ln2": _ln_specs(cfg), "ffn": ffn_mod.ffn_specs(cfg)}


def _dec_layer_specs(cfg):
    return {"ln1": _ln_specs(cfg), "self_attn": attn.attn_specs(cfg),
            "ln_x": _ln_specs(cfg), "cross_attn": attn.attn_specs(cfg),
            "ln2": _ln_specs(cfg), "ffn": ffn_mod.ffn_specs(cfg)}


@dataclass
class EncDecModel:
    cfg: ModelConfig

    def specs(self):
        cfg = self.cfg
        return {
            "embed": ParamSpec((cfg.vocab, cfg.d_model),
                               ("vocab", "embed_fsdp"), init="embed",
                               scale=1.0),
            "frontend_proj": ParamSpec((cfg.d_model, cfg.d_model),
                                       ("embed_fsdp", None)),
            "encoder": stack_specs(_enc_layer_specs(cfg),
                                   cfg.encoder_layers, None),
            "enc_norm": _ln_specs(cfg),
            "decoder": stack_specs(_dec_layer_specs(cfg), cfg.n_layers,
                                   None),
            "final_norm": _ln_specs(cfg),
        }

    def init(self, key):
        return init_params(self.specs(), key, self.cfg.pdtype)

    # ---- encoder ----
    def encode(self, params, frontend_embeds, *, mesh=None, rules=None):
        cfg = self.cfg
        x = frontend_embeds.astype(cfg.cdtype) \
            @ params["frontend_proj"].astype(cfg.cdtype)
        S = x.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(cfg.cdtype)
        x = constrain(x, ACT_SPEC, mesh, rules)

        def layer(x, lp):
            h = layer_norm(x, lp["ln1"]["g"], lp["ln1"]["b"])
            y = attn.attention_block(lp["attn"], h, cfg, causal=False,
                                     mesh=mesh, rules=rules)
            x = x + y.astype(x.dtype)
            h = layer_norm(x, lp["ln2"]["g"], lp["ln2"]["b"])
            x = x + ffn_mod.ffn_block(lp["ffn"], h, cfg).astype(x.dtype)
            return constrain(x, ACT_SPEC, mesh, rules), None

        if cfg.remat:
            from repro.models.transformer import remat_policy_of
            layer = jax.checkpoint(layer, policy=remat_policy_of(cfg))
        x, _ = jax.lax.scan(layer, x, params["encoder"])
        return layer_norm(x, params["enc_norm"]["g"],
                          params["enc_norm"]["b"])

    # ---- decoder (full sequence: train / scoring) ----
    def forward(self, params, tokens, *, frontend_embeds, mesh=None,
                rules=None):
        cfg = self.cfg
        memory = self.encode(params, frontend_embeds, mesh=mesh,
                             rules=rules)
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
        S = x.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(cfg.cdtype)
        x = constrain(x, ACT_SPEC, mesh, rules)

        def layer(x, lp):
            h = layer_norm(x, lp["ln1"]["g"], lp["ln1"]["b"])
            y = attn.attention_block(lp["self_attn"], h, cfg, causal=True,
                                     mesh=mesh, rules=rules)
            x = x + y.astype(x.dtype)
            h = layer_norm(x, lp["ln_x"]["g"], lp["ln_x"]["b"])
            y = attn.cross_attention_block(lp["cross_attn"], h, memory, cfg)
            x = x + y.astype(x.dtype)
            h = layer_norm(x, lp["ln2"]["g"], lp["ln2"]["b"])
            x = x + ffn_mod.ffn_block(lp["ffn"], h, cfg).astype(x.dtype)
            return constrain(x, ACT_SPEC, mesh, rules), None

        if cfg.remat:
            from repro.models.transformer import remat_policy_of
            layer = jax.checkpoint(layer, policy=remat_policy_of(cfg))
        x, _ = jax.lax.scan(layer, x, params["decoder"])
        x = layer_norm(x, params["final_norm"]["g"],
                       params["final_norm"]["b"])
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(cfg.cdtype),
                            preferred_element_type=jnp.float32)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch, *, mesh=None, rules=None):
        logits, aux = self.forward(
            params, batch["tokens"],
            frontend_embeds=batch["frontend_embeds"], mesh=mesh,
            rules=rules)
        ce = softmax_cross_entropy(logits, batch["labels"], self.cfg.z_loss)
        loss = jnp.mean(ce)
        return loss, {"ce_loss": loss, "aux_loss": aux, "total_loss": loss}

    # ---- decode: cache self-attn KV + precomputed encoder memory ----
    def init_caches(self, batch: int, max_seq: int):
        cfg = self.cfg
        cs = attn.CacheSpec(batch, cfg.n_kv_heads, max_seq, cfg.hd,
                            cfg.cdtype)
        per_layer = attn.init_cache(cs)
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (cfg.n_layers,) + a.shape),
            per_layer)
        return {"states": states, "pos": jnp.zeros((batch,), jnp.int32)}

    def decode_step(self, params, tokens_t, caches, memory, *, mesh=None,
                    rules=None):
        cfg = self.cfg
        B = tokens_t.shape[0]
        x = jnp.take(params["embed"], tokens_t, axis=0).astype(cfg.cdtype)
        pos = caches["pos"]
        # sinusoidal position of the current token
        table = sinusoidal_positions(
            int(caches["states"]["k"].shape[3]), cfg.d_model)
        x = x + jnp.take(table, jnp.minimum(pos, table.shape[0] - 1),
                         axis=0)[:, None].astype(cfg.cdtype)

        def layer(x, xs):
            lp, st = xs
            h = layer_norm(x, lp["ln1"]["g"], lp["ln1"]["b"])
            y, st = attn.decode_attention(lp["self_attn"], h, st, pos, cfg)
            x = x + y.astype(x.dtype)
            h = layer_norm(x, lp["ln_x"]["g"], lp["ln_x"]["b"])
            y = attn.cross_attention_block(lp["cross_attn"], h, memory, cfg)
            x = x + y.astype(x.dtype)
            h = layer_norm(x, lp["ln2"]["g"], lp["ln2"]["b"])
            x = x + ffn_mod.ffn_block(lp["ffn"], h, cfg).astype(x.dtype)
            return x, st

        x, states = jax.lax.scan(layer, x,
                                 (params["decoder"], caches["states"]))
        x = layer_norm(x, params["final_norm"]["g"],
                       params["final_norm"]["b"])
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(cfg.cdtype),
                            preferred_element_type=jnp.float32)
        return logits, {"states": states, "pos": pos + 1}
