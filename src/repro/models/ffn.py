"""Dense FFN blocks (SwiGLU / GELU-MLP) with TP sharding."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import ParamSpec, gelu, silu
from repro.parallel.sharding import constrain
from .config import ModelConfig


def ffn_specs(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w1": ParamSpec((D, F), ("embed_fsdp", "mlp")),
            "w3": ParamSpec((D, F), ("embed_fsdp", "mlp")),
            "w2": ParamSpec((F, D), ("mlp", "embed_fsdp")),
        }
    return {
        "w1": ParamSpec((D, F), ("embed_fsdp", "mlp")),
        "b1": ParamSpec((F,), ("mlp",), init="zeros"),
        "w2": ParamSpec((F, D), ("mlp", "embed_fsdp")),
        "b2": ParamSpec((D,), (None,), init="zeros"),
    }


def ffn_block(p, x, cfg: ModelConfig):
    cd = cfg.cdtype
    x = x.astype(cd)
    if cfg.act == "swiglu":
        h = silu(x @ p["w1"].astype(cd)) * (x @ p["w3"].astype(cd))
        h = constrain(h, ("batch", None, "mlp"))
        return h @ p["w2"].astype(cd)
    h = gelu(x @ p["w1"].astype(cd) + p["b1"].astype(cd))
    h = constrain(h, ("batch", None, "mlp"))
    return h @ p["w2"].astype(cd) + p["b2"].astype(cd)
