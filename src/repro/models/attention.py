"""GQA attention: training/prefill, KV-cache decode, optional Ulysses SP.

Sharding strategy (logical axes, resolved per mesh by the rules engine):

* train/prefill: activations ``(batch, seq, embed)``; heads sharded over
  "model" (TP).  With ``use_ulysses`` the sequence is sharded over "model"
  outside attention and re-sharded to heads via the *factorized all-to-all*
  (the paper's collective) around the attention core.
* decode: the KV cache is sharded ``(batch, kv_heads, seq_sp, head)`` —
  sequence over "model" when kv_heads cannot absorb the TP axis (GQA with
  few KV heads), which makes XLA lower the softmax into the
  flash-decoding-style partial-max/partial-sum collective combine.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.common import ParamSpec, apply_rope, dense
from repro.parallel.sharding import constrain
from .config import ModelConfig


def attn_specs(cfg: ModelConfig) -> dict:
    """Megatron column/row-parallel attention projections: heads over
    "model" (so q/k/v dots contract a REPLICATED dim and shard the head
    output — no partial-sum all-reduce), embed rows over the FSDP axes.
    Giving "model" to the embed dim instead costs an f32 all-reduce of
    every projection output (measured 2x step time; EXPERIMENTS §Perf)."""
    D, hd, Hq, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    specs = {
        "wq": ParamSpec((D, Hq, hd), ("embed_fsdp", "heads", None)),
        "wk": ParamSpec((D, Hkv, hd), ("embed_fsdp", "kv_heads", None)),
        "wv": ParamSpec((D, Hkv, hd), ("embed_fsdp", "kv_heads", None)),
        "wo": ParamSpec((Hq, hd, D), ("heads", None, "embed_fsdp")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((Hq, hd), ("heads", None), init="zeros")
        specs["bk"] = ParamSpec((Hkv, hd), ("kv_heads", None), init="zeros")
        specs["bv"] = ParamSpec((Hkv, hd), ("kv_heads", None), init="zeros")
    return specs


def _project_qkv(p, x, cfg: ModelConfig, positions):
    cd = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bhsk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bhsk", x.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bhsk", x.astype(cd), p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)[None, :, None, :]
        k = k + p["bk"].astype(cd)[None, :, None, :]
        v = v + p["bv"].astype(cd)[None, :, None, :]
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg: ModelConfig, *, causal=True,
                    positions=None, mesh=None, rules=None):
    """Full self-attention over x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _project_qkv(p, x, cfg, positions)        # (B, H, S, hd)

    if cfg.use_ulysses and mesh is not None and "model" in mesh.shape \
            and mesh.shape["model"] > 1:
        from repro.parallel.ulysses import ulysses_attention
        out = ulysses_attention(q, k, v, cfg, causal=causal, mesh=mesh,
                                rules=rules)
    else:
        q = constrain(q, ("batch", "heads", None, None))
        k = constrain(k, ("batch", "kv_heads", None, None))
        v = constrain(v, ("batch", "kv_heads", None, None))
        out = kops.attention(q, k, v, causal=causal, window=cfg.window,
                             impl=cfg.attention_impl)
    out = constrain(out, ("batch", "heads", None, None))
    y = jnp.einsum("bhsk,hkd->bsd", out.astype(cfg.cdtype),
                   p["wo"].astype(cfg.cdtype))
    return y


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheSpec:
    """Layout of one layer's KV cache."""
    batch: int
    n_kv: int
    max_seq: int
    head_dim: int
    dtype: object

    @property
    def shape(self):
        return (self.batch, self.n_kv, self.max_seq, self.head_dim)

    @property
    def logical(self):
        # seq over "model" when kv_heads can't absorb TP (GQA decode);
        # resolver drops what doesn't divide.
        return ("batch", "kv_heads", "seq_sp", None)


def init_cache(cache_spec: CacheSpec):
    z = jnp.zeros(cache_spec.shape, cache_spec.dtype)
    # slot_pos[b, s] = absolute position stored in slot s (-1 = empty);
    # supports both linear caches (slot == position) and ring buffers
    # (sliding window: slot == position % window).
    pos_map = jnp.full((cache_spec.batch, cache_spec.max_seq), -1,
                       jnp.int32)
    return {"k": z, "v": z, "slot_pos": pos_map}


def decode_attention(p, x, cache, position, cfg: ModelConfig):
    """One-token decode: x (B, 1, D); cache {k,v}: (B, Hkv, W, hd);
    position: (B,) int32 current absolute position.  Returns (y, cache').

    The cache is a ring buffer of W slots (W = window for SWA, max_seq
    otherwise): the new KV overwrites slot ``position % W`` and masking is
    driven by the per-slot absolute positions, so a 500k-token stream with
    a 4k window touches only 4k slots.
    """
    B = x.shape[0]
    W = cache["k"].shape[2]
    slot = position % W
    q, k_new, v_new = _project_qkv(p, x, cfg, position[:, None])

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, s: jax.lax.dynamic_update_slice(
                cb, nb, (0, s, 0)))(c, new, slot)
    cache = {
        "k": upd(cache["k"], k_new.astype(cache["k"].dtype)),
        "v": upd(cache["v"], v_new.astype(cache["v"].dtype)),
        "slot_pos": jax.vmap(
            lambda m, s, pos: m.at[s].set(pos))(cache["slot_pos"], slot,
                                                position),
    }
    logical = CacheSpec(0, 0, 0, 0, None).logical
    k = constrain(cache["k"], logical)
    v = constrain(cache["v"], logical)

    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(cfg.hd)
    qf = q.astype(jnp.float32)                      # (B, Hq, 1, hd)
    qf = qf.reshape(B, Hkv, group, cfg.hd)
    logits = jnp.einsum("bhgk,bhsk->bhgs", qf,
                        k.astype(jnp.float32)) * scale    # (B,Hkv,g,W)
    slot_pos = cache["slot_pos"]                          # (B, W)
    mask = (slot_pos >= 0) & (slot_pos <= position[:, None])
    if cfg.window is not None:
        mask &= slot_pos > position[:, None] - cfg.window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsk->bhgk", probs, v.astype(jnp.float32))
    out = out.reshape(B, Hq, 1, cfg.hd).astype(cfg.cdtype)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))
    return y, cache


def cross_attention_block(p, x, memory, cfg: ModelConfig):
    """Encoder-decoder cross attention: queries from x, KV from memory."""
    B, S, D = x.shape
    cd = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bhsk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bhsk", memory.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bhsk", memory.astype(cd), p["wv"].astype(cd))
    out = kops.attention(q, k, v, causal=False, impl=cfg.attention_impl)
    return jnp.einsum("bhsk,hkd->bsd", out.astype(cd), p["wo"].astype(cd))
