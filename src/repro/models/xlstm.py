"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Follows arXiv:2405.04517: the mLSTM cell keeps a per-head matrix memory
``C: (hd, hd)`` with exponential input gating and a stabilizer state; the
sLSTM cell keeps scalar memories with exponential gating.  Both are
``lax.scan`` recurrences (state O(B*H*hd^2) / O(B*D)) with single-step
decode — xLSTM therefore runs the ``long_500k`` shape.

Block structure (paper Fig. 9/10 simplified): mLSTM = pre-norm ->
up-projection (2x) -> causal conv + q/k/v -> mLSTM cell -> group norm ->
gated (SiLU) down-projection.  sLSTM = pre-norm -> sLSTM cell (4 gates) ->
group norm -> GLU-style projection (4/3 factor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm, silu
from .config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    Din = 2 * D                      # up-projection factor 2
    H = cfg.n_heads
    hd = Din // H
    return {
        "up": ParamSpec((D, 2 * Din), ("embed_fsdp", "mlp")),
        "wq": ParamSpec((Din, Din), ("mlp", None)),
        "wk": ParamSpec((Din, Din), ("mlp", None)),
        "wv": ParamSpec((Din, Din), ("mlp", None)),
        "wif": ParamSpec((Din, 2 * H), ("mlp", None)),  # i/f gate preacts
        "wo": ParamSpec((Din, Din), ("mlp", None)),     # output gate
        "gn": ParamSpec((Din,), ("mlp",), init="ones"),
        "down": ParamSpec((Din, D), ("mlp", "embed_fsdp")),
    }


def mlstm_block(p, x, cfg: ModelConfig, state=None):
    """x: (B, S, D) -> (y, state).  state: {C: (B,H,hd,hd), n: (B,H,hd),
    m: (B,H)}."""
    B, S, D = x.shape
    cd = cfg.cdtype
    H = cfg.n_heads
    Din = 2 * D
    hd = Din // H

    up = x.astype(cd) @ p["up"].astype(cd)
    xi, z = jnp.split(up, 2, axis=-1)                     # (B,S,Din) each

    def heads(w):
        return (xi.astype(jnp.float32)
                @ w.astype(jnp.float32)).reshape(B, S, H, hd)
    q, k, v = heads(p["wq"]), heads(p["wk"]), heads(p["wv"])
    k = k / jnp.sqrt(hd)
    gates = xi.astype(jnp.float32) @ p["wif"].astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates.reshape(B, S, 2, H), 2, axis=2)
    i_pre, f_pre = i_pre[:, :, 0], f_pre[:, :, 0]         # (B, S, H)
    o_gate = jax.nn.sigmoid(
        xi.astype(jnp.float32) @ p["wo"].astype(jnp.float32))

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    if cfg.xlstm_chunk and S > cfg.xlstm_chunk \
            and S % cfg.xlstm_chunk == 0:
        h, (Cf, nf, mf) = _mlstm_chunked(
            q, k, v, i_pre, f_pre, (C0, n0, m0), cfg.xlstm_chunk,
            step_remat=cfg.recurrent_step_remat)
        h = h.reshape(B, S, Din)
        h = rms_norm(h, p["gn"]) * o_gate
        y = (h.astype(cd) * silu(z)) @ p["down"].astype(cd)
        return y, {"C": Cf, "n": nf, "m": mf}

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp    # (B,H,hd) x3, (B,H) x2
        log_f = -jax.nn.softplus(-f_t)   # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, i_t)
        i_s = jnp.exp(i_t - m_new)[..., None]              # (B,H,1)
        f_s = jnp.exp(log_f + m - m_new)[..., None]
        C = f_s[..., None] * C + i_s[..., None] * \
            (v_t[..., :, None] * k_t[..., None, :])        # (B,H,hd,hd)
        n = f_s * n + i_s * k_t
        num = jnp.einsum("bhij,bhj->bhi", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q_t)),
                          jnp.exp(-m_new))[..., None]
        h = num / den
        return (C, n, m_new), h

    if cfg.recurrent_step_remat:
        step = jax.checkpoint(step)
    q_s = jnp.moveaxis(q, 1, 0)      # (S, B, H, hd)
    k_s = jnp.moveaxis(k, 1, 0)
    v_s = jnp.moveaxis(v, 1, 0)
    i_s_seq = jnp.moveaxis(i_pre, 1, 0)   # (S, B, H)
    f_s_seq = jnp.moveaxis(f_pre, 1, 0)
    (Cf, nf, mf), hs = jax.lax.scan(
        step, (C0, n0, m0), (q_s, k_s, v_s, i_s_seq, f_s_seq))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, Din)        # (B,S,H,hd)->
    h = rms_norm(h, p["gn"]) * o_gate
    y = (h.astype(cd) * silu(z)) @ p["down"].astype(cd)
    return y, {"C": Cf, "n": nf, "m": mf}


def _mlstm_chunked(q, k, v, i_pre, f_pre, state, L: int,
                   step_remat: bool = False):
    """Chunkwise-parallel mLSTM (beyond-paper perf optimization).

    The per-step recurrence reads+writes the (hd, hd) matrix memory every
    token — the dominant HBM term for xLSTM training (hd^2 >> hd).  The
    chunkwise form (cf. GLA / xLSTM official kernels) touches the state
    once per chunk of L tokens and handles intra-chunk interactions with
    an (L, L) attention-like matrix:

      a_s  = log i_s - b_s                (b_s = cumsum of log f within chunk)
      M_t  = max(m_prev, cummax_s<=t a_s)  (running stabilizer)
      S_ts = (q_t . k_s) e^{a_s - M_t}     for s <= t  (intra)
      inter_t = e^{m_prev - M_t} (C_prev q_t)
      h_t  = (inter_t + sum_s S_ts v_s) / max(|l_t|, e^{-(b_t + M_t)})
      l_t  = e^{m_prev - M_t}(n_prev . q_t) + sum_s S_ts

    State I/O drops by ~L; validated against the per-step scan in
    tests/test_models.py.
    """
    B, S, H, hd = q.shape
    nC = S // L

    def chunk_step(carry, inp):
        C, n, m = carry                      # (B,H,hd,hd),(B,H,hd),(B,H)
        qc, kc, vc, ic, fc = inp             # (B,L,H,hd) x3, (B,L,H) x2
        qc = qc.transpose(0, 2, 1, 3)        # (B,H,L,hd)
        kc = kc.transpose(0, 2, 1, 3)
        vc = vc.transpose(0, 2, 1, 3)
        ic = ic.transpose(0, 2, 1)           # (B,H,L)
        fc = fc.transpose(0, 2, 1)

        log_f = -jax.nn.softplus(-fc)        # (B,H,L)
        b = jnp.cumsum(log_f, axis=-1)       # b_t
        a = ic - b                           # a_s
        M = jnp.maximum(m[..., None], jax.lax.cummax(a, axis=2))  # (B,H,L)

        # intra-chunk scores
        scores = jnp.einsum("bhtd,bhsd->bhts", qc, kc)
        decay = jnp.exp(a[:, :, None, :] - M[..., None])   # e^{a_s - M_t}
        mask = jnp.tril(jnp.ones((L, L), bool))
        W = jnp.where(mask[None, None], scores * decay, 0.0)

        inter_scale = jnp.exp(m[..., None] - M)            # (B,H,L)
        inter_num = jnp.einsum("bhij,bhtj->bhti", C, qc) \
            * inter_scale[..., None]
        num = inter_num + jnp.einsum("bhts,bhsd->bhtd", W, vc)
        l = jnp.einsum("bhj,bhtj->bht", n, qc) * inter_scale \
            + jnp.sum(W, axis=-1)
        m_t = b + M
        den = jnp.maximum(jnp.abs(l), jnp.exp(-m_t))[..., None]
        h = num / den                                       # (B,H,L,hd)

        # end-of-chunk state
        M_L = M[..., -1]
        # e^{b_L - b_s + li_s - m_new} = e^{a_s - M_L}  (m_new = b_L + M_L)
        w_end = jnp.exp(a - M_L[..., None])
        C_new = jnp.exp(m - M_L)[..., None, None] * C + \
            jnp.einsum("bhs,bhsd,bhse->bhde", w_end, vc, kc)
        n_new = jnp.exp(m - M_L)[..., None] * n + \
            jnp.einsum("bhs,bhsd->bhd", w_end, kc)
        m_new = b[..., -1] + M_L
        return (C_new, n_new, m_new), h.transpose(0, 2, 1, 3)  # (B,L,H,hd)

    qs = q.reshape(B, nC, L, H, hd).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nC, L, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nC, L, H, hd).transpose(1, 0, 2, 3, 4)
    is_ = i_pre.reshape(B, nC, L, H).transpose(1, 0, 2, 3)
    fs = f_pre.reshape(B, nC, L, H).transpose(1, 0, 2, 3)
    if step_remat:
        chunk_step = jax.checkpoint(chunk_step)
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, state,
                                    (qs, ks, vs, is_, fs))
    # hs: (nC, B, L, H, hd) -> (B, S, H, hd)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return h, (Cf, nf, mf)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H = cfg.n_heads
    F = max(1, 4 * D // 3) // 8 * 8 or 8
    return {
        "w_gates": ParamSpec((D, 4 * D), ("embed_fsdp", "mlp")),
        # block-diagonal per-head recurrence (xLSTM paper §sLSTM: heads
        # do not mix through R) — H x smaller recurrent matrix, read
        # every timestep, so this also cuts the recurrent HBM term by H.
        "r_gates": ParamSpec((H, D // H, 4 * (D // H)), (None, None, None)),
        "gn": ParamSpec((D,), (None,), init="ones"),
        "up1": ParamSpec((D, F), ("embed_fsdp", "mlp")),
        "up2": ParamSpec((D, F), ("embed_fsdp", "mlp")),
        "down": ParamSpec((F, D), ("mlp", "embed_fsdp")),
    }


def slstm_block(p, x, cfg: ModelConfig, state=None):
    """x: (B, S, D) -> (y, state).  state: {c,n,m,h}: (B, D) each."""
    B, S, D = x.shape
    cd = cfg.cdtype
    H = cfg.n_heads
    Dh = D // H

    if state is None:
        z = jnp.zeros((B, D), jnp.float32)
        c0, n0, h0 = z, z + 1e-6, z
        m0 = jnp.full((B, D), -1e30, jnp.float32)
    else:
        c0, n0, m0, h0 = state["c"], state["n"], state["m"], state["h"]

    wx = x.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32)
    r = p["r_gates"].astype(jnp.float32)        # (H, Dh, 4*Dh)

    def step(carry, wx_t):
        c, n, m, h = carry
        # block-diagonal recurrence: (B,H,Dh) x (H,Dh,4Dh) -> (B,H,4Dh)
        rec = jnp.einsum("bhd,hde->bhe", h.reshape(B, H, Dh), r)
        pre = wx_t + rec.reshape(B, H, 4, Dh).transpose(0, 2, 1, 3) \
            .reshape(B, 4 * D)
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c = f_s * c + i_s * jnp.tanh(zt)
        n = f_s * n + i_s
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    if cfg.recurrent_step_remat:
        step = jax.checkpoint(step)
    (cf, nf, mf, hf), hs = jax.lax.scan(
        step, (c0, n0, m0, h0), jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                             # (B, S, D)
    h = rms_norm(h, p["gn"]).astype(cd)
    y = (silu(h @ p["up1"].astype(cd)) * (h @ p["up2"].astype(cd))) \
        @ p["down"].astype(cd)
    return y, {"c": cf, "n": nf, "m": mf, "h": hf}
