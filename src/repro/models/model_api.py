"""Unified model construction + step functions (train / prefill / decode).

``build_model(cfg)`` returns a ``Model`` (decoder-only) or ``EncDecModel``
(whisper).  ``make_train_step`` / ``make_serve_step`` produce the jittable
functions the launcher, dry-run and examples all share.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecModel
from repro.models.transformer import Model
from repro.parallel.sharding import ShardingRules


def build_model(cfg: ModelConfig):
    if cfg.encoder_layers > 0:
        return EncDecModel(cfg)
    return Model(cfg)


def make_loss_fn(model, mesh=None, rules: ShardingRules | None = None):
    def loss_fn(params, batch):
        return model.loss(params, batch, mesh=mesh, rules=rules)
    return loss_fn


def make_train_step(model, optimizer, mesh=None,
                    rules: ShardingRules | None = None,
                    grad_accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_accum > 1`` splits the batch into microbatches inside the
    jitted step (lax.scan), averaging gradients before one optimizer
    update — the memory knob for large global batches.
    """
    loss_fn = make_loss_fn(model, mesh, rules)

    def grads_of(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            grads, metrics = grads_of(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)

            def body(acc, mb):
                g, m = grads_of(params, mb)
                acc_g, acc_m = acc
                return (jax.tree.map(jnp.add, acc_g, g),
                        jax.tree.map(jnp.add, acc_m, m)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"ce_loss": 0.0, "aux_loss": 0.0, "total_loss": 0.0}
            zero_m = jax.tree.map(jnp.float32, zero_m)
            (gsum, msum), _ = jax.lax.scan(body, (zero_g, zero_m), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            metrics = jax.tree.map(lambda m: m / grad_accum, msum)
        params, opt_state, gnorm = optimizer.update(params, grads,
                                                    opt_state)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_serve_step(model, mesh=None, rules: ShardingRules | None = None,
                    memory_fn=None):
    """One greedy decode step: (params, caches, tokens_t[, memory]) ->
    (next_tokens, logits, caches)."""
    def serve_step(params, caches, tokens_t, memory=None):
        if memory is not None:
            logits, caches = model.decode_step(params, tokens_t, caches,
                                               memory, mesh=mesh,
                                               rules=rules)
        else:
            logits, caches = model.decode_step(params, tokens_t, caches,
                                               mesh=mesh, rules=rules)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return serve_step


def make_prefill_fn(model, mesh=None, rules: ShardingRules | None = None):
    """Full-sequence prefill returning last-position logits (the
    prefill_32k dry-run shape lowers this)."""
    def prefill(params, tokens, frontend_embeds=None):
        if frontend_embeds is not None:
            logits, _ = model.forward(params, tokens, mesh=mesh,
                                      rules=rules,
                                      frontend_embeds=frontend_embeds)
        else:
            logits, _ = model.forward(params, tokens, mesh=mesh,
                                      rules=rules)
        return logits[:, -1]

    return prefill
