"""Mixture-of-Experts with expert parallelism over the *factorized torus
all-to-all* — the primary consumer of the paper's collective.

Dispatch layout (capacity-based, GShard-style):

* The EP group spans the mesh axes ``ep_axes(mesh)`` — ``("data",)`` on a
  single pod, ``("data", "pod")`` across pods.  Virtual expert rank
  ``v = data + |data| * pod``: experts are *owned* along "data" and
  *replicated* across "pod" (storage stays exact ``(E, ...)``; the virtual
  ``(G, ...)`` view is a ``reshape`` when ``E >= G`` and a ``tile`` when
  ``E < G`` — tiling makes replica gradients sum automatically).
* Each device scatters its top-k routed tokens into ``(G, E_loc, C, D)``
  composite blocks — *exactly* the paper's ``p``-block send buffer — and
  one ``A2APlan`` collective per direction moves them: on the multi-pod
  mesh this is the d=2 schedule (ICI "data" round, then DCN "pod" round),
  the paper's hierarchical decomposition.
* Expert FFN runs as a grouped matmul (``kernels.expert_matmul``) with the
  hidden dim tensor-parallel over "model" (one psum per layer).
* ``capacity_factor=None`` switches to **dropless** dispatch: the
  collective becomes the ragged Alltoallv (``core.plan
  .plan_ragged_all_to_all``) with the per-rank window sized to the worst
  case, per-rank send counts from the router, and padding waste reported
  as the plan's bucket occupancy — no token is ever dropped.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from repro.core.autotune import lookup_ragged_measured
from repro.core.comm import torus_comm
from repro.core.ragged import next_pow2
from repro.core.tuning import choose_ragged_algorithm, default_links
from repro.kernels import ops as kops
from repro.models.common import ParamSpec, silu, gelu
from repro.parallel.sharding import ShardingRules, constrain, ep_axes, \
    resolve_spec
from .config import ModelConfig


def moe_specs(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((D, E), (None, None), dtype=jnp.float32),
        "w1": ParamSpec((E, D, F), ("expert", "embed_fsdp", "mlp")),
        "w3": ParamSpec((E, D, F), ("expert", "embed_fsdp", "mlp")),
        "w2": ParamSpec((E, F, D), ("expert", "mlp", "embed_fsdp")),
    }


def _group_geometry(cfg: ModelConfig, mesh):
    """(axes, G, E_loc, R): EP axes, group size, experts/rank, replicas."""
    if mesh is None:
        return (), 1, cfg.n_experts, 1
    axes = ep_axes(mesh)
    G = math.prod(mesh.shape[a] for a in axes)
    E = cfg.n_experts
    if E >= G:
        if E % G:
            raise ValueError(f"n_experts={E} not divisible by EP group {G}")
        return axes, G, E // G, 1
    if G % E:
        raise ValueError(f"EP group {G} not divisible by n_experts={E}")
    return axes, G, 1, G // E


def _virtual_weights(w, G: int):
    """(E, ...) -> (G, E_loc, ...) virtual-expert view (reshape or tile)."""
    E = w.shape[0]
    if E >= G:
        return w.reshape(G, E // G, *w.shape[1:])
    R = G // E
    return jnp.tile(w, (R,) + (1,) * (w.ndim - 1)) \
        .reshape(G, 1, *w.shape[1:])


def _capacity(cfg: ModelConfig, n_tokens: int, n_slots: int) -> int:
    # A single expert can receive at most n_tokens rows from one device
    # (the top_k experts of a token are distinct), so the capacity is
    # clamped there: tiny batches must not pad past the routed tokens.
    hard = max(1, n_tokens)
    if cfg.capacity_factor is None:    # dropless: worst case, no slack
        return hard
    c = math.ceil(cfg.capacity_factor * cfg.top_k * n_tokens / n_slots)
    return min(max(8, -(-c // 8) * 8), hard)  # 8-aligned, then clamped


def moe_ep_comm(cfg: ModelConfig, mesh, axes):
    """The cached Cartesian communicator of the EP group — the API root
    every MoE collective is constructed through (``core.comm``).  Fetched
    from the comm registry on every later layer/step, so the torus
    factorization and device fingerprint resolve once per (devices, EP
    axes, variant)."""
    if not axes or mesh is None:
        return None
    return torus_comm(mesh, axes, variant=cfg.a2a_variant)


def moe_a2a_plan(cfg: ModelConfig, mesh, axes, E_loc: int, C: int):
    """The one A2APlan shared by dispatch and combine for this MoE layer.

    Resolved once per (mesh devices, EP axes, block shape, dtype, config
    knobs) through the EP group's :class:`~repro.core.comm.TorusComm` and
    fetched from the plan registry on every later layer/step — the
    paper's cached-communicator amortization.  ``cfg.a2a_backend``
    parameterizes plan construction here and nowhere else; with
    ``"autotune"`` the dispatch/combine collective replays the measured
    winner recorded in the tuning DB for exactly this (devices, EP axes,
    block, dtype) key, falling back to the analytic model on a miss — an
    explicit ``core.autotune.autotune(...)`` run warms the DB offline.
    """
    comm = moe_ep_comm(cfg, mesh, axes)
    if comm is None:
        return None
    return comm.all_to_all(
        block_shape=(E_loc, C, cfg.d_model), dtype=cfg.cdtype,
        backend=cfg.a2a_backend, n_chunks=cfg.a2a_chunks,
        max_chunks=cfg.a2a_chunks or 4)


def moe_ragged_a2a_plan(cfg: ModelConfig, mesh, axes, E_loc: int, C: int,
                        n_loc: int):
    """The RaggedA2APlan for dropless dispatch/combine
    (``capacity_factor=None``).

    One ragged row is one token embedding; each destination rank's bucket
    window holds its ``(E_loc, C)`` expert-strided slots, so ``max_count``
    is the per-rank window ``E_loc * C`` while the *expected* per-rank
    payload is ``top_k * n_loc / p`` rows — the ratio is the plan's
    occupancy estimate, the quantity dropless mode trades for never
    dropping a token.  Same registry/caching semantics as
    :func:`moe_a2a_plan` (both construct through :func:`moe_ep_comm`);
    ``cfg.a2a_backend`` resolves the padded data plan identically.
    """
    comm = moe_ep_comm(cfg, mesh, axes)
    if comm is None:
        return None
    window = E_loc * C
    avg = min(float(window), max(1.0, cfg.top_k * n_loc / comm.p))
    return comm.ragged_all_to_all(
        row_shape=(cfg.d_model,), dtype=cfg.cdtype,
        max_count=window, avg_count=avg, backend=cfg.a2a_backend,
        n_chunks=cfg.a2a_chunks, max_chunks=cfg.a2a_chunks or 4)


def moe_dropless_a2a_plan(cfg: ModelConfig, mesh, axes, E_loc: int, C: int,
                          n_loc: int):
    """Dropless plan chooser: ragged (dense-bucketed) vs sparse
    (neighborhood) Alltoallv, decided by the router's expected density.

    The expected nonzero fraction of the p x p count matrix follows the
    Poisson occupancy of ``top_k * n_loc / p`` tokens per (source, dest)
    pair: ``rho ~= 1 - exp(-top_k * n_loc / p)``.  With
    ``cfg.a2a_backend == "autotune"`` the measured ragged-vs-sparse
    winner recorded by :func:`core.autotune.autotune_ragged` is replayed
    for exactly this (devices, EP axes, row, dtype, window, density
    decade) key; on a miss — and for every analytic backend — the
    density-aware :func:`core.tuning.choose_ragged_algorithm` prices
    both and the sparse plan is used only when it wins.  Either way the
    returned plan exposes the same ``forward``/``reverse`` bucketed
    contract, so :func:`_moe_inner` is backend-agnostic.
    """
    comm = moe_ep_comm(cfg, mesh, axes)
    if comm is None:
        return None
    window = E_loc * C
    lam = cfg.top_k * n_loc / comm.p
    density = min(1.0, max(1e-6, 1.0 - math.exp(-lam)))
    backend = None
    if cfg.a2a_backend == "autotune":
        rec = lookup_ragged_measured(
            comm.dev_key, comm.dims, comm.axis_names, (cfg.d_model,),
            cfg.cdtype, window, cfg.a2a_variant, density)
        if rec is not None:
            backend = rec["winner"]["backend"]
    if backend is None:
        row_bytes = cfg.d_model * jnp.dtype(cfg.cdtype).itemsize
        sched = choose_ragged_algorithm(
            comm.dims, default_links(comm.axis_names), row_bytes,
            next_pow2(window), max_chunks=cfg.a2a_chunks or 4,
            density=density)
        backend = sched.kind
    if backend == "sparse":
        avg = min(float(window), max(1.0, cfg.top_k * n_loc / comm.p))
        return comm.sparse_all_to_all(
            row_shape=(cfg.d_model,), dtype=cfg.cdtype, max_count=window,
            avg_count=avg, density=density)
    return moe_ragged_a2a_plan(cfg, mesh, axes, E_loc, C, n_loc)


def _moe_inner(x, router_w, w1, w3, w2, *, cfg: ModelConfig, axes, G, E_loc,
               R, C, tp_axis, reduce_axes, plan=None, ragged_plan=None):
    """Per-device MoE computation (runs inside shard_map, or standalone when
    there is no mesh).  x: (B_loc, S, D); w*: (1, E_loc, ...) local slices
    of the virtual-expert arrays; ``plan`` is the resolved A2APlan (None
    when there is no EP group); ``ragged_plan`` the RaggedA2APlan — or the
    duck-typed SparseA2APlan, same bucketed forward/reverse contract —
    dropless mode routes through instead (``capacity_factor=None``)."""
    B, S, D = x.shape
    N = B * S
    E = cfg.n_experts
    cd = cfg.cdtype
    xt = x.reshape(N, D)
    w1, w3, w2 = w1[0], w3[0], w2[0]

    # ---- routing (f32) ----
    logits = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)     # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- per-expert positions (order: token-major, k-minor) ----
    flat_e = expert_idx.reshape(-1)                              # (N*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_e = jnp.cumsum(onehot, axis=0) - 1                       # inclusive-1
    pos_e = jnp.take_along_axis(pos_e, flat_e[:, None], 1)[:, 0]

    if E >= G:   # experts partitioned over ranks
        v_idx = flat_e // E_loc
        sub_idx = flat_e % E_loc
        slot_pos = pos_e
    else:        # experts replicated R times: round-robin across replicas
        spread = pos_e % R
        v_idx = flat_e + E * spread       # tile layout: replica r at r*E+e
        sub_idx = jnp.zeros_like(flat_e)
        slot_pos = pos_e // R
    keep = slot_pos < C
    c_idx = jnp.where(keep, slot_pos, C)  # C = out-of-bounds -> dropped

    # ---- dispatch scatter: (G, E_loc, C, D) composite blocks ----
    tok_idx = jnp.repeat(jnp.arange(N), cfg.top_k)
    disp = jnp.zeros((G, E_loc, C, D), cd)
    disp = disp.at[v_idx, sub_idx, c_idx].set(
        xt[tok_idx].astype(cd), mode="drop")

    # ---- expert FFN (grouped matmul; TP over `tp_axis` on the hidden dim).
    # Takes any capacity slice (G, E_loc, Cc, D): tokens are independent
    # rows of the grouped matmul, so this doubles as the overlap engine's
    # per-chunk compute stage. ----
    def expert_ffn(recv, _chunk=0):
        Cc = recv.shape[2]
        xe = recv.transpose(1, 0, 2, 3).reshape(E_loc, G * Cc, D)
        h = silu(kops.expert_matmul(xe, w1.astype(cd))) \
            * kops.expert_matmul(xe, w3.astype(cd)) \
            if cfg.act == "swiglu" else \
            gelu(kops.expert_matmul(xe, w1.astype(cd)))
        ye = kops.expert_matmul(h, w2.astype(cd))      # partial over F shard
        if tp_axis is not None:
            ye = jax.lax.psum(ye, tp_axis)
        return ye.reshape(E_loc, G, Cc, D).transpose(1, 0, 2, 3)

    # ---- the paper's collective, through its resolved A2APlan: backend,
    # chunk count, and round orders were all fixed once at plan time
    # (tuning.choose_algorithm prices tuned|direct|factorized|overlap with
    # per-axis ICI/DCN links); here we only replay the chosen kernel. ----
    def a2a(blocks, reverse=False):
        if plan is None:
            return blocks
        flat = blocks.reshape(G, -1)
        out = plan.reverse(flat) if reverse else plan.forward(flat)
        return out.reshape(blocks.shape)

    if ragged_plan is not None:
        # Dropless (capacity_factor=None): the ragged Alltoallv moves the
        # (E_loc, C) expert-strided window of each destination rank as one
        # bucket of token rows; per-rank send counts (the real routed
        # assignments) drive the counts phase and the occupancy stat, and
        # the combine direction reuses the dispatch's recv counts.  C is
        # the worst case, so `keep` is identically true — no token drops.
        # Combine re-derives slot validity from this device's own routing
        # indices, so recv_counts feeds nothing the output depends on and
        # XLA dead-code-eliminates both counts exchanges here — the
        # counts phase costs nothing in this path; it exists for callers
        # that do consume recv counts (see RaggedA2APlan.forward).
        counts = jnp.zeros((G,), jnp.int32).at[v_idx].add(
            keep.astype(jnp.int32), mode="drop")
        rows = disp.reshape(G, E_loc * C, D)
        recv_rows, recv_counts = ragged_plan.forward(rows, counts)
        recv = recv_rows[:, :E_loc * C].reshape(G, E_loc, C, D)
        recv = checkpoint_name(recv, "moe_recv")
        ye = expert_ffn(recv)
        back_rows, _ = ragged_plan.reverse(
            ye.reshape(G, E_loc * C, D), recv_counts)
        back = back_rows[:, :E_loc * C].reshape(G, E_loc, C, D)
        back = checkpoint_name(back, "moe_back")
    elif plan is not None and plan.backend == "overlap":
        # dispatch-round / expert-FFN / combine-round pipelined per
        # capacity chunk: chunk c+1's rounds hide behind chunk c's FFN.
        # Each chunk's post-dispatch state keeps the "moe_recv" name so the
        # remat_policy="collectives" save list works unchanged.
        back = plan.overlap(
            disp,
            compute_fn=lambda chunk, c: expert_ffn(
                checkpoint_name(chunk, "moe_recv"), c),
            reverse=True, chunk_axis=2)
        back = checkpoint_name(back, "moe_back")
    else:
        recv = checkpoint_name(a2a(disp), "moe_recv")  # (G, E_loc, C, D)
        ye = expert_ffn(recv)
        # ---- reverse collective + combine ----
        back = checkpoint_name(a2a(ye, reverse=True), "moe_back")
    pad = jnp.zeros((G, E_loc, 1, D), cd)
    backp = jnp.concatenate([back, pad], axis=2)       # dropped -> zeros
    yk = backp[v_idx, sub_idx, c_idx]                  # (N*k, D)
    yk = yk.reshape(N, cfg.top_k, D)
    gates = (gate_vals * keep.reshape(N, cfg.top_k)).astype(jnp.float32)
    y = jnp.einsum("nkd,nk->nd", yk.astype(jnp.float32), gates)

    # ---- load-balance aux loss (GShard): E * sum_e f_e * P_e; = 1 when
    # perfectly balanced ----
    f_e = jnp.mean(onehot.astype(jnp.float32), axis=0)   # sums to 1
    p_e = jnp.mean(probs, axis=0)
    if reduce_axes:
        f_e = jax.lax.pmean(f_e, reduce_axes)
        p_e = jax.lax.pmean(p_e, reduce_axes)
    aux = E * jnp.sum(f_e * p_e)
    return y.reshape(B, S, D).astype(x.dtype), aux


def moe_block(p, x, cfg: ModelConfig, mesh=None,
              rules: ShardingRules | None = None):
    """x: (B, S, D) -> (y, aux_loss)."""
    axes, G, E_loc, R = _group_geometry(cfg, mesh)
    B, S, D = x.shape

    w1 = _virtual_weights(p["w1"], G)
    w3 = _virtual_weights(p["w3"], G)
    w2 = _virtual_weights(p["w2"], G)

    if mesh is None:
        C = _capacity(cfg, B * S, max(cfg.n_experts, G))
        return _moe_inner(x, p["router"], w1, w3, w2, cfg=cfg, axes=(),
                          G=G, E_loc=E_loc, R=R, C=C, tp_axis=None,
                          reduce_axes=())

    rules = rules or ShardingRules()
    w1 = constrain(w1, ("expert_virtual", None, None, "mlp"), mesh, rules)
    w3 = constrain(w3, ("expert_virtual", None, None, "mlp"), mesh, rules)
    w2 = constrain(w2, ("expert_virtual", None, "mlp", None), mesh, rules)

    x_spec = resolve_spec(x.shape, ("batch", None, None), mesh, rules)
    part = x_spec[0]
    batch_axes = () if part is None else \
        ((part,) if isinstance(part, str) else tuple(part))
    n_batch_shards = math.prod([mesh.shape[a] for a in batch_axes]) \
        if batch_axes else 1
    n_loc = (B // n_batch_shards) * S
    C = _capacity(cfg, n_loc, max(cfg.n_experts, G))
    tp_axis = "model" if "model" in mesh.shape and mesh.shape["model"] > 1 \
        else None
    reduce_axes = batch_axes

    wv_spec = resolve_spec(w1.shape, ("expert_virtual", None, None, "mlp"),
                           mesh, rules)
    w2_spec = resolve_spec(w2.shape, ("expert_virtual", None, "mlp", None),
                           mesh, rules)
    router_spec = P(None, None)

    # Dropless mode replaces the capacity-padded dense collective with the
    # ragged or sparse-neighborhood plan (density-chosen); otherwise the
    # dense A2APlan path is unchanged.
    if cfg.dropless:
        plan, ragged = None, moe_dropless_a2a_plan(cfg, mesh, axes, E_loc, C,
                                                   n_loc)
    else:
        plan, ragged = moe_a2a_plan(cfg, mesh, axes, E_loc, C), None
    inner = functools.partial(
        _moe_inner, cfg=cfg, axes=axes, G=G, E_loc=E_loc, R=R, C=C,
        tp_axis=tp_axis, reduce_axes=reduce_axes, plan=plan,
        ragged_plan=ragged)

    y, aux = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, router_spec, wv_spec, wv_spec, w2_spec),
        out_specs=(x_spec, P()),
        check_vma=False,   # aux is value-replicated after pmean; see note
    )(x, p["router"], w1, w3, w2)
    return y, aux
