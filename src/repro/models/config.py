"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field

import jax.numpy as jnp

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                         # 0 => no separate FFN (xLSTM)
    vocab: int
    head_dim: int | None = None       # default d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    # Expert capacity factor (GShard-style), or None for *dropless* MoE:
    # dispatch/combine route through the ragged Alltoallv plan
    # (core.plan.plan_ragged_all_to_all) with the per-expert buffer sized
    # to the worst case, so no token is ever dropped and the padding
    # waste is reported as the plan's bucket occupancy instead of being
    # silently shipped as capacity slack.
    capacity_factor: float | None = 1.25
    router_aux_weight: float = 0.01
    moe_every: int = 1                # MoE FFN every k-th layer (jamba: 2)

    # --- attention ---
    window: int | None = None         # sliding-window size (SWA)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    attention_impl: str = "xla"       # xla | pallas | pallas_interpret

    # --- layer mixer pattern (repeating):
    #     attn | mamba | mlstm | slstm | spectral ---
    block_pattern: tuple[str, ...] = ("attn",)

    # --- ssm (mamba) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # Substitute every recurrent mixer (mamba/mlstm/slstm) in
    # block_pattern with the spectral long-convolution layer
    # (models.spectral): same diagonal state-space family, but the
    # full-sequence pass is an FFT causal conv (O(S log S), and
    # sequence-parallel via workloads.fft.PencilFFT) instead of a
    # sequential scan; decode keeps the O(1)-per-token recurrence.
    spectral_long_conv: bool = False

    # --- xlstm: chunkwise-parallel mLSTM chunk length; 0 = per-step
    # recurrence (paper-faithful baseline).  L>0 cuts matrix-memory HBM
    # traffic by ~L (EXPERIMENTS §Perf hillclimb) ---
    xlstm_chunk: int = 0
    # remat each recurrent timestep/chunk body: the bwd pass recomputes
    # step internals from the carried state instead of saving ~17 stacked
    # per-step residual buffers (EXPERIMENTS §Perf hillclimb)
    recurrent_step_remat: bool = False

    # --- frontends / enc-dec ---
    frontend: str | None = None       # vit_stub | audio_stub
    n_frontend_tokens: int = 0
    encoder_layers: int = 0           # >0 => encoder-decoder (whisper)

    # --- numerics ---
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "swiglu"               # swiglu | gelu
    tie_embeddings: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # remat policy: what survives the forward pass of each superblock.
    #   "nothing"  — recompute everything in bwd (min memory, max flops
    #                AND re-runs fwd collectives — the paper-baseline)
    #   "dots"     — save dot outputs w/o batch dims (skips most
    #                recompute of matmuls; moderate memory)
    #   "collectives" — save collective results by name (avoids re-running
    #                all-gathers in bwd; the collective-term optimization)
    remat_policy: str = "nothing"
    z_loss: float = 1e-4

    # --- parallelism hints ---
    use_ulysses: bool = False         # Ulysses SP for attention
    expert_axes: tuple[str, ...] = ("data",)   # EP mesh axes (fastest first)
    a2a_variant: str = "natural"      # factorized A2A variant for EP/SP
    # tuned | autotune | factorized | direct | pipelined | overlap
    # "overlap" pipelines dispatch-round / expert-FFN / combine-round per
    # payload chunk (core.overlap); "tuned" picks backend AND chunk count
    # from the alpha-beta model (tuning.choose_algorithm); "autotune"
    # replays the measured winner from the persistent tuning DB
    # (core.autotune) and falls back to "tuned" semantics on a DB miss —
    # it never measures inside a model step.  These three knobs
    # parameterize A2APlan construction (core.plan.plan_all_to_all)
    # in one place per consumer — moe.moe_a2a_plan and ulysses — and are
    # resolved once per (devices, axes, shape, dtype) plan key; nothing
    # dispatches on these strings at call time.
    a2a_backend: str = "tuned"
    a2a_chunks: int = 0               # payload chunks; 0 = cost-model auto

    def __post_init__(self):
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.n_layers % len(self.block_pattern):
            raise ValueError("n_layers must divide into block_pattern")
        # validate against the plan layer's own backend list so the two
        # can never drift (lazy import: keep config importable without
        # pulling the collective stack in until it's needed)
        from repro.core.plan import BACKENDS
        if self.a2a_backend not in BACKENDS:
            raise ValueError(f"unknown a2a_backend {self.a2a_backend!r}; "
                             f"expected one of {BACKENDS}")

    @property
    def dropless(self) -> bool:
        """Dropless MoE: no capacity factor, ragged dispatch/combine."""
        return self.capacity_factor is None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def superblock(self) -> tuple[tuple[str, str], ...]:
        """Repeating (mixer, ffn) plan; scan iterates over superblocks."""
        period = len(self.block_pattern)
        if self.moe_every > 1:
            period = math.lcm(period, self.moe_every)
        plan = []
        for i in range(period):
            mixer = self.block_pattern[i % len(self.block_pattern)]
            if self.spectral_long_conv and mixer in ("mamba", "mlstm",
                                                     "slstm"):
                mixer = "spectral"
            if self.d_ff == 0:
                ffn = "none"
            elif self.n_experts and (self.moe_every <= 1
                                     or i % self.moe_every == 1):
                ffn = "moe"
            else:
                ffn = "dense"
            plan.append((mixer, ffn))
        return tuple(plan)

    @property
    def n_superblocks(self) -> int:
        n = len(self.superblock)
        assert self.n_layers % n == 0
        return self.n_layers // n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for MODEL_FLOPS = 6*N*D) ----
    def param_count_estimate(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        n_attn = 0
        n_mixer_other = 0
        n_ffn_dense = 0
        n_ffn_moe = 0
        attn_p = D * hd * Hq * 2 + D * hd * Hkv * 2     # q,o + k,v
        Ein = self.ssm_expand * D
        mamba_p = D * Ein * 2 + Ein * self.ssm_conv + \
            Ein * (self.ssm_state * 2 + 1) + Ein * D + Ein * self.ssm_state
        mlstm_p = D * (2 * D) * 2 + (2 * D) * 3 * (2 * D) // 4 + 2 * D * D
        slstm_p = D * D * 4 + D * 4 * D // 4
        spectral_p = D * 2 * Ein + Ein * (3 * self.ssm_state + 2) + Ein * D
        ffn_dense = 3 * D * F if self.act == "swiglu" else 2 * D * F
        per_expert = 3 * D * F if self.act == "swiglu" else 2 * D * F
        for i in range(self.n_layers):
            mixer, ffn = self.superblock[i % len(self.superblock)]
            if mixer == "attn":
                n_attn += 1
            elif mixer == "mamba":
                n_mixer_other += mamba_p
            elif mixer == "mlstm":
                n_mixer_other += mlstm_p
            elif mixer == "slstm":
                n_mixer_other += slstm_p
            elif mixer == "spectral":
                n_mixer_other += spectral_p
            if ffn == "dense":
                n_ffn_dense += 1
            elif ffn == "moe":
                n_ffn_moe += 1
        total = n_attn * attn_p + n_mixer_other
        total += n_ffn_dense * ffn_dense
        k_active = min(self.top_k, max(1, self.n_experts))
        experts_counted = k_active if active_only else self.n_experts
        total += n_ffn_moe * (per_expert * experts_counted + D * self.n_experts)
        total += V * D * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            total += self.encoder_layers * (attn_p + ffn_dense)
        return total
