"""Parameter-spec infrastructure and common layers (no flax — pure JAX).

Every layer declares its parameters as ``ParamSpec`` trees carrying shape,
*logical* sharding axes, and initializer.  From one spec tree we derive:
concrete initialization (training), ``ShapeDtypeStruct`` stand-ins
(dry-run: no allocation), and ``NamedSharding`` trees (pjit in/out specs).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import ShardingRules, resolve_spec


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float | None = None    # None -> fan-in 1/sqrt(fan_in)
    dtype: Any = None             # None -> model param_dtype

    def initializer(self, key, param_dtype):
        dtype = self.dtype or param_dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init in ("normal", "embed"):
            fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
            scale = self.scale if self.scale is not None \
                else 1.0 / math.sqrt(max(1, fan_in))
            return (jax.random.normal(key, self.shape) * scale).astype(dtype)
        raise ValueError(self.init)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key, param_dtype=jnp.bfloat16):
    """Concrete parameter tree from a spec tree (training path)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.initializer(k, param_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs, param_dtype=jnp.bfloat16, mesh: Mesh | None = None,
                    rules: ShardingRules | None = None):
    """ShapeDtypeStruct tree (optionally with shardings) — dry-run path."""
    def mk(s: ParamSpec):
        dtype = s.dtype or param_dtype
        if mesh is not None:
            sh = NamedSharding(mesh, resolve_spec(s.shape, s.logical, mesh,
                                                  rules))
            return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sh)
        return jax.ShapeDtypeStruct(s.shape, dtype)
    return jax.tree.map(mk, specs, is_leaf=is_spec)


def param_shardings(specs, mesh: Mesh, rules: ShardingRules | None = None):
    def mk(s: ParamSpec):
        return NamedSharding(mesh, resolve_spec(s.shape, s.logical, mesh,
                                                rules))
    return jax.tree.map(mk, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(math.prod(s.shape)
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def stack_specs(specs, n: int, axis_name: str | None = None):
    """Stack a spec tree for scan-over-layers: prepend a layer dimension."""
    def mk(s: ParamSpec):
        return ParamSpec((n,) + s.shape, (axis_name,) + s.logical,
                         s.init, s.scale, s.dtype)
    return jax.tree.map(mk, specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Numerics / layers
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(dtype)


def dense(x, w, b=None, compute_dtype=jnp.bfloat16):
    """x @ w (+ b), computing in ``compute_dtype`` with f32 accumulation."""
    out = jax.lax.dot_general(
        x.astype(compute_dtype), w.astype(compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(compute_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dh: int, theta: float = 10000.0):
    return 1.0 / (theta ** (np.arange(0, dh, 2) / dh))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, Dh); positions: (..., S) int32 absolute positions."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def sinusoidal_positions(max_len: int, d_model: int):
    pos = np.arange(max_len)[:, None]
    i = np.arange(d_model)[None, :]
    angle = pos / np.power(10000, (2 * (i // 2)) / d_model)
    table = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(table, jnp.float32)


def gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


def silu(x):
    return jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)


def softmax_cross_entropy(logits, labels, z_loss: float = 0.0):
    """logits (..., V) f32, labels (...,) int32.  Returns mean loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return loss
