"""Transformer stack: superblock scan, unified Model API.

The repeating (mixer, ffn) *superblock* (``cfg.superblock``) is scanned
over ``cfg.n_superblocks`` with stacked parameters — HLO stays O(1) in
depth, remat wraps each superblock.  Heterogeneous stacks (jamba's
mamba/attn interleave with MoE-every-2, xLSTM's 7:1 mLSTM/sLSTM) are one
superblock of several positions; homogeneous stacks are a superblock of
length 1.

Modes:
  * ``forward``     — full-sequence (train / prefill), returns logits.
  * ``decode_step`` — one token with per-layer caches (KV / SSM states).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import spectral as spectral_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (ParamSpec, init_params, rms_norm,
                                 layer_norm, softmax_cross_entropy,
                                 stack_specs)
from repro.parallel.sharding import ShardingRules, constrain
from .config import ModelConfig

ACT_SPEC = ("batch", None, "act_embed")


def remat_policy_of(cfg: ModelConfig):
    """Map cfg.remat_policy to a jax checkpoint policy."""
    if cfg.remat_policy == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if cfg.remat_policy == "collectives":
        # save every checkpoint_name'd value; collectives are wrapped with
        # checkpoint_name at their call sites (sharding boundaries).
        return jax.checkpoint_policies.save_only_these_names(
            "act_gather", "moe_recv", "moe_back")
    raise ValueError(cfg.remat_policy)


def _norm_specs(cfg):
    if cfg.norm == "layernorm":
        return {"g": ParamSpec((cfg.d_model,), (None,), init="ones"),
                "b": ParamSpec((cfg.d_model,), (None,), init="zeros")}
    return {"g": ParamSpec((cfg.d_model,), (None,), init="ones")}


def _apply_norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["g"], p["b"])
    return rms_norm(x, p["g"])


def _mixer_specs(cfg, kind):
    return {"attn": attn.attn_specs, "mamba": mamba_mod.mamba_specs,
            "mlstm": xlstm_mod.mlstm_specs,
            "slstm": xlstm_mod.slstm_specs,
            "spectral": spectral_mod.spectral_specs}[kind](cfg)


def _ffn_specs(cfg, kind):
    if kind == "dense":
        return ffn_mod.ffn_specs(cfg)
    if kind == "moe":
        return moe_mod.moe_specs(cfg)
    return {}


def position_specs(cfg, mixer, ffn):
    out = {"norm1": _norm_specs(cfg), "mixer": _mixer_specs(cfg, mixer)}
    if ffn != "none":
        out["norm2"] = _norm_specs(cfg)
        out["ffn"] = _ffn_specs(cfg, ffn)
    return out


def superblock_specs(cfg: ModelConfig):
    return {f"pos{i}": position_specs(cfg, mixer, ffn)
            for i, (mixer, ffn) in enumerate(cfg.superblock)}


# ---------------------------------------------------------------------------
# Cache/state initialization (decode)
# ---------------------------------------------------------------------------

def _position_state(cfg: ModelConfig, mixer, batch, max_seq):
    if mixer == "attn":
        # Sliding-window attention needs only `window` KV slots (ring
        # buffer) — this is what makes long_500k decode O(window) for SWA.
        slots = min(max_seq, cfg.window) if cfg.window else max_seq
        cs = attn.CacheSpec(batch, cfg.n_kv_heads, slots, cfg.hd,
                            cfg.cdtype)
        return attn.init_cache(cs)
    D = cfg.d_model
    if mixer == "mamba":
        Ein = cfg.ssm_expand * D
        return {"ssm": jnp.zeros((batch, Ein, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1, Ein),
                                  cfg.cdtype)}
    if mixer == "spectral":
        Ein = cfg.ssm_expand * D
        return {"ssm": jnp.zeros((batch, Ein, cfg.ssm_state), jnp.float32)}
    if mixer == "mlstm":
        Din = 2 * D
        H = cfg.n_heads
        hd = Din // H
        return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
                "n": jnp.zeros((batch, H, hd), jnp.float32),
                "m": jnp.full((batch, H), -1e30, jnp.float32)}
    if mixer == "slstm":
        z = jnp.zeros((batch, D), jnp.float32)
        return {"c": z, "n": z + 1e-6, "m": jnp.full((batch, D), -1e30,
                                                     jnp.float32), "h": z}
    raise ValueError(mixer)


def init_layer_states(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked (n_superblocks, ...) state tree for decode."""
    per_sb = {f"pos{i}": _position_state(cfg, mixer, batch, max_seq)
              for i, (mixer, _) in enumerate(cfg.superblock)}
    n = cfg.n_superblocks
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), per_sb)


def _position_state_logical(cfg: ModelConfig, mixer):
    """Logical sharding axes mirroring ``_position_state`` (for dry-run
    abstract caches: sharded ShapeDtypeStructs, no allocation)."""
    if mixer == "attn":
        kv = ("batch", "kv_heads", "seq_sp", None)
        return {"k": kv, "v": kv, "slot_pos": ("batch", "seq_sp")}
    if mixer == "mamba":
        return {"ssm": ("batch", "mlp", None),
                "conv": ("batch", None, "mlp")}
    if mixer == "spectral":
        return {"ssm": ("batch", "mlp", None)}
    if mixer == "mlstm":
        return {"C": ("batch", "heads", None, None),
                "n": ("batch", "heads", None), "m": ("batch", "heads")}
    if mixer == "slstm":
        v = ("batch", None)
        return {"c": v, "n": v, "m": v, "h": v}
    raise ValueError(mixer)


def cache_logical_axes(cfg: ModelConfig):
    """Logical axes tree matching ``Model.init_caches`` output (layer
    states get a leading stacked superblock dim)."""
    per_sb = {f"pos{i}": _position_state_logical(cfg, mixer)
              for i, (mixer, _) in enumerate(cfg.superblock)}
    states = jax.tree.map(lambda ax: (None,) + tuple(ax), per_sb,
                          is_leaf=lambda x: isinstance(x, tuple))
    return {"states": states, "pos": ("batch",)}


# ---------------------------------------------------------------------------
# Superblock application
# ---------------------------------------------------------------------------

def _apply_position(pp, x, cfg, mixer, ffn, mesh, rules, positions,
                    state=None, decode=False):
    """One (mixer, ffn) position.  Returns (x, aux, new_state)."""
    h = _apply_norm(pp["norm1"], x, cfg)
    new_state = state
    if mixer == "attn":
        if decode:
            y, new_state = attn.decode_attention(pp["mixer"], h, state,
                                                 positions, cfg)
        else:
            y = attn.attention_block(
                pp["mixer"], h, cfg, causal=True, positions=positions,
                mesh=mesh, rules=rules)
    elif mixer == "mamba":
        y, new_state = mamba_mod.mamba_block(pp["mixer"], h, cfg,
                                             state=state)
    elif mixer == "spectral":
        y, new_state = spectral_mod.spectral_block(pp["mixer"], h, cfg,
                                                   state=state)
    elif mixer == "mlstm":
        y, new_state = xlstm_mod.mlstm_block(pp["mixer"], h, cfg,
                                             state=state)
    elif mixer == "slstm":
        y, new_state = xlstm_mod.slstm_block(pp["mixer"], h, cfg,
                                             state=state)
    else:
        raise ValueError(mixer)
    x = x + y.astype(x.dtype)

    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = _apply_norm(pp["norm2"], x, cfg)
        if ffn == "moe":
            y, aux = moe_mod.moe_block(pp["ffn"], h, cfg, mesh=mesh,
                                       rules=rules)
        else:
            y = ffn_mod.ffn_block(pp["ffn"], h, cfg)
        x = x + y.astype(x.dtype)
    x = constrain(x, ACT_SPEC, mesh, rules)
    return x, aux, new_state


def _apply_superblock(params_sb, x, cfg, mesh, rules, positions,
                      states_sb=None, decode=False):
    aux_total = jnp.zeros((), jnp.float32)
    new_states = {}
    for i, (mixer, ffn) in enumerate(cfg.superblock):
        st = states_sb[f"pos{i}"] if states_sb is not None else None
        x, aux, st2 = _apply_position(
            params_sb[f"pos{i}"], x, cfg, mixer, ffn, mesh, rules,
            positions, state=st, decode=decode)
        aux_total = aux_total + aux
        if st2 is not None:
            new_states[f"pos{i}"] = st2
    return x, aux_total, new_states


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass
class Model:
    cfg: ModelConfig

    # ---- parameter specs ----
    def specs(self):
        cfg = self.cfg
        out = {
            "embed": ParamSpec((cfg.vocab, cfg.d_model),
                               ("vocab", "embed_fsdp"), init="embed",
                               scale=1.0),
            "blocks": stack_specs(superblock_specs(cfg), cfg.n_superblocks,
                                  None),
            "final_norm": _norm_specs(cfg),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = ParamSpec((cfg.vocab, cfg.d_model),
                                       ("vocab", "embed_fsdp"))
        if cfg.frontend is not None:
            out["frontend_proj"] = ParamSpec(
                (cfg.d_model, cfg.d_model), ("embed_fsdp", None))
        return out

    def init(self, key):
        return init_params(self.specs(), key, self.cfg.pdtype)

    # ---- embedding / head ----
    def embed(self, params, tokens):
        e = jnp.take(params["embed"], tokens, axis=0)
        return e.astype(self.cfg.cdtype)

    def logits(self, params, x):
        w = params.get("lm_head", params["embed"])
        out = jnp.einsum("bsd,vd->bsv", x.astype(self.cfg.cdtype),
                         w.astype(self.cfg.cdtype),
                         preferred_element_type=jnp.float32)
        return out  # f32

    # ---- full-sequence forward (train / prefill) ----
    def forward(self, params, tokens, *, mesh=None, rules=None,
                frontend_embeds=None):
        cfg = self.cfg
        x = self.embed(params, tokens)
        if frontend_embeds is not None:
            fe = frontend_embeds.astype(cfg.cdtype)
            fe = fe @ params["frontend_proj"].astype(cfg.cdtype)
            x = jnp.concatenate([fe, x], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = constrain(x, ACT_SPEC, mesh, rules)

        def body(carry, params_sb):
            x, aux = carry
            x, aux_sb, _ = _apply_superblock(params_sb, x, cfg, mesh, rules,
                                             positions)
            return (x, aux + aux_sb), None

        if cfg.remat:
            body = jax.checkpoint(body, policy=remat_policy_of(cfg))
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        x = _apply_norm(params["final_norm"], x, cfg)
        if frontend_embeds is not None:
            x = x[:, frontend_embeds.shape[1]:]
        return self.logits(params, x), aux

    # ---- loss ----
    def loss(self, params, batch, *, mesh=None, rules=None):
        cfg = self.cfg
        logits, aux = self.forward(
            params, batch["tokens"], mesh=mesh, rules=rules,
            frontend_embeds=batch.get("frontend_embeds"))
        ce = softmax_cross_entropy(logits, batch["labels"], cfg.z_loss)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(batch["labels"], jnp.float32)
        loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = loss + cfg.router_aux_weight * aux   # aux == 0 if no MoE
        metrics = {"ce_loss": loss, "aux_loss": aux, "total_loss": total}
        return total, metrics

    # ---- decode ----
    def init_caches(self, batch: int, max_seq: int):
        return {"states": init_layer_states(self.cfg, batch, max_seq),
                "pos": jnp.zeros((batch,), jnp.int32)}

    def prefill(self, params, tokens, caches, *, mesh=None, rules=None,
                frontend_embeds=None):
        """Sequential prefill through decode_step (correct though not the
        fast path; full-seq prefill uses ``forward``)."""
        def step(carry, t):
            caches, _ = carry
            logits, caches = self.decode_step(params, tokens[:, t:t + 1],
                                              caches, mesh=mesh, rules=rules)
            return (caches, logits), None
        (caches, logits), _ = jax.lax.scan(
            step, (caches, jnp.zeros((tokens.shape[0], 1, self.cfg.vocab),
                                     jnp.float32)),
            jnp.arange(tokens.shape[1]))
        return logits, caches

    def decode_step(self, params, tokens_t, caches, *, mesh=None,
                    rules=None):
        """tokens_t: (B, 1). Returns (logits (B,1,V), caches)."""
        cfg = self.cfg
        x = self.embed(params, tokens_t)
        x = constrain(x, ("batch", None, None), mesh, rules)
        pos = caches["pos"]

        def body(carry, xs):
            x = carry
            params_sb, states_sb = xs
            x, _, new_states = _apply_superblock(
                params_sb, x, cfg, mesh, rules, pos, states_sb=states_sb,
                decode=True)
            return x, new_states

        x, new_states = jax.lax.scan(
            body, x, (params["blocks"], caches["states"]))
        x = _apply_norm(params["final_norm"], x, cfg)
        logits = self.logits(params, x)
        return logits, {"states": new_states, "pos": pos + 1}
