"""Mamba (selective SSM) block — jamba's mixer layer.

Training/prefill: ``lax.scan`` over time with state ``(B, Ein, n)`` (the
selective recurrence is inherently sequential; the scan keeps HLO O(1) in
sequence length and the state O(Ein*n), never materializing (S, Ein, n)).
Decode: single-step state update (O(1) per token — this is why jamba runs
the ``long_500k`` shape).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, silu
from .config import ModelConfig


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    Ein = cfg.ssm_expand * D
    n = cfg.ssm_state
    r = _dt_rank(cfg)
    return {
        "in_proj": ParamSpec((D, 2 * Ein), ("embed_fsdp", "mlp")),
        "conv_w": ParamSpec((cfg.ssm_conv, Ein), (None, "mlp")),
        "conv_b": ParamSpec((Ein,), ("mlp",), init="zeros"),
        "x_proj": ParamSpec((Ein, r + 2 * n), ("mlp", None)),
        "dt_proj": ParamSpec((r, Ein), (None, "mlp")),
        "dt_bias": ParamSpec((Ein,), ("mlp",), init="zeros"),
        "A_log": ParamSpec((Ein, n), ("mlp", None), init="ones"),
        "D_skip": ParamSpec((Ein,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((Ein, D), ("mlp", "embed_fsdp")),
    }


def _ssm_params(p, xc, cfg):
    """Input-dependent (dt, B, C) from the conv branch xc: (B, S, Ein)."""
    n, r = cfg.ssm_state, _dt_rank(cfg)
    proj = xc.astype(jnp.float32) @ p["x_proj"].astype(jnp.float32)
    dt_in, Bm, Cm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,Ein)
    return dt, Bm, Cm


def _conv_step(p, x_window):
    """Causal depthwise conv over a (B, K, Ein) window -> (B, Ein)."""
    w = p["conv_w"].astype(jnp.float32)                   # (K, Ein)
    return jnp.einsum("bke,ke->be", x_window.astype(jnp.float32), w) \
        + p["conv_b"].astype(jnp.float32)


def mamba_block(p, x, cfg: ModelConfig, state=None):
    """x: (B, S, D).  state: None (train/prefill from scratch) or dict with
    'ssm' (B, Ein, n) and 'conv' (B, K-1, Ein) for incremental decode.
    Returns (y, new_state)."""
    B, S, D = x.shape
    Ein = cfg.ssm_expand * D
    K = cfg.ssm_conv
    n = cfg.ssm_state
    cd = cfg.cdtype

    xz = x.astype(cd) @ p["in_proj"].astype(cd)            # (B, S, 2Ein)
    xs, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        conv_tail = jnp.zeros((B, K - 1, Ein), cd)
        ssm0 = jnp.zeros((B, Ein, n), jnp.float32)
    else:
        conv_tail = state["conv"]
        ssm0 = state["ssm"]

    # causal depthwise conv via explicit window (supports S==1 decode)
    xs_pad = jnp.concatenate([conv_tail.astype(cd), xs], axis=1)
    windows = jnp.stack([xs_pad[:, t:t + K] for t in range(S)], axis=1) \
        if S <= 4 else None
    if windows is not None:
        xc = jax.vmap(lambda w: _conv_step(p, w), in_axes=1, out_axes=1)(
            windows)
    else:
        w = p["conv_w"].astype(jnp.float32)
        xc = sum(xs_pad[:, K - 1 - i: K - 1 - i + S].astype(jnp.float32)
                 * w[K - 1 - i] for i in range(K))
        xc = xc + p["conv_b"].astype(jnp.float32)
    xc = silu(xc)                                          # (B, S, Ein)

    dt, Bm, Cm = _ssm_params(p, xc, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (Ein, n)

    def step(h, inputs):
        xc_t, dt_t, B_t, C_t = inputs                      # (B,Ein),(B,Ein),(B,n),(B,n)
        da = jnp.exp(dt_t[..., None] * A[None])            # (B, Ein, n)
        db = dt_t[..., None] * B_t[:, None, :]             # (B, Ein, n)
        h = da * h + db * xc_t[..., None].astype(jnp.float32)
        y = jnp.einsum("ben,bn->be", h, C_t)
        return h, y

    if cfg.recurrent_step_remat:
        step = jax.checkpoint(step)
    xs_t = jnp.moveaxis(xc.astype(jnp.float32), 1, 0)      # (S, B, Ein)
    dt_t = jnp.moveaxis(dt, 1, 0)
    B_t = jnp.moveaxis(Bm, 1, 0)
    C_t = jnp.moveaxis(Cm, 1, 0)
    h_final, ys = jax.lax.scan(step, ssm0, (xs_t, dt_t, B_t, C_t))
    y = jnp.moveaxis(ys, 0, 1)                             # (B, S, Ein)
    y = y + xc.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)
    y = (y.astype(cd) * silu(z))
    out = y @ p["out_proj"].astype(cd)

    new_state = {"ssm": h_final,
                 "conv": xs_pad[:, -(K - 1):].astype(cd)}
    return out, new_state
