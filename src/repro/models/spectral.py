"""Spectral long-convolution mixer — an LTI diagonal SSM whose full
sequence pass is an FFT causal convolution.

The state-space kernel is time-invariant (unlike mamba's selective
scan), so the length-S output is a causal convolution with the
materialized kernel ``K[t, e] = sum_n C[e,n] * Abar[e,n]^t * Bbar[e,n]``
— computed in O(S log S) via FFT instead of an O(S) sequential scan.
Decode keeps the recurrent form: one O(Ein*n) state update per token,
bit-for-bit the same linear system (the SSM-parity test in
``tests/test_models.py`` checks conv ≡ recurrence).

Sequence-parallel training rides the pencil FFT
(:func:`distributed_fft_causal_conv`): the rfft/irfft pair along a
*sharded* sequence axis runs through ``workloads.fft.PencilFFT``, so
every global re-shard is a cached
:class:`~repro.core.plan.TransposePlan`.

Opt-in via ``ModelConfig(spectral_long_conv=True)`` (substitutes the
recurrent mixers in ``block_pattern``) or ``block_pattern=("spectral",)``
directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, silu
from .config import ModelConfig


def spectral_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    Ein = cfg.ssm_expand * D
    n = cfg.ssm_state
    return {
        "in_proj": ParamSpec((D, 2 * Ein), ("embed_fsdp", "mlp")),
        "A_log": ParamSpec((Ein, n), ("mlp", None), init="ones"),
        "B": ParamSpec((Ein, n), ("mlp", None)),
        "C": ParamSpec((Ein, n), ("mlp", None)),
        "dt_log": ParamSpec((Ein,), ("mlp",), init="zeros"),
        "D_skip": ParamSpec((Ein,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((Ein, D), ("mlp", "embed_fsdp")),
    }


def _discretize(p):
    """(Abar, Bbar, C) of the ZOH-Euler discretized diagonal system."""
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (Ein, n) < 0
    dt = jax.nn.softplus(p["dt_log"].astype(jnp.float32))[:, None]
    dA = jnp.exp(dt * A)                                   # (Ein, n)
    dB = dt * p["B"].astype(jnp.float32)                   # (Ein, n)
    return dA, dB, p["C"].astype(jnp.float32), dt * A


def ssm_kernel(p, L: int):
    """Materialize the causal conv kernel ``K``: (L, Ein), with
    ``K[t] = C . Abar^t . Bbar`` (so ``K[0] = C . Bbar``)."""
    _, dB, C, dtA = _discretize(p)
    t = jnp.arange(L, dtype=jnp.float32)
    powers = jnp.exp(t[:, None, None] * dtA[None])         # (L, Ein, n)
    return jnp.einsum("len,en->le", powers, C * dB)


def fft_causal_conv(x, kernel):
    """Causal (linear, not circular) convolution of ``x``: (B, S, E)
    with per-channel ``kernel``: (S, E) via zero-padded FFT; float32."""
    S = x.shape[1]
    L = 2 * S
    X = jnp.fft.rfft(x.astype(jnp.float32), n=L, axis=1)
    Kf = jnp.fft.rfft(kernel.astype(jnp.float32), n=L, axis=0)
    return jnp.fft.irfft(X * Kf[None], n=L, axis=1)[:, :S]


def distributed_fft_causal_conv(comm, x, kernel, *, mesh=None):
    """Sequence-sharded causal convolution through the pencil FFT.

    ``x``: global (B, S, E) with the sequence axis sharded over
    ``comm``'s torus (any input sharding — the jit re-shards);
    ``kernel``: (S, E), replicated.  The forward and inverse transforms
    along the padded sequence axis run through
    :class:`~repro.workloads.fft.PencilFFT` (slab decomposition over
    *all* torus axes), so each of the four global re-shards is a cached
    :class:`~repro.core.plan.TransposePlan` collective and the whole
    conv is one jit — zero host round-trips.  Returns (B, S, E) float32
    sharded like the FFT input spec."""
    from repro.workloads.fft import PencilFFT
    from jax.sharding import PartitionSpec as P

    B, S, E = x.shape
    L = 2 * S
    p = comm.p
    if L % p or (B * E) % p:
        raise ValueError(f"padded seq {L} and B*E {B * E} must divide "
                         f"p={p}")
    fft = PencilFFT(comm, (L, B * E), axes=(0,),
                    grid=(tuple(comm.axis_names),), dtype="complex64")
    mesh = comm.mesh if mesh is None else mesh
    dim_of = dict(zip(comm.axis_names, comm.dims))
    gspec = tuple(reversed(comm.axis_names))               # major -> minor
    cols = B * E // p

    def shard_local(xl, kp):
        # xl: (L/p, B*E) time-major slab; kp: (L, E) replicated
        X = fft.forward_local(xl)                          # (L, B*E/p)
        idx = jnp.zeros((), jnp.int32)
        for name in gspec:
            idx = idx * dim_of[name] + jax.lax.axis_index(name)
        off = idx * cols
        e_idx = (off + jnp.arange(cols)) % E               # channel of col
        Kf = jnp.fft.fft(kp, axis=0)                       # (L, E) local
        return fft.inverse_local(X * Kf[:, e_idx])         # (L/p, B*E)

    def run(xg, kg):
        xp = jnp.pad(xg.astype(jnp.complex64), ((0, 0), (0, S), (0, 0)))
        xf = jnp.moveaxis(xp, 1, 0).reshape(L, B * E)
        kp = jnp.pad(kg.astype(jnp.complex64), ((0, S), (0, 0)))
        yf = jax.shard_map(shard_local, mesh=mesh,
                           in_specs=(fft.in_spec, P(None, None)),
                           out_specs=fft.in_spec)(xf, kp)
        y = jnp.moveaxis(yf.reshape(L, B, E), 0, 1)[:, :S]
        return jnp.real(y)

    return jax.jit(run)(x, kernel)


def spectral_block(p, x, cfg: ModelConfig, state=None):
    """x: (B, S, D).  ``state=None`` (train / prefill from scratch) runs
    the FFT convolution path and returns the final recurrent state for
    decode handoff; with a state dict (``{'ssm': (B, Ein, n)}``) it runs
    the step recurrence — same linear system either way.  Returns
    (y, new_state)."""
    B, S, D = x.shape
    cd = cfg.cdtype
    xz = x.astype(cd) @ p["in_proj"].astype(cd)            # (B, S, 2Ein)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs_f = xs.astype(jnp.float32)
    dA, dB, C, dtA = _discretize(p)

    if state is None:
        K = ssm_kernel(p, S)
        y = fft_causal_conv(xs_f, K)                       # (B, S, Ein)
        # decode handoff: h[S-1] = sum_s Abar^{S-1-s} Bbar x[s]
        rev = jnp.arange(S - 1, -1, -1, dtype=jnp.float32)
        powers = jnp.exp(rev[:, None, None] * dtA[None])   # (S, Ein, n)
        h_final = jnp.einsum("sen,bse->ben", powers * dB[None], xs_f)
    else:
        def step(h, x_t):                                  # x_t: (B, Ein)
            h = dA[None] * h + dB[None] * x_t[..., None]
            return h, jnp.einsum("ben,en->be", h, C)
        h_final, ys = jax.lax.scan(step, state["ssm"],
                                   jnp.moveaxis(xs_f, 1, 0))
        y = jnp.moveaxis(ys, 0, 1)                         # (B, S, Ein)

    y = y + xs_f * p["D_skip"].astype(jnp.float32)
    y = y.astype(cd) * silu(z)
    out = y @ p["out_proj"].astype(cd)
    return out, {"ssm": h_final}
