"""Deterministic synthetic LM data pipeline.

Production shape without external data: batches are a pure function of
``(seed, step)`` so any host can regenerate any shard — restart/elastic
resume needs only the step cursor (stored in checkpoints), and two hosts
never disagree about batch contents.  Two generators:

* ``make_lm_batch`` — Zipf-ish random token stream (throughput/memory
  benchmarking; loss floor is ~ln(vocab) entropy).
* ``make_copy_task_batch`` — prefix + SEP + copy-of-prefix sequences: a
  *learnable* task so end-to-end examples show genuinely decreasing loss
  (induction behaviour), not just optimizer motion.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234


@dataclass(frozen=True)
class CopyTaskConfig(DataConfig):
    prefix_len: int = 0   # default seq_len // 2 - 1

    @property
    def plen(self):
        return self.prefix_len or (self.seq_len // 2)


def _fold(cfg: DataConfig, step: int):
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)


def make_lm_batch(cfg: DataConfig, step: int):
    """Zipf-distributed tokens; labels = next token."""
    key = _fold(cfg, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    u = jax.random.uniform(key, (B, S + 1), minval=1e-6, maxval=1.0)
    # inverse-CDF power law (Zipf-ish) truncated to the vocab
    ranks = jnp.floor((1.0 / u) ** 0.9)
    toks = (ranks.astype(jnp.int32) - 1) % V
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
            "mask": jnp.ones((B, S), jnp.float32)}


def make_copy_task_batch(cfg: CopyTaskConfig, step: int):
    key = _fold(cfg, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    plen = cfg.plen
    assert 2 * plen + 1 <= S + 1, "prefix too long for seq_len"
    sep = V - 1
    prefix = jax.random.randint(key, (B, plen), 0, V - 1)
    seq = jnp.concatenate(
        [prefix, jnp.full((B, 1), sep, jnp.int32), prefix,
         jnp.zeros((B, S + 1 - 2 * plen - 1), jnp.int32)], axis=1)
    tokens, labels = seq[:, :-1], seq[:, 1:]
    # only the copy region is scored
    pos = jnp.arange(S)[None]
    mask = ((pos >= plen) & (pos < 2 * plen)).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (B, S))
    return {"tokens": tokens, "labels": labels, "mask": mask}


class SyntheticLM:
    """Stateful iterator facade with a resumable cursor and device
    placement (batch sharded over the DP axes)."""

    def __init__(self, cfg: DataConfig, mesh: Mesh | None = None,
                 task: str = "lm", start_step: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.task = task
        self.step = start_step

    def _place(self, batch):
        if self.mesh is None:
            return batch
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
        part = axes if len(axes) > 1 else (axes[0] if axes else None)
        sh = NamedSharding(self.mesh, P(part))   # shard batch dim only
        return {k: jax.device_put(v, sh) for k, v in batch.items()}

    def next(self):
        fn = make_copy_task_batch if self.task == "copy" else make_lm_batch
        batch = fn(self.cfg, self.step)
        self.step += 1
        return self._place(batch)

    # ---- checkpointable cursor ----
    def state_dict(self):
        return {"step": self.step, "seed": self.cfg.seed,
                "task": self.task}

    def load_state_dict(self, d):
        assert d["seed"] == self.cfg.seed and d["task"] == self.task, \
            "resuming with a different data stream"
        self.step = int(d["step"])
