"""Data pipeline: deterministic synthetic LM streams with resume cursors."""

from .pipeline import (CopyTaskConfig, DataConfig, SyntheticLM,
                       make_copy_task_batch, make_lm_batch)

__all__ = ["CopyTaskConfig", "DataConfig", "SyntheticLM",
           "make_copy_task_batch", "make_lm_batch"]
