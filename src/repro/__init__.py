"""repro: factorized zero-copy all-to-all for multidimensional tori
(Träff, CS.DC 2026) — JAX/TPU training & serving framework."""

from . import compat  # noqa: F401  (installs JAX version shims)

__version__ = "1.0.0"
