"""Checkpoint store: msgpack manifest + zstd-compressed leaf files.

Design points for 1000+-node operation (scaled down to one process here):

* **Atomicity** — writes go to ``step_XXXX.tmp`` and are renamed only
  after the manifest (with per-leaf sha256) is fsynced; a crashed save can
  never be mistaken for a valid checkpoint.
* **Resharding on restore** — leaves are stored as *global* logical arrays
  (assembled from shards at save time); restore takes a target sharding
  tree (any mesh) and lays out device buffers accordingly, so a checkpoint
  from a 256-chip run restores onto 512 chips (elastic scaling).
* **Async saves** — a background thread serializes a host snapshot while
  training continues; ``wait()`` joins before the next save or exit.
* **Retention** — keep the newest ``keep`` checkpoints; integrity checked
  on restore.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import warnings
from pathlib import Path

import jax
import msgpack
import numpy as np

import zlib as _zlib

from repro.core import telemetry

try:
    import zstandard as zstd
    _HAVE_ZSTD = True
except ImportError:          # gate the optional dep: stdlib zlib fallback
    _HAVE_ZSTD = False

    class _Compressor:
        def __init__(self, level=3):
            self._level = level

        def compress(self, data):
            return _zlib.compress(data, self._level)

    class _Decompressor:
        @staticmethod
        def decompress(data):
            return _zlib.decompress(data)

    class zstd:  # noqa: N801 - mimics the zstandard module surface
        ZstdCompressor = _Compressor
        ZstdDecompressor = _Decompressor


# Saves record their codec in the manifest so a checkpoint written where
# zstandard is absent restores anywhere (and vice versa); legacy manifests
# without the field are sniffed by the zstd frame magic.
_CODEC = "zstd" if _HAVE_ZSTD else "zlib"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _decompress(data: bytes, codec: str | None) -> bytes:
    if codec is None:
        codec = "zstd" if data[:4] == _ZSTD_MAGIC else "zlib"
    if codec == "zstd":
        if not _HAVE_ZSTD:
            raise ModuleNotFoundError(
                "checkpoint leaves are zstd-compressed; install zstandard "
                "to restore them here")
        return zstd.ZstdDecompressor().decompress(data)
    return _zlib.decompress(data)


def _tree_to_entries(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    entries = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        entries.append((key, leaf))
    return entries, treedef


def _path_str(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory, step: int, tree, extra: dict | None = None,
                    keep: int = 3):
    """Synchronous atomic save of a pytree of arrays."""
    with telemetry.get_tracer().span("checkpoint.save", cat="checkpoint",
                                     step=int(step)) as sp:
        out = _save_checkpoint_impl(directory, step, tree, extra, keep)
        sp.set(path=str(out))
        telemetry.metrics().counter("checkpoint.saves").inc()
        return out


def _save_checkpoint_impl(directory, step: int, tree,
                          extra: dict | None = None, keep: int = 3):
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    entries, _ = _tree_to_entries(tree)
    cctx = zstd.ZstdCompressor(level=3)
    manifest = {"step": step, "extra": extra or {}, "codec": _CODEC,
                "leaves": {}}
    for i, (key, leaf) in enumerate(entries):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        digest = hashlib.sha256(raw).hexdigest()
        fname = f"leaf_{i:05d}.zst"
        with open(tmp / fname, "wb") as f:
            f.write(cctx.compress(raw))
        manifest["leaves"][key] = {
            "file": fname, "dtype": str(arr.dtype),
            "shape": list(arr.shape), "sha256": digest,
        }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: Path, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(Path(directory) / f"step_{s:08d}", ignore_errors=True)


def all_steps(directory) -> list[int]:
    directory = Path(directory)
    out = []
    if not directory.exists():
        return out
    for p in directory.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


# Failures that mean "this checkpoint is unusable", as opposed to a
# caller error: unreadable/corrupt files (OSError incl. the sha256
# IOError), missing leaves, and decode errors from a flipped byte
# (json/reshape ValueError, zlib.error; ZstdError when zstd is present).
_INTEGRITY_ERRORS = (OSError, KeyError, ValueError, _zlib.error)
if _HAVE_ZSTD:
    _INTEGRITY_ERRORS = _INTEGRITY_ERRORS + (zstd.ZstdError,)


def restore_checkpoint(directory, step: int | None, target_tree,
                       shardings=None, verify: bool = True,
                       fallback: bool = True):
    """Restore into the structure of ``target_tree`` (arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    ``NamedSharding`` for device placement (elastic re-mesh).

    With ``step=None`` (restore-latest) and ``fallback=True``, a
    checkpoint that fails integrity checks (sha256 mismatch, truncated
    or undecodable leaf, missing manifest entry) is *skipped with a
    warning* and the next-newest retained checkpoint is tried — one
    corrupt save must not strand a run that has older good state.  An
    explicitly requested ``step`` still raises on corruption."""
    directory = Path(directory)
    if step is not None:
        return _restore_step(directory, step, target_tree, shardings,
                             verify)
    steps = all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    last_err = None
    for s in reversed(steps):
        try:
            return _restore_step(directory, s, target_tree, shardings,
                                 verify)
        except _INTEGRITY_ERRORS as e:
            if not fallback:
                raise
            last_err = e
            warnings.warn(
                f"skipping checkpoint step {s}: "
                f"{type(e).__name__}: {e}; falling back to next-newest",
                RuntimeWarning, stacklevel=2)
    raise IOError(f"all {len(steps)} retained checkpoints in "
                  f"{directory} are unusable") from last_err


def _restore_step(directory: Path, step: int, target_tree,
                  shardings=None, verify: bool = True):
    with telemetry.get_tracer().span("checkpoint.restore", cat="checkpoint",
                                     step=int(step), verify=verify):
        out = _restore_step_impl(directory, step, target_tree, shardings,
                                 verify)
        telemetry.metrics().counter("checkpoint.restores").inc()
        return out


def _restore_step_impl(directory: Path, step: int, target_tree,
                       shardings=None, verify: bool = True):
    base = directory / f"step_{step:08d}"
    with open(base / "manifest.json") as f:
        manifest = json.load(f)

    entries, treedef = _tree_to_entries(target_tree)
    sh_list = None
    if shardings is not None:
        sh_list = [s for _, s in _tree_to_entries(shardings)[0]]
    codec = manifest.get("codec")
    leaves = []
    for i, (key, ref) in enumerate(entries):
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint at step {step} missing leaf {key}")
        with open(base / info["file"], "rb") as f:
            raw = _decompress(f.read(), codec)
        if verify:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != info["sha256"]:
                raise IOError(f"corrupt leaf {key} in step {step}")
        arr = np.frombuffer(raw, dtype=np.dtype(info["dtype"])) \
            .reshape(info["shape"]).copy()
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target {ref.shape}")
        if sh_list is not None:
            leaves.append(jax.device_put(arr, sh_list[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), manifest["extra"], step


class CheckpointManager:
    """Async checkpointing with retention and preemption-safe finalize."""

    def __init__(self, directory, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree, extra=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra,
                            self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree, extra=None):
        self.wait()
        return save_checkpoint(self.directory, step, tree, extra, self.keep)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest(self):
        return latest_step(self.directory)

    def restore(self, target_tree, shardings=None, step=None):
        return restore_checkpoint(self.directory, step, target_tree,
                                  shardings)
