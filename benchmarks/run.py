"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Multi-device benches run in
subprocesses (this process keeps 1 CPU device per repo policy).

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _subproc(module: str, devices: int) -> int:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}" + (
        os.pathsep + env["PYTHONPATH"] if "PYTHONPATH" in env else "")
    proc = subprocess.run([sys.executable, "-m", module], env=env,
                          cwd=ROOT, text=True, capture_output=True)
    sys.stdout.write(proc.stdout)
    if proc.returncode:
        sys.stderr.write(proc.stderr)
    return proc.returncode


def table1_factorizations():
    """Paper Table 1: dims_create factorizations."""
    from benchmarks import table1
    table1.main()


def figures_1_2_3_alltoall():
    """Paper Figures 1-3: factorized vs direct over message sizes
    (measured, 16 virtual devices, subprocess)."""
    rc = _subproc("benchmarks.alltoall_cmp", devices=16)
    if rc:
        print("alltoall_cmp,failed,,see stderr")


def guideline_check():
    """Paper viewpoint 3: self-consistent performance guidelines."""
    from benchmarks import guidelines
    guidelines.main()


def zero_copy():
    """Paper §4: the explicit-copy cost that zero-copy eliminates."""
    from benchmarks import zero_copy_cost
    zero_copy_cost.main()


def roofline_table():
    """§Roofline: derived terms from the dry-run artifacts."""
    from benchmarks import roofline
    roofline.main()


def model_steps():
    """Measured smoke-config step times per architecture."""
    from benchmarks import model_step
    model_step.main()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slower measured benches")
    args = ap.parse_args()

    print("# table1 (paper Table 1)")
    table1_factorizations()
    print("\n# alltoall message-size sweep (paper Figs 1-3)")
    if not args.quick:
        figures_1_2_3_alltoall()
    print("\n# guideline check (paper [5,12])")
    guideline_check()
    print("\n# zero-copy saving (paper §4)")
    zero_copy()
    print("\n# roofline (from dry-run artifacts)")
    roofline_table()
    print("\n# per-arch smoke step times")
    if not args.quick:
        model_steps()


if __name__ == "__main__":
    main()
