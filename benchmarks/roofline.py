"""Roofline derivation from dry-run artifacts (§Roofline of EXPERIMENTS).

Per (arch x shape x mesh) cell, from the compiled dry-run:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [197 TF bf16]
  memory term     = HLO_bytes_per_device / HBM_bw               [819 GB/s]
  collective term = collective_bytes_per_device / link_bw       [50 GB/s ICI]

plus MODEL_FLOPS = 6*N*D (train, active params for MoE) or 2*N*D
(prefill/decode), and the useful-compute ratio MODEL_FLOPS / global
HLO_FLOPs.  The dominant term is the bottleneck the perf loop iterates on.

The FFT section is artifact-free: a strong-scaling roofline for the
pencil-decomposition FFT (``workloads.fft``) on a fixed global problem,
priced purely from the per-axis ``LinkModel`` alpha-beta terms
(``tuning.predict_transpose``) — slab (one transpose over the whole
torus, factorized vs direct) against pencil (one per-axis transpose
stage), on all-ICI and ICI+DCN link assignments, vs the per-chip FFT
compute term.
"""

from __future__ import annotations

import json
import math
from pathlib import Path

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12         # bf16 per chip, TPU v5e
HBM_BW = 819e9              # B/s per chip
LINK_BW = 50e9              # B/s per ICI link
DCN_BW = 6.4e9              # B/s per chip cross-pod

ARTIFACTS = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def collective_term(rec: dict) -> tuple[float, float, float]:
    """(total_s, ici_s, dcn_s): per-axis attribution when available.

    On the multi-pod mesh (2,16,16) a collective whose replica-group id
    SPAN reaches 256 includes devices from both pods and is paced by DCN;
    everything else is ICI.  This is exactly where the paper's
    hierarchical schedule matters: the factorized EP dispatch confines
    the ICI round within pods and isolates DCN traffic in the pod round,
    while a direct product-axis collective drags everything through the
    mixed group."""
    by_span = rec.get("collective_bytes_by_span") \
        or rec.get("collective_bytes_by_stride")
    if not by_span:
        t = rec["collective_bytes_per_device"] / LINK_BW
        return t, t, 0.0
    pod_span = 256 if rec["mesh"] == "multi" else 1 << 30
    ici_b = dcn_b = 0.0
    for key, v in by_span.items():
        span = int(key.rsplit("@", 1)[1])
        if span >= pod_span:
            dcn_b += v
        else:
            ici_b += v
    ici_s, dcn_s = ici_b / LINK_BW, dcn_b / DCN_BW
    return ici_s + dcn_s, ici_s, dcn_s


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    cell = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    t_mem = rec["bytes_accessed_per_device"] / HBM_BW
    t_coll, t_ici, t_dcn = collective_term(rec)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_params = rec.get("params_active") or rec.get("params_total")
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    flops_per_token = 6 * n_params if cell.kind == "train" else 2 * n_params
    model_flops = flops_per_token * tokens
    hlo_global = rec["flops_per_device"] * chips
    ratio = model_flops / hlo_global if hlo_global > 0 else float("nan")
    bound = max(terms.values())
    roofline_frac = min(1.0, t_comp / bound) if bound > 0 else 0.0
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=chips, t_compute=t_comp, t_memory=t_mem,
        t_collective=t_coll, t_ici=t_ici, t_dcn=t_dcn,
        dominant=dominant,
        model_flops=model_flops, hlo_flops_global=hlo_global,
        useful_ratio=ratio, roofline_fraction=roofline_frac,
        step_time_bound=bound,
    )


def suggestion(row) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("reduce collective volume: re-shard to cut all-gathers, "
                "tune the factorized A2A round order, overlap with compute")
    if d == "memory":
        if row["useful_ratio"] < 0.5:
            return ("HLO flops >> model flops: remat recompute dominates — "
                    "relax the checkpoint policy or fuse")
        return ("cut HBM traffic: fuse elementwise chains, bf16 "
                "intermediates, bigger kernel blocks")
    return "compute-bound at the MXU: increase per-chip batch or accept"


def rows(mesh: str | None = "single"):
    out = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if mesh and rec.get("mesh") != mesh:
            continue
        row = analyze(rec)
        if row:
            out.append(row)
    return out


FFT_GLOBAL = (512, 512, 512)      # fixed complex64 strong-scaling problem
FFT_TORI = (16, 64, 256, 1024)


def fft_rows():
    """Strong-scaling roofline for the pencil FFT: fixed global problem,
    growing 2-D torus, transpose terms from the per-axis LinkModels."""
    from repro.core import DCN, ICI, dims_create, predict_transpose

    n_total = math.prod(FFT_GLOBAL)
    total_bytes = n_total * 8                       # complex64
    flops = 5.0 * n_total * math.log2(n_total)      # ~FFT flop count
    out = []
    for p in FFT_TORI:
        dims = dims_create(p, 2)
        pencil = total_bytes / p
        t_fft = flops / p / PEAK_FLOPS
        for label, links in (("ici", (ICI, ICI)), ("ici+dcn", (ICI, DCN))):
            # slab: one transpose over the whole torus per direction
            slab_fact = predict_transpose(dims, links, pencil, p)
            slab_dir = predict_transpose(dims, links, pencil, p,
                                         kind="direct")
            # pencil: one per-axis transpose stage per direction
            pen = sum(predict_transpose((Dk,), (lk,), pencil, Dk)
                      for Dk, lk in zip(dims, links))
            t_comm = min(slab_fact, slab_dir, pen)
            out.append(dict(
                p=p, dims=dims, links=label, t_fft=t_fft,
                slab_factorized=slab_fact, slab_direct=slab_dir,
                pencil=pen, bound=max(t_fft, t_comm),
                dominant="compute" if t_fft >= t_comm else "transpose"))
    return out


def print_fft_roofline():
    table = fft_rows()
    size = "x".join(str(n) for n in FFT_GLOBAL)
    print(f"\nFFT strong scaling ({size} complex64, per-direction "
          "transpose terms):")
    print(f"{'p':>5s} {'dims':>10s} {'links':>8s} {'fft(s)':>10s} "
          f"{'slab-f(s)':>10s} {'slab-d(s)':>10s} {'pencil(s)':>10s} "
          f"{'dominant':>10s}")
    for r in table:
        print(f"{r['p']:5d} {str(r['dims']):>10s} {r['links']:>8s} "
              f"{r['t_fft']:10.2e} {r['slab_factorized']:10.2e} "
              f"{r['slab_direct']:10.2e} {r['pencil']:10.2e} "
              f"{r['dominant']:>10s}")
    for r in table:
        print(f"roofline,fft[{size}]p={r['p']};links={r['links']},"
              f"{1e6 * r['bound']:.0f},"
              f"dom={r['dominant']};pencil_us={1e6 * r['pencil']:.1f};"
              f"slab_us={1e6 * r['slab_factorized']:.1f}")


def main():
    print_fft_roofline()
    table = rows("single")
    if not table:
        print("roofline,skipped,no dryrun artifacts")
        return 0
    hdr = (f"{'arch':18s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for r in table:
        print(f"{r['arch']:18s} {r['shape']:12s} {r['t_compute']:9.4f} "
              f"{r['t_memory']:9.4f} {r['t_collective']:9.4f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{100 * r['roofline_fraction']:6.1f}%")
    for r in table:
        print(f"roofline,{r['arch']}__{r['shape']},"
              f"{1e6 * r['step_time_bound']:.0f},"
              f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}")
    return 0


if __name__ == "__main__":
    main()
