"""Dissect one dry-run cell: top computations by loop-weighted bytes and
flops, plus collective breakdown by kind and mesh-axis stride.

The perf-iteration microscope:

  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
  PYTHONPATH=src python -m benchmarks.dissect --arch xlstm-1.3b \
      --shape train_4k --mesh single [--set xlstm_chunk=64]

NOTE: import repro.launch.dryrun FIRST (it pins the 512-device flag).
"""

from __future__ import annotations

import argparse

from repro.launch import dryrun as dr
from repro.core.hlo_inspect import (_comp_bytes, _comp_dot_flops,
                                    _inlined_computations, _multipliers,
                                    _parse_computations,
                                    collective_bytes_by_stride,
                                    loop_aware_analysis)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", dest="overrides")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    cfg, model, lowered = dr.build_lowered(args.arch, args.shape,
                                           args.mesh,
                                           overrides=args.overrides)
    compiled = lowered.compile()
    text = compiled.as_text()
    la = loop_aware_analysis(text)
    print(f"== {args.arch} x {args.shape} x {args.mesh} "
          f"overrides={args.overrides}")
    print(f"flops/dev {la['flops']:.4g}  bytes/dev {la['bytes_proxy']:.4g}"
          f"  coll/dev {la['collective_bytes']:.4g}")
    print(f"terms(s): comp {la['flops'] / 197e12:.2f} "
          f"mem {la['bytes_proxy'] / 819e9:.2f} "
          f"coll {la['collective_bytes'] / 50e9:.2f}")
    print("memory_analysis:", dr._mem_dict(compiled.memory_analysis()))

    comps = _parse_computations(text)
    mult = _multipliers(comps)
    inlined = _inlined_computations(comps)
    rows = []
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        b = _comp_bytes(comp, comps) if name not in inlined else 0.0
        f = _comp_dot_flops(comp)
        rows.append((m * b, m * f, m, name, len(comp.ops)))
    print(f"\ntop {args.top} computations by loop-weighted bytes:")
    for wb, wf, m, name, nops in sorted(rows, reverse=True)[:args.top]:
        print(f"  {wb:12.4g} B  {wf:12.4g} F  x{m:<10.0f} {name} "
              f"({nops} ops)")

    print("\ncollectives by (kind, member-stride):")
    for (k, s), v in sorted(collective_bytes_by_stride(text).items(),
                            key=lambda kv: -kv[1]):
        print(f"  {k:22s} stride={s:<6d} {v:12.4g} B")


if __name__ == "__main__":
    main()
