"""Self-consistent performance-guideline verification (paper viewpoint 3).

Reads the alltoall_cmp measurements and reports every block size where
the native (direct) collective loses to its own factorized composition —
the class of defect the paper exposes in OpenMPI 4.1.6 (Fig. 2).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core import Measurement, check_guidelines, format_report

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def main():
    src = ARTIFACTS / "alltoall_cmp.json"
    if not src.exists():
        print("guidelines,skipped,no alltoall_cmp.json "
              "(run benchmarks.alltoall_cmp first)")
        return 0
    rows = json.loads(src.read_text())
    # Only the dense all-to-all columns: the guideline compares the
    # native collective against compositions of *itself* — the ragged
    # (Alltoallv) and allgather (gather-family) columns measure different
    # collectives and must not masquerade as composed all-to-alls.
    composed = ("factorized[", "overlap[", "autotune[")
    ms = [Measurement(r["impl"], r["block_elems"], r["seconds"])
          for r in rows
          if r["impl"] == "direct" or r["impl"].startswith(composed)]
    violations = check_guidelines(ms, tolerance=1.10)
    print(format_report(violations))
    for v in violations:
        print(f"guidelines,violation,{v.block_elems},"
              f"{v.factor:.2f}x,{v.best_composed_impl}")
    if not violations:
        print("guidelines,clean,0")
    return 0


if __name__ == "__main__":
    main()
