"""Per-architecture smoke-config step timings (single device, measured).

One row per arch for train-step and decode-step — the measured-substrate
complement to the derived full-scale roofline table.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model, make_serve_step, make_train_step
from repro.optim import AdamW, AdamWConfig

REPS, WARMUP = 10, 3


def _bench(fn, *args):
    out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    key = jax.random.PRNGKey(0)
    for arch in ARCH_NAMES:
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params = model.init(key)
        opt = AdamW(AdamWConfig(lr=1e-3))
        B, S = 2, 32
        toks = jnp.zeros((B, S), jnp.int32)
        batch = {"tokens": toks, "labels": toks,
                 "mask": jnp.ones((B, S), jnp.float32)}
        if cfg.frontend is not None or cfg.encoder_layers:
            batch["frontend_embeds"] = jnp.zeros(
                (B, cfg.n_frontend_tokens, cfg.d_model))
        step = jax.jit(make_train_step(model, opt))
        sec = _bench(step, params, opt.init(params), batch)
        print(f"model_step.train,{arch},{sec * 1e6:.0f},smoke B=2 S=32")

        serve = jax.jit(make_serve_step(model))
        caches = model.init_caches(B, 64)
        if cfg.encoder_layers:
            mem = model.encode(params, batch["frontend_embeds"])
            sec = _bench(serve, params, caches, toks[:, :1], mem)
        else:
            sec = _bench(serve, params, caches, toks[:, :1])
        print(f"model_step.decode,{arch},{sec * 1e6:.0f},smoke B=2")
    return 0


if __name__ == "__main__":
    main()
