"""What zero-copy saves: explicit datatype pack/unpack vs metadata-only.

The paper's central implementation claim is that derived datatypes make
the d-round algorithm *formally zero-copy* — an implementation without
them must pack composite messages before (and unpack after) every round.
We measure that explicit-copy cost per round (the Pallas/XLA
``block_reorder`` path) against the zero-copy path's 0 bytes, per buffer
size — single device, pure local-copy cost.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ops import pack_round, unpack_round

DIMS = (4, 4, 4)   # p = 64 blocks
REPS, WARMUP = 30, 5


def bench(fn):
    for _ in range(WARMUP):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    p = 64
    for nelem in (16, 256, 4096, 65536):
        x = jnp.ones((p, nelem), jnp.float32)
        for k in range(len(DIMS)):
            pk = jax.jit(lambda x, k=k: unpack_round(
                pack_round(x, DIMS, k, impl="xla"), DIMS, k, impl="xla"))
            sec = bench(lambda: pk(x))
            mb = x.nbytes / 1e6
            print(f"zero_copy_cost,round{k},elems={nelem},"
                  f"{sec * 1e6:.1f},us for {2 * mb:.2f} MB copied "
                  f"(zero-copy path: 0 bytes)")
    return 0


if __name__ == "__main__":
    main()
