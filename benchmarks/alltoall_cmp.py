"""Paper §5 (Figures 1–3): factorized vs direct all-to-all over message
sizes.

Protocol mirrors the paper: element counts in deciles 1..10000 of int32
("MPI_INT") per process pair, 8 warmup + 40 measured repetitions,
best-of (completion time of the slowest process ~ host wall time here),
barrier via ``block_until_ready``.  p = 16 virtual CPU devices;
factorizations d=1 (direct), 2, 3, 4 = ceil(log2 p) from dims_create,
plus the chunk-pipelined ``overlap[d=2]`` schedule (core.overlap) — on
the CPU harness overlap carries correctness-priced overhead only and
should sit within noise of ``factorized[d=2]``; the link-level win needs
multi-ported hardware (see tuning.predict_overlapped).

This is the CPU-backend *measured* analogue; the TPU-regime predictions
come from the tuning model and the roofline artifacts.  Run via:

  XLA_FLAGS=--xla_force_host_platform_device_count=16 \
      PYTHONPATH=src python -m benchmarks.alltoall_cmp
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import dims_create, host_alltoall
from repro.core.cache import cart_create

P_PROCS = 16
ELEMENTS = (1, 10, 100, 1000, 10000)
WARMUP, REPS = 8, 40

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def bench(fn, x):
    for _ in range(WARMUP):
        jax.block_until_ready(fn(x))
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    if jax.device_count() < P_PROCS:
        print(f"need {P_PROCS} devices (run via benchmarks.run)",
              file=sys.stderr)
        return 1
    rows = []
    variants = [("direct", (P_PROCS,), "direct")]
    for d in (2, 3, 4):
        variants.append((f"factorized[d={d}]", dims_create(P_PROCS, d),
                         "factorized"))
    variants.append(("overlap[d=2]", dims_create(P_PROCS, 2), "overlap"))

    for impl, dims, backend in variants:
        names = tuple(f"t{i}" for i in range(len(dims)))
        mesh = cart_create(P_PROCS, tuple(reversed(dims)), names)
        fn = host_alltoall(mesh, names, backend=backend)
        for nelem in ELEMENTS:
            x = jnp.ones((P_PROCS, P_PROCS, nelem), jnp.int32)
            sec = bench(fn, x)
            rows.append({"impl": impl, "dims": list(dims),
                         "block_elems": nelem, "seconds": sec})
            print(f"alltoall_cmp,{impl},{nelem},{sec * 1e6:.1f}")

    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "alltoall_cmp.json").write_text(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
