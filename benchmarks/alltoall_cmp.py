"""Paper §5 (Figures 1–3): factorized vs direct all-to-all over message
sizes, constructed through the ``TorusComm`` API root (``core.comm``)
and executed through the plan objects it returns.

Protocol mirrors the paper: element counts in deciles 1..10000 of int32
("MPI_INT") per process pair, 8 warmup + 40 measured repetitions,
best-of (completion time of the slowest process ~ host wall time here),
barrier via ``block_until_ready``.  p = 16 virtual CPU devices by
default (``--p`` overrides — the CI smoke job runs p = 8);
factorizations d=1 (direct), 2, 3, 4 = ceil(log2 p) from dims_create,
plus the chunk-pipelined ``overlap[d=2]`` schedule (core.overlap) — on
the CPU harness overlap carries correctness-priced overhead only and
should sit within noise of ``factorized[d=2]``; the link-level win needs
multi-ported hardware (see tuning.predict_overlapped).

Each row additionally measures the paper's *cached-communicator
amortization* on our stack (Listings 1–2: setup once, reuse forever):

* ``plan_cold_us``   — ``torus_comm(...).all_to_all(...)`` with empty
  registries: the full once-per-plan resolution (communicator build,
  factorization, cost model, schedule).
* ``plan_cached_us`` — the same call hitting the comm + plan LRU
  registries, i.e. the per-call cost every steady-state all-to-all pays.

The ``ragged[d=2]`` column measures the bucketed Alltoallv subsystem
(core.ragged): ``block_elems`` is the per-pair ``max_count`` of int32
rows, counts are a fixed non-uniform matrix, and the recorded ``seconds``
covers the counts phase plus the bucket-padded data rounds — with the
achieved ``occupancy`` (useful rows / bucketed rows) alongside.

The ``sparse[d=2]`` column measures the sparse-neighborhood Alltoallv
(core.sparse) on the same d=2 factorization: counts are the same
per-pair bound but only a ~10% random subset of pairs is non-zero, so
the plan's per-round neighborhoods skip the all-empty combined messages
— recorded alongside as ``density`` / ``skipped_exchanges`` /
``combined_messages`` (from the plan's host-side ``analyze``).  Compare
against ``ragged[d=2]`` at the same ``block_elems`` for the measured
dense<->sparse crossover the density-aware tuner models.

The ``allgather[d=2]`` column measures the dimension-wise gather family
(``comm.all_gather``): ``block_elems`` int32 elements contributed per
rank, exchanged as d per-axis stages on the same cached communicator —
plus the usual plan cold/cached construction columns.

The ``fft[d=2]`` column measures the pencil-decomposition FFT workload
(``workloads.fft``) on the same d=2 factorization: a 2-D slab transform
of global shape ``(p, p*block_elems)`` complex64 whose single global
transpose is a cached ``TransposePlan`` carrying ``block_elems``
elements per peer — ``seconds`` is the full forward transform (local
FFTs + the transpose collective), and the plan cold/cached columns
price the whole ``pencil_fft`` resolution (comm + transpose + inner
dense plan) exactly like the other rows.

The ``autotune[d=2]`` column prices the measured-selection pipeline
(core.autotune) against an isolated throwaway tuning DB:

* ``autotune_search_us`` — the one-time cold empirical search (every
  candidate timed, winner persisted);
* ``plan_cold_us``      — rebuilding the winner from the warm DB with
  empty plan registries (what a fresh process pays);
* ``plan_cached_us``    — the steady-state LRU fetch, as above.

This is the CPU-backend *measured* analogue; the TPU-regime predictions
come from the tuning model and the roofline artifacts.  Run via:

  XLA_FLAGS=--xla_force_host_platform_device_count=16 \
      PYTHONPATH=src python -m benchmarks.alltoall_cmp [--p 16] [--out F]
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import dims_create
from repro.core.autotune import TuningDB, autotune
from repro.core.cache import cart_create, free_all
from repro.core.comm import free_comms, torus_comm
from repro.core.plan import free_plans, plan_cache_stats

ELEMENTS = (1, 10, 100, 1000, 10000)
WARMUP, REPS = 8, 40
PLAN_REPS = 200

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def bench(fn, x):
    for _ in range(WARMUP):
        jax.block_until_ready(fn(x))
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_plan_construction(mesh, names, nelem, backend, method="all_to_all",
                            **plan_kw):
    """(cold_seconds, cached_seconds) for one plan resolution through the
    communicator, best-of (same protocol as the collective timings).
    Cold clears *all three* registries (comms, plans, and factorization
    descriptors + fingerprint memo) so it prices the full once-per-plan
    setup including the implicit-comm build — for backend="autotune" that
    is the warm-DB reconstruction path, never a measurement."""
    kw = dict(block_shape=(nelem,), dtype=jnp.int32, backend=backend,
              **plan_kw)
    cold = float("inf")
    for _ in range(8):
        free_comms()
        free_plans()
        free_all()
        t0 = time.perf_counter()
        getattr(torus_comm(mesh, names), method)(**kw)
        cold = min(cold, time.perf_counter() - t0)
    cached = float("inf")
    for _ in range(8):
        t0 = time.perf_counter()
        for _ in range(PLAN_REPS):
            getattr(torus_comm(mesh, names), method)(**kw)
        cached = min(cached, (time.perf_counter() - t0) / PLAN_REPS)
    return cold, cached


def bench_ragged(p_procs, rows):
    """The ragged (Alltoallv) column: bucketed execution on the d=2
    factorization with non-uniform per-pair counts.

    ``block_elems`` plays the role of ``max_count`` (int32 rows per pair,
    so the bucket is its power-of-two round-up); counts are a fixed
    pseudo-random matrix in [0, max_count], giving the recorded
    ``occupancy`` ~ mean/bucket.  ``seconds`` therefore includes the
    counts phase + the bucket-padded data rounds — the end-to-end price
    ``tuning.predict_ragged`` models."""
    dims = dims_create(p_procs, 2)
    names = tuple(f"t{i}" for i in range(len(dims)))
    mesh = cart_create(p_procs, tuple(reversed(dims)), names)
    comm = torus_comm(mesh, names)
    rng = np.random.default_rng(0)
    for nelem in ELEMENTS:
        plan = comm.ragged_all_to_all((), jnp.int32, max_count=nelem)
        counts = jnp.asarray(rng.integers(0, nelem + 1,
                                          size=(p_procs, p_procs)),
                             jnp.int32)
        x = jnp.ones((p_procs, p_procs, plan.bucket), jnp.int32)
        fn = plan.host_fn()
        sec = bench(lambda x: fn(x, counts), x)
        cold, cached = bench_ragged_plan_construction(mesh, names, nelem)
        occ = float(np.asarray(counts).mean() / plan.bucket)
        rows.append({"impl": "ragged[d=2]", "dims": list(dims),
                     "block_elems": nelem, "seconds": sec,
                     "bucket": plan.bucket, "occupancy": occ,
                     "plan_cold_us": cold * 1e6,
                     "plan_cached_us": cached * 1e6,
                     "plan": plan.describe()})
        print(f"alltoall_cmp,ragged[d=2],{nelem},{sec * 1e6:.1f},"
              f"bucket={plan.bucket},occupancy={occ:.3f},"
              f"plan_cold={cold * 1e6:.1f}us,"
              f"plan_cached={cached * 1e6:.2f}us")


def bench_ragged_plan_construction(mesh, names, max_count):
    """Ragged analogue of ``bench_plan_construction``: cold resolves the
    comm, the data + counts plans and the bucket; cached is the LRU fetch
    of the composed RaggedA2APlan."""
    kw = dict(row_shape=(), dtype=jnp.int32, max_count=max_count)
    cold = float("inf")
    for _ in range(8):
        free_comms()
        free_plans()
        free_all()
        t0 = time.perf_counter()
        torus_comm(mesh, names).ragged_all_to_all(**kw)
        cold = min(cold, time.perf_counter() - t0)
    cached = float("inf")
    for _ in range(8):
        t0 = time.perf_counter()
        for _ in range(PLAN_REPS):
            torus_comm(mesh, names).ragged_all_to_all(**kw)
        cached = min(cached, (time.perf_counter() - t0) / PLAN_REPS)
    return cold, cached


SPARSE_DENSITY = 0.1


def bench_sparse(p_procs, rows):
    """The sparse-neighborhood (Alltoallv) column: message-combining
    execution on the d=2 factorization with a ~``SPARSE_DENSITY``
    fraction of non-zero pairs.

    Same protocol as ``bench_ragged`` (``block_elems`` = per-pair
    ``max_count`` of int32 rows, power-of-two bucket), but the fixed
    pseudo-random count matrix is sparse, so whole per-round combined
    messages are empty and the plan skips them — ``seconds`` is the
    counts phase plus only the non-empty data lanes, the end-to-end
    price ``tuning.predict_sparse`` models.  The achieved ``density``
    and the skip counters come from the plan's host-side ``analyze``."""
    dims = dims_create(p_procs, 2)
    names = tuple(f"t{i}" for i in range(len(dims)))
    mesh = cart_create(p_procs, tuple(reversed(dims)), names)
    comm = torus_comm(mesh, names)
    rng = np.random.default_rng(0)
    for nelem in ELEMENTS:
        plan = comm.sparse_all_to_all((), jnp.int32, max_count=nelem,
                                      density=SPARSE_DENSITY)
        counts_np = (rng.integers(1, nelem + 1, size=(p_procs, p_procs))
                     * (rng.random((p_procs, p_procs)) < SPARSE_DENSITY))
        counts = jnp.asarray(counts_np, jnp.int32)
        x = jnp.ones((p_procs, p_procs, plan.bucket), jnp.int32)
        fn = plan.host_fn()
        sec = bench(lambda x: fn(x, counts), x)
        cold, cached = bench_sparse_plan_construction(mesh, names, nelem)
        stats = plan.analyze(np.asarray(counts_np))
        rows.append({"impl": "sparse[d=2]", "dims": list(dims),
                     "block_elems": nelem, "seconds": sec,
                     "bucket": plan.bucket,
                     "density": stats["density"],
                     "skipped_exchanges": stats["skipped_exchanges"],
                     "combined_messages": stats["combined_messages"],
                     "plan_cold_us": cold * 1e6,
                     "plan_cached_us": cached * 1e6,
                     "plan": plan.describe()})
        print(f"alltoall_cmp,sparse[d=2],{nelem},{sec * 1e6:.1f},"
              f"bucket={plan.bucket},density={stats['density']:.3f},"
              f"skipped={stats['skipped_exchanges']},"
              f"plan_cold={cold * 1e6:.1f}us,"
              f"plan_cached={cached * 1e6:.2f}us")


def bench_sparse_plan_construction(mesh, names, max_count):
    """Sparse analogue of ``bench_ragged_plan_construction``: cold
    resolves the comm, the counts plan, the per-round message masks and
    the cost model; cached is the LRU fetch of the SparseA2APlan."""
    kw = dict(row_shape=(), dtype=jnp.int32, max_count=max_count,
              density=SPARSE_DENSITY)
    cold = float("inf")
    for _ in range(8):
        free_comms()
        free_plans()
        free_all()
        t0 = time.perf_counter()
        torus_comm(mesh, names).sparse_all_to_all(**kw)
        cold = min(cold, time.perf_counter() - t0)
    cached = float("inf")
    for _ in range(8):
        t0 = time.perf_counter()
        for _ in range(PLAN_REPS):
            torus_comm(mesh, names).sparse_all_to_all(**kw)
        cached = min(cached, (time.perf_counter() - t0) / PLAN_REPS)
    return cold, cached


def bench_allgather(p_procs, rows):
    """The dimension-wise gather-family column: ``comm.all_gather`` on
    the d=2 factorization.  ``block_elems`` int32 elements are
    contributed per rank; ``seconds`` covers the d per-axis stages
    (``backend="factorized"``); plan cold/cached columns price the
    comm-rooted construction exactly like the all-to-all rows."""
    dims = dims_create(p_procs, 2)
    names = tuple(f"t{i}" for i in range(len(dims)))
    mesh = cart_create(p_procs, tuple(reversed(dims)), names)
    comm = torus_comm(mesh, names)
    for nelem in ELEMENTS:
        plan = comm.all_gather((nelem,), jnp.int32, backend="factorized")
        x = jnp.ones((p_procs, nelem), jnp.int32)
        sec = bench(plan.host_fn(), x)
        cold, cached = bench_plan_construction(mesh, names, nelem,
                                               "factorized",
                                               method="all_gather")
        rows.append({"impl": "allgather[d=2]", "dims": list(dims),
                     "block_elems": nelem, "seconds": sec,
                     "plan_cold_us": cold * 1e6,
                     "plan_cached_us": cached * 1e6,
                     "plan": plan.describe()})
        print(f"alltoall_cmp,allgather[d=2],{nelem},{sec * 1e6:.1f},"
              f"plan_cold={cold * 1e6:.1f}us,"
              f"plan_cached={cached * 1e6:.2f}us")


def bench_fft(p_procs, rows):
    """The pencil-FFT workload column: a 2-D slab ``pencil_fft`` on the
    d=2 factorization, global shape ``(p, p*block_elems)`` complex64 —
    one global transpose per direction, carrying ``block_elems``
    elements per peer through a cached ``TransposePlan``.  ``seconds``
    is the jitted forward transform (local FFTs + transpose); the plan
    columns price the full ``pencil_fft`` resolution."""
    from jax.sharding import NamedSharding

    from repro.workloads import pencil_fft

    dims = dims_create(p_procs, 2)
    names = tuple(f"t{i}" for i in range(len(dims)))
    mesh = cart_create(p_procs, tuple(reversed(dims)), names)
    comm = torus_comm(mesh, names)
    for nelem in ELEMENTS:
        shape = (p_procs, p_procs * nelem)
        fft = pencil_fft(comm, shape, backend="factorized")
        fn = fft.forward_fn()
        x = jax.device_put(jnp.ones(shape, jnp.complex64),
                           NamedSharding(mesh, fft.in_spec))
        sec = bench(fn, x)
        cold, cached = bench_fft_plan_construction(mesh, names, shape)
        d = fft.describe()
        rows.append({"impl": "fft[d=2]", "dims": list(dims),
                     "block_elems": nelem, "seconds": sec,
                     "global_shape": list(shape),
                     "decomposition": d["decomposition"],
                     "predicted_transpose_seconds":
                         d["predicted_transpose_seconds"],
                     "plan_cold_us": cold * 1e6,
                     "plan_cached_us": cached * 1e6,
                     "plan": fft.plans[0].describe()})
        print(f"alltoall_cmp,fft[d=2],{nelem},{sec * 1e6:.1f},"
              f"decomp={d['decomposition']},"
              f"plan_cold={cold * 1e6:.1f}us,"
              f"plan_cached={cached * 1e6:.2f}us")


def bench_fft_plan_construction(mesh, names, shape):
    """FFT analogue of ``bench_plan_construction``: cold resolves the
    comm plus every stage TransposePlan (and its inner dense plan);
    cached re-resolves the same ``pencil_fft`` against warm registries."""
    from repro.workloads import pencil_fft

    cold = float("inf")
    for _ in range(8):
        free_comms()
        free_plans()
        free_all()
        t0 = time.perf_counter()
        pencil_fft(torus_comm(mesh, names), shape, backend="factorized")
        cold = min(cold, time.perf_counter() - t0)
    cached = float("inf")
    for _ in range(8):
        t0 = time.perf_counter()
        for _ in range(PLAN_REPS):
            pencil_fft(torus_comm(mesh, names), shape,
                       backend="factorized")
        cached = min(cached, (time.perf_counter() - t0) / PLAN_REPS)
    return cold, cached


def bench_autotune(p_procs, rows):
    """The measured-selection column: cold search vs warm-DB plan hits.

    Uses a throwaway ``TuningDB`` in a temp directory (never the user's
    ``~/.cache/repro/tuning.json``), passed explicitly through
    ``comm.all_to_all(db=...)``."""
    dims = dims_create(p_procs, 2)
    names = tuple(f"t{i}" for i in range(len(dims)))
    mesh = cart_create(p_procs, tuple(reversed(dims)), names)
    with tempfile.TemporaryDirectory(prefix="repro-tuning-") as tmp:
        db = TuningDB(Path(tmp) / "tuning.json")
        for nelem in ELEMENTS:
            db.clear()
            free_plans()
            t0 = time.perf_counter()
            plan = autotune(mesh, names, (nelem,), jnp.int32, warmup=2,
                            repeats=5, budget_seconds=10.0, db=db)
            search = time.perf_counter() - t0
            fn = plan.host_fn()
            x = jnp.ones((p_procs, p_procs, nelem), jnp.int32)
            sec = bench(fn, x)
            cold, cached = bench_plan_construction(mesh, names, nelem,
                                                   "autotune", db=db)
            rows.append({"impl": "autotune[d=2]", "dims": list(dims),
                         "block_elems": nelem, "seconds": sec,
                         "plan_cold_us": cold * 1e6,
                         "plan_cached_us": cached * 1e6,
                         "autotune_search_us": search * 1e6,
                         "plan": plan.describe()})
            print(f"alltoall_cmp,autotune[d=2],{nelem},{sec * 1e6:.1f},"
                  f"search={search * 1e6:.0f}us,"
                  f"db_hit_cold={cold * 1e6:.1f}us,"
                  f"plan_cached={cached * 1e6:.2f}us,"
                  f"winner={plan.backend}[n={plan.n_chunks}]")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=16,
                    help="process (device) count; CI smoke uses 8")
    ap.add_argument("--out", type=Path,
                    default=ARTIFACTS / "alltoall_cmp.json",
                    help="artifact path (CI writes outside the tree so "
                         "the committed golden stays the schema baseline)")
    args = ap.parse_args(argv)
    p_procs = args.p

    if jax.device_count() < p_procs:
        print(f"need {p_procs} devices (run via benchmarks.run)",
              file=sys.stderr)
        return 1
    rows = []
    variants = [("direct", (p_procs,), "direct")]
    for d in (2, 3, 4):
        variants.append((f"factorized[d={d}]", dims_create(p_procs, d),
                         "factorized"))
    variants.append(("overlap[d=2]", dims_create(p_procs, 2), "overlap"))

    for impl, dims, backend in variants:
        names = tuple(f"t{i}" for i in range(len(dims)))
        mesh = cart_create(p_procs, tuple(reversed(dims)), names)
        comm = torus_comm(mesh, names)
        for nelem in ELEMENTS:
            plan = comm.all_to_all(block_shape=(nelem,),
                                   dtype=jnp.int32, backend=backend)
            fn = plan.host_fn()
            x = jnp.ones((p_procs, p_procs, nelem), jnp.int32)
            sec = bench(fn, x)
            cold, cached = bench_plan_construction(mesh, names, nelem,
                                                   backend)
            rows.append({"impl": impl, "dims": list(dims),
                         "block_elems": nelem, "seconds": sec,
                         "plan_cold_us": cold * 1e6,
                         "plan_cached_us": cached * 1e6,
                         "plan": plan.describe()})
            print(f"alltoall_cmp,{impl},{nelem},{sec * 1e6:.1f},"
                  f"plan_cold={cold * 1e6:.1f}us,"
                  f"plan_cached={cached * 1e6:.2f}us")

    bench_allgather(p_procs, rows)
    bench_fft(p_procs, rows)
    bench_ragged(p_procs, rows)
    bench_sparse(p_procs, rows)
    bench_autotune(p_procs, rows)

    stats = plan_cache_stats()
    print(f"alltoall_cmp,plan_cache,hits={stats['hits']},"
          f"misses={stats['misses']},evictions={stats['evictions']}")
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
