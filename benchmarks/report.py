"""Generate the EXPERIMENTS.md tables from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.report [--dir dryrun] [--mesh single]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.roofline import (DCN_BW, HBM_BW, LINK_BW, PEAK_FLOPS,
                                 analyze, suggestion)

BASE = Path(__file__).resolve().parent / "artifacts"


def load(dirname: str):
    recs = []
    for f in sorted((BASE / dirname).glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("tag"):
            recs.append(rec)
    return recs


def dryrun_table(recs, mesh):
    print(f"\n### Dry-run cells ({mesh} mesh)\n")
    print("| arch | shape | status | compile (s) | args GB/dev | "
          "temp GB/dev | HLO GFLOP/dev | coll GB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                  f"{reason} | | | | | |")
            continue
        mem = r["memory_analysis"]
        print(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} | "
              f"{mem.get('argument_size_in_bytes', 0) / 1e9:.2f} | "
              f"{mem.get('temp_size_in_bytes', 0) / 1e9:.2f} | "
              f"{r['flops_per_device'] / 1e9:.0f} | "
              f"{r['collective_bytes_per_device'] / 1e9:.1f} |")


def roofline_table(recs, mesh):
    print(f"\n### Roofline ({mesh} mesh; {PEAK_FLOPS/1e12:.0f} TF bf16, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {LINK_BW/1e9:.0f} GB/s ICI"
          + (f", {DCN_BW/1e9:.1f} GB/s DCN" if mesh == "multi" else "")
          + ")\n")
    print("| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) "
          "| T_ici | T_dcn | dominant | 6ND/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        a = analyze(r)
        print(f"| {a['arch']} | {a['shape']} | {a['t_compute']:.3f} | "
              f"{a['t_memory']:.2f} | {a['t_collective']:.2f} | "
              f"{a['t_ici']:.2f} | {a['t_dcn']:.2f} | {a['dominant']} | "
              f"{a['useful_ratio']:.2f} | "
              f"{100 * a['roofline_fraction']:.1f}% |")


def bottleneck_notes(recs, mesh):
    print(f"\n### Per-cell bottleneck notes ({mesh})\n")
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        a = analyze(r)
        print(f"- **{a['arch']} × {a['shape']}**: {a['dominant']}-bound "
              f"— {suggestion(a)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--notes", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for m in meshes:
        dryrun_table(recs, m)
        roofline_table(recs, m)
        if args.notes:
            bottleneck_notes(recs, m)


if __name__ == "__main__":
    main()
