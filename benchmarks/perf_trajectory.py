"""Per-PR perf-trajectory baseline: dense vs ragged vs sparse Alltoallv.

Writes ``BENCH_<n>.json`` **at the repo root** — a small, committed
regression baseline recording the measured microseconds of the three
bucketed exchange backends at three router densities (sparse regime,
mid, fully dense) on the d=2 factorization.  Each PR commits its own
``BENCH_<n>.json``; the regression gate (``--gate``, default on when a
baseline exists) compares the fresh record against the newest earlier
``BENCH_*.json`` at the repo root — the single home for the full
history (BENCH_7/BENCH_8 were migrated from the legacy
``benchmarks/artifacts/`` location) — and fails on a >25% latency
regression in any ``dense_us`` column — the dense factorized exchange
is the stable reference; the ragged/sparse columns remain trajectory
data only (their crossover moves by design as tuning evolves).

Columns per density:

* ``dense_us``  — the dense factorized all-to-all moving the same
  ``(p, p, bucket)`` padded buffer (what capacity-padded MoE pays);
* ``ragged_us`` — the bucketed ragged Alltoallv (counts phase + dense
  data rounds), ``core.ragged``;
* ``sparse_us`` — the sparse-neighborhood Alltoallv (counts phase +
  only the non-empty combined messages), ``core.sparse`` — plus its
  oracle-derived ``skip_fraction`` on the measured count matrix.

One extra ``kv_migration`` row times the serving spine's KV-cache
handoff: the ``KVMigrationPlan`` collective with one migrating sequence
per prefill rank (the count matrix non-zero only in the
prefill->decode block) against the dense exchange moving the same
padded buffer.

One extra ``fft`` row times the pencil-decomposition FFT workload
(``workloads.fft``): the jitted 2-D slab forward transform of a
``(p, p*bucket)`` complex64 global array — local FFTs plus one global
transpose through a cached ``TransposePlan`` — against the same dense
reference.

Run via:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.perf_trajectory [--p 8] [--out F]
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import dims_create
from repro.core.cache import cart_create
from repro.core.comm import torus_comm

PR = 10
DENSITIES = (0.05, 0.5, 1.0)
MAX_COUNT = 256
WARMUP, REPS = 4, 20
REGRESSION_THRESHOLD = 0.25     # >25% slower in any dense column fails

ROOT = Path(__file__).resolve().parents[1]


def _best(fn, *args):
    for _ in range(WARMUP):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _counts(p, density, rng):
    """Fixed sparse count matrix: ~density fraction of non-zero pairs,
    each in [1, MAX_COUNT]; at least one pair stays non-zero so the
    exchange is never degenerate."""
    c = (rng.integers(1, MAX_COUNT + 1, size=(p, p))
         * (rng.random((p, p)) < density))
    if not c.any():
        c[0, 0] = MAX_COUNT
    return c.astype(np.int32)


def run(p_procs: int) -> dict:
    dims = dims_create(p_procs, 2)
    names = tuple(f"t{i}" for i in range(len(dims)))
    mesh = cart_create(p_procs, tuple(reversed(dims)), names)
    comm = torus_comm(mesh, names)

    ragged = comm.ragged_all_to_all((), jnp.int32, max_count=MAX_COUNT)
    bucket = ragged.bucket
    dense = comm.all_to_all(block_shape=(bucket,), dtype=jnp.int32,
                            backend="factorized")
    x = jnp.ones((p_procs, p_procs, bucket), jnp.int32)
    dense_fn = dense.host_fn()
    ragged_fn = ragged.host_fn()
    dense_us = _best(dense_fn, x) * 1e6

    rng = np.random.default_rng(0)
    rows = []
    for density in DENSITIES:
        sparse = comm.sparse_all_to_all((), jnp.int32, max_count=MAX_COUNT,
                                        density=density)
        counts_np = _counts(p_procs, density, rng)
        counts = jnp.asarray(counts_np)
        sparse_fn = sparse.host_fn()
        stats = sparse.analyze(counts_np)
        row = {
            "density_requested": density,
            "density_measured": stats["density"],
            "dense_us": dense_us,
            "ragged_us": _best(ragged_fn, x, counts) * 1e6,
            "sparse_us": _best(sparse_fn, x, counts) * 1e6,
            "skip_fraction": stats["skip_fraction"],
            "skipped_exchanges": stats["skipped_exchanges"],
            "total_exchanges": stats["total_exchanges"],
        }
        rows.append(row)
        print(f"perf_trajectory,rho={density},dense={row['dense_us']:.1f}us,"
              f"ragged={row['ragged_us']:.1f}us,"
              f"sparse={row['sparse_us']:.1f}us,"
              f"skip={row['skip_fraction']:.3f}")

    # the serving spine's KV handoff: one migrating sequence per prefill
    # rank, counts non-zero only in the prefill->decode block
    n_prefill = p_procs // 2
    n_decode = p_procs - n_prefill
    kv = comm.kv_migration((), jnp.int32, max_count=MAX_COUNT,
                           n_prefill=n_prefill,
                           migrations_per_tick=float(n_prefill))
    kv_counts = np.zeros((p_procs, p_procs), np.int32)
    for s in range(n_prefill):
        kv_counts[s, n_prefill + s % n_decode] = MAX_COUNT
    kv_us = _best(kv.host_fn(), x, jnp.asarray(kv_counts)) * 1e6
    kv_row = {
        "n_prefill": n_prefill,
        "n_decode": n_decode,
        "migrating_pairs": n_prefill,
        "inner_kind": kv.inner_kind,
        "dense_us": dense_us,
        "kv_migrate_us": kv_us,
    }
    print(f"perf_trajectory,kv_migration,n_prefill={n_prefill},"
          f"inner={kv.inner_kind},dense={dense_us:.1f}us,"
          f"kv_migrate={kv_us:.1f}us")

    # the pencil-FFT workload: 2-D slab forward transform whose global
    # transpose carries `bucket` complex64 elements per peer
    from jax.sharding import NamedSharding

    from repro.workloads import pencil_fft

    fft_shape = (p_procs, p_procs * bucket)
    fft = pencil_fft(comm, fft_shape, backend="factorized")
    xg = jax.device_put(jnp.ones(fft_shape, jnp.complex64),
                        NamedSharding(mesh, fft.in_spec))
    fft_us = _best(fft.forward_fn(), xg) * 1e6
    fft_row = {
        "global_shape": list(fft_shape),
        "decomposition": fft.describe()["decomposition"],
        "transpose_backend": fft.plans[0].backend,
        "dense_us": dense_us,
        "fft_forward_us": fft_us,
    }
    print(f"perf_trajectory,fft,shape={fft_shape[0]}x{fft_shape[1]},"
          f"decomp={fft_row['decomposition']},dense={dense_us:.1f}us,"
          f"fft_forward={fft_us:.1f}us")
    return {"pr": PR, "p": p_procs, "dims": list(dims),
            "max_count": MAX_COUNT, "bucket": bucket, "dtype": "int32",
            "warmup": WARMUP, "repeats": REPS, "densities": rows,
            "kv_migration": kv_row, "fft": fft_row}


def find_baseline(exclude: Path | None = None) -> Path | None:
    """Newest committed baseline: the highest-numbered ``BENCH_<n>.json``
    at the repo root — the single home for the full perf-trajectory
    history (BENCH_7/BENCH_8 were migrated here from the legacy
    ``benchmarks/artifacts/`` location); ``exclude`` keeps a run's own
    output file from being its baseline."""
    cands = []
    for f in ROOT.glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", f.name)
        if m is None:
            continue
        if exclude is not None and f.resolve() == exclude.resolve():
            continue
        cands.append((int(m.group(1)), f))
    if not cands:
        return None
    return max(cands)[1]


def check_regression(record: dict, baseline: dict,
                     threshold: float = REGRESSION_THRESHOLD) -> list[str]:
    """The per-PR gate: every ``dense_us`` column (one per density row,
    plus the kv_migration row's dense reference) must be within
    ``threshold`` of the baseline.  Returns failure messages (empty =
    pass); rows/columns absent from the baseline are skipped — an old
    baseline must not fail a schema-extending PR."""
    failures = []

    def gate(label, new_us, base_us):
        if base_us is None or not base_us > 0 or new_us is None:
            return
        if new_us > base_us * (1.0 + threshold):
            failures.append(
                f"{label}: dense_us {new_us:.1f} > baseline "
                f"{base_us:.1f} by more than {threshold:.0%}")

    base_rows = {r.get("density_requested"): r
                 for r in baseline.get("densities", ())}
    for row in record.get("densities", ()):
        base = base_rows.get(row.get("density_requested"))
        if base is not None:
            gate(f"rho={row.get('density_requested')}",
                 row.get("dense_us"), base.get("dense_us"))
    gate("kv_migration", record.get("kv_migration", {}).get("dense_us"),
         baseline.get("kv_migration", {}).get("dense_us"))
    gate("fft", record.get("fft", {}).get("dense_us"),
         baseline.get("fft", {}).get("dense_us"))
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=8,
                    help="process (device) count; CI smoke uses 8")
    ap.add_argument("--out", type=Path,
                    default=ROOT / f"BENCH_{PR}.json",
                    help="output path (default: repo-root BENCH_%d.json; "
                         "CI writes outside the tree so the committed "
                         "baseline stays put)" % PR)
    ap.add_argument("--no-gate", action="store_true",
                    help="skip the >25%% dense-column regression gate")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="explicit baseline file (default: newest "
                         "committed BENCH_<n>.json)")
    args = ap.parse_args(argv)
    if jax.device_count() < args.p:
        print(f"need {args.p} devices (set "
              f"XLA_FLAGS=--xla_force_host_platform_device_count={args.p})",
              file=sys.stderr)
        return 1
    record = run(args.p)
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(record, indent=1))
    print(f"perf_trajectory,wrote={args.out}")
    if not args.no_gate:
        base_path = args.baseline if args.baseline is not None \
            else find_baseline(exclude=args.out)
        if base_path is None:
            print("perf_trajectory,gate=skipped (no committed baseline)")
        else:
            failures = check_regression(
                record, json.loads(base_path.read_text()))
            if failures:
                print(f"perf_trajectory,gate=FAIL vs {base_path.name}:",
                      file=sys.stderr)
                for msg in failures:
                    print(f"  {msg}", file=sys.stderr)
                return 1
            print(f"perf_trajectory,gate=ok vs {base_path.name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
