"""Paper Table 1: spec-conforming factorizations from dims_create.

Device-free.  Reproduces the paper's p = 36x32 = 1152 rows and adds the
production meshes of this repo (256 single-pod, 512 multi-pod).
"""

from __future__ import annotations

from repro.core import dims_create, max_dims


def rows():
    out = []
    for p in (1152, 256, 512):
        for d in (2, 3, 4):
            out.append((p, d, dims_create(p, d)))
        dlog = 9 if p == 1152 else max_dims(p)   # paper lists 9 for 1152
        out.append((p, dlog, dims_create(p, dlog)))
    return out


def main():
    print("# Paper Table 1 (p=1152) + production meshes")
    for p, d, dims in rows():
        label = "x".join(map(str, dims))
        print(f"table1,p={p},d={d},{label}")
    # the paper's observed OpenMPI violation
    assert dims_create(1152, 2) == (36, 32) != (48, 24)
    print("table1,openmpi_violation_check,passed "
          "(spec gives 36x32, not 48x24)")
    return 0


if __name__ == "__main__":
    main()
