"""CI guard: fail when the alltoall_cmp result schema or backend column
set drifts from the committed golden artifact.

The benchmark-smoke job runs ``benchmarks.alltoall_cmp`` on forced host
devices and compares its fresh JSON against
``benchmarks/artifacts/alltoall_cmp.json`` *structurally* — never on
timings, which are machine-dependent:

* the set of ``impl`` columns (direct, factorized[d=k], overlap[d=2],
  allgather[d=2], fft[d=2], ragged[d=2], sparse[d=2], autotune[d=2])
  must match exactly — a silently dropped or renamed backend column is
  the regression this guard exists for;
* per column, the row key set and the ``plan`` (describe()) key set must
  match — additions and removals both fail, so describe()/artifact
  schema changes have to land together with a regenerated golden;
* per column, the measured ``block_elems`` sweep must match.

Usage: python benchmarks/check_schema.py FRESH.json [GOLDEN.json]
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

GOLDEN = Path(__file__).resolve().parent / "artifacts" / "alltoall_cmp.json"

# Every row must carry these to be classifiable at all; a row missing one
# is reported as a readable per-row diagnosis (row index + the keys it
# does have), never as a bare KeyError traceback.
REQUIRED_ROW_KEYS = ("impl", "block_elems")


def schema(rows: list[dict], problems: list[str] | None = None,
           label: str = "") -> dict[str, dict]:
    cols: dict[str, dict] = {}
    where = f"{label} " if label else ""
    for i, r in enumerate(rows):
        missing = [k for k in REQUIRED_ROW_KEYS if k not in r]
        if missing:
            if problems is not None:
                problems.append(
                    f"{where}row {i}: missing required keys {missing} "
                    f"(has: {sorted(r)})")
            continue
        col = cols.get(r["impl"])
        if col is None:
            col = cols[r["impl"]] = {"keys": set(r), "keys_every": set(r),
                                     "plan_keys": set(r.get("plan") or {}),
                                     "elems": set()}
        # union AND intersection: a key dropped from only *some* rows of a
        # column is drift too, not something the union may paper over
        col["keys"] |= set(r)
        col["keys_every"] &= set(r)
        col["plan_keys"] |= set(r.get("plan") or {})
        col["elems"].add(r["block_elems"])
    return cols


def diff(fresh: dict, golden: dict) -> list[str]:
    problems = []
    if set(fresh) != set(golden):
        problems.append(f"backend column set drift: fresh={sorted(fresh)} "
                        f"golden={sorted(golden)}")
    for impl in sorted(set(fresh) & set(golden)):
        for field in ("keys", "keys_every", "plan_keys", "elems"):
            f, g = fresh[impl][field], golden[impl][field]
            if f != g:
                problems.append(
                    f"{impl}: {field} drift: only-fresh={sorted(f - g)} "
                    f"only-golden={sorted(g - f)}")
    return problems


def main(argv) -> int:
    if not 1 <= len(argv) <= 2:
        print(__doc__, file=sys.stderr)
        return 2
    fresh_path = Path(argv[0])
    golden_path = Path(argv[1]) if len(argv) == 2 else GOLDEN
    problems: list[str] = []
    fresh = schema(json.loads(fresh_path.read_text()), problems, "fresh")
    golden = schema(json.loads(golden_path.read_text()), problems,
                    "golden")
    problems += diff(fresh, golden)
    if problems:
        print("alltoall_cmp schema drift vs committed golden "
              f"({golden_path}):", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        print("If the change is intentional, regenerate the golden: "
              "XLA_FLAGS=--xla_force_host_platform_device_count=16 "
              "PYTHONPATH=src python -m benchmarks.alltoall_cmp",
              file=sys.stderr)
        return 1
    impls = ", ".join(sorted(fresh))
    print(f"OK alltoall_cmp schema matches golden ({impls})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
