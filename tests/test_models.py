"""Model-level invariants: forward == decode path, recurrent scan ==
incremental state, MoE routing properties, ring-buffer windowed cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property import given, settings, st

from repro.models import ModelConfig, build_model
from repro.models.common import init_params
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.moe import moe_block, moe_specs

KEY = jax.random.PRNGKey(0)
BASE = dict(n_kv_heads=2, vocab=97, param_dtype="float32",
            compute_dtype="float32")


def _cfg(name, **kw):
    return ModelConfig(name=name, family="x", n_layers=kw.pop("n_layers", 2),
                       d_model=32, n_heads=4,
                       d_ff=kw.pop("d_ff", 64), **BASE, **kw)


class TestForwardDecodeConsistency:
    """The KV-cache/state decode path must reproduce full-seq forward."""

    @pytest.mark.parametrize("name,kw", [
        ("dense", {}),
        ("swa", {"window": 5}),
        ("moe", {"n_experts": 4, "capacity_factor": 8.0}),
        ("hybrid", {"n_experts": 4, "capacity_factor": 8.0,
                    "moe_every": 2, "block_pattern": ("mamba", "attn")}),
        ("xlstm", {"d_ff": 0, "block_pattern": ("mlstm", "slstm")}),
    ])
    def test_forward_equals_decode(self, name, kw):
        cfg = _cfg(name, **kw)
        m = build_model(cfg)
        p = init_params(m.specs(), KEY, cfg.pdtype)
        B, S = 2, 10
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab)
        logits_full, _ = m.forward(p, toks)
        caches = m.init_caches(B, 16)
        outs = []
        for t in range(S):
            lg, caches = m.decode_step(p, toks[:, t:t + 1], caches)
            outs.append(lg)
        logits_dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.array(logits_full),
                                   np.array(logits_dec),
                                   rtol=5e-3, atol=5e-3)

    def test_ring_buffer_smaller_than_context(self):
        # window=5 cache has only 5 slots; decoding 10 tokens must still
        # match the full forward (ring overwrite correctness).
        cfg = _cfg("swa", window=5)
        m = build_model(cfg)
        p = init_params(m.specs(), KEY, cfg.pdtype)
        caches = m.init_caches(2, 16)
        W = caches["states"]["pos0"]["k"].shape[3]
        assert W == 5  # min(max_seq, window)


class TestRecurrentBlocks:
    @pytest.mark.parametrize("mod,specs,block", [
        (mamba_mod, mamba_mod.mamba_specs, mamba_mod.mamba_block),
        (xlstm_mod, xlstm_mod.mlstm_specs, xlstm_mod.mlstm_block),
        (xlstm_mod, xlstm_mod.slstm_specs, xlstm_mod.slstm_block),
    ])
    def test_scan_equals_incremental(self, mod, specs, block):
        cfg = _cfg("r", ssm_state=8)
        p = init_params(specs(cfg), KEY, jnp.float32)
        x = jax.random.normal(KEY, (2, 12, 32))
        y_full, _ = block(p, x, cfg)
        state, outs = None, []
        for t in range(12):
            yt, state = block(p, x[:, t:t + 1], cfg, state=state)
            outs.append(yt)
        np.testing.assert_allclose(np.array(y_full),
                                   np.array(jnp.concatenate(outs, 1)),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("B,S,L", [(2, 32, 8), (1, 64, 16),
                                       (2, 48, 12)])
    def test_chunked_mlstm_equals_per_step(self, B, S, L):
        cfg0 = _cfg("m", d_ff=0, block_pattern=("mlstm",))
        cfgc = cfg0.replace(xlstm_chunk=L)
        p = init_params(xlstm_mod.mlstm_specs(cfg0), KEY, jnp.float32)
        x = jax.random.normal(KEY, (B, S, 32))
        y0, s0 = xlstm_mod.mlstm_block(p, x, cfg0)
        y1, s1 = xlstm_mod.mlstm_block(p, x, cfgc)
        np.testing.assert_allclose(np.array(y0), np.array(y1),
                                   rtol=3e-4, atol=3e-4)
        for k in ("C", "n", "m"):
            np.testing.assert_allclose(np.array(s0[k]), np.array(s1[k]),
                                       rtol=3e-4, atol=3e-4)

    def test_chunked_mlstm_with_carried_state(self):
        cfg0 = _cfg("m", d_ff=0, block_pattern=("mlstm",))
        cfgc = cfg0.replace(xlstm_chunk=8)
        p = init_params(xlstm_mod.mlstm_specs(cfg0), KEY, jnp.float32)
        x = jax.random.normal(KEY, (2, 48, 32))
        _, st = xlstm_mod.mlstm_block(p, x[:, :16], cfg0)
        y0, _ = xlstm_mod.mlstm_block(p, x[:, 16:], cfg0, state=st)
        y1, _ = xlstm_mod.mlstm_block(p, x[:, 16:], cfgc, state=st)
        np.testing.assert_allclose(np.array(y0), np.array(y1),
                                   rtol=3e-4, atol=3e-4)

    def test_state_sizes_constant_in_seq(self):
        # sub-quadratic property: state size independent of context length
        cfg = _cfg("r", ssm_state=8)
        p = init_params(mamba_mod.mamba_specs(cfg), KEY, jnp.float32)
        _, s1 = mamba_mod.mamba_block(p, jnp.zeros((2, 4, 32)), cfg)
        _, s2 = mamba_mod.mamba_block(p, jnp.zeros((2, 64, 32)), cfg)
        assert jax.tree.map(jnp.shape, s1) == jax.tree.map(jnp.shape, s2)


class TestMoE:
    def test_capacity_drops_are_masked(self):
        # absurdly low capacity: output must stay finite (dropped tokens
        # contribute zero, not garbage)
        cfg = _cfg("moe", n_experts=4, capacity_factor=0.05)
        p = init_params(moe_specs(cfg), KEY, jnp.float32)
        x = jax.random.normal(KEY, (2, 16, 32))
        y, aux = moe_block(p, x, cfg, mesh=None)
        assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))

    def test_aux_loss_balanced_near_one(self):
        # uniform router (zero weights) => perfectly balanced aux ~= 1
        cfg = _cfg("moe", n_experts=4, capacity_factor=4.0)
        p = init_params(moe_specs(cfg), KEY, jnp.float32)
        p["router"] = jnp.zeros_like(p["router"])
        x = jax.random.normal(KEY, (2, 64, 32))
        _, aux = moe_block(p, x, cfg, mesh=None)
        assert abs(float(aux) - 1.0) < 0.05

    @given(st.integers(2, 8), st.sampled_from([1, 2]))
    @settings(max_examples=8, deadline=None)
    def test_gates_route_topk(self, E, k):
        cfg = _cfg("moe", n_experts=E, top_k=k, capacity_factor=8.0)
        p = init_params(moe_specs(cfg), KEY, jnp.float32)
        x = jax.random.normal(KEY, (1, 8, 32))
        y, _ = moe_block(p, x, cfg, mesh=None)
        assert y.shape == x.shape and bool(jnp.isfinite(y).all())

    def test_capacity_clamped_to_routed_tokens(self):
        # the boundary: a tiny batch must never pad the capacity past the
        # routed-token count, whatever the capacity factor says
        from repro.models.moe import _capacity
        cfg = _cfg("moe", n_experts=4, top_k=1, capacity_factor=8.0)
        assert _capacity(cfg, 2, 4) == 2          # was 8 (8-aligned floor)
        assert _capacity(cfg, 1, 4) == 1
        cfg2 = _cfg("moe", n_experts=4, top_k=2, capacity_factor=8.0)
        # per-expert worst case is n_tokens (top_k experts are distinct)
        assert _capacity(cfg2, 3, 4) == 3
        assert _capacity(cfg2, 100, 4) == 100     # clamp binds: 8.0*2*100/4
        cfg3 = _cfg("moe", n_experts=4, top_k=2, capacity_factor=0.25)
        assert _capacity(cfg3, 100, 4) == 16      # unclamped regime: 8-align

    def test_dropless_capacity_is_worst_case(self):
        from repro.models.moe import _capacity
        cfg = _cfg("moe", n_experts=4, top_k=2, capacity_factor=None)
        assert cfg.dropless
        assert _capacity(cfg, 16, 4) == 16
        assert _capacity(cfg, 2, 4) == 2

    def test_dropless_equals_high_capacity_locally(self):
        # capacity_factor=None (dropless) must reproduce the capacity path
        # whenever the capacity path would not have dropped
        cfg_cap = _cfg("moe", n_experts=4, capacity_factor=8.0)
        cfg_drop = _cfg("moe", n_experts=4, capacity_factor=None)
        p = init_params(moe_specs(cfg_cap), KEY, jnp.float32)
        x = jax.random.normal(KEY, (2, 16, 32))
        y_cap, aux_cap = moe_block(p, x, cfg_cap, mesh=None)
        y_drop, aux_drop = moe_block(p, x, cfg_drop, mesh=None)
        np.testing.assert_allclose(np.array(y_cap), np.array(y_drop),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(float(aux_cap), float(aux_drop),
                                   rtol=1e-6)

    def test_dropless_keeps_tokens_the_capacity_path_drops(self):
        # skew the router so one expert overflows a tight capacity: the
        # capacity path drops (some gate mass lost), dropless must not
        cfg_tight = _cfg("moe", n_experts=4, top_k=1, capacity_factor=0.3)
        cfg_drop = _cfg("moe", n_experts=4, top_k=1, capacity_factor=None)
        p = init_params(moe_specs(cfg_tight), KEY, jnp.float32)
        p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
        x = jax.random.normal(KEY, (2, 32, 32))
        y_tight, _ = moe_block(p, x, cfg_tight, mesh=None)
        y_drop, _ = moe_block(p, x, cfg_drop, mesh=None)
        # dropped tokens contribute zero output rows in the tight path
        zero_rows_tight = int(jnp.sum(jnp.all(y_tight == 0, axis=-1)))
        zero_rows_drop = int(jnp.sum(jnp.all(y_drop == 0, axis=-1)))
        assert zero_rows_tight > 0 and zero_rows_drop == 0


class TestRematPolicies:
    @pytest.mark.parametrize("policy", ["nothing", "dots", "collectives"])
    def test_policies_same_loss(self, policy):
        cfg = _cfg("dense", remat=True).replace(remat_policy=policy)
        m = build_model(cfg)
        p = init_params(m.specs(), KEY, cfg.pdtype)
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        loss, _ = m.loss(p, batch)
        g = jax.grad(lambda p: m.loss(p, batch)[0])(p)
        assert bool(jnp.isfinite(loss))
        assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


class TestSpectral:
    """The spectral long-conv mixer (models.spectral): the FFT conv path
    must equal the LTI recurrence exactly (same discretized SSM), the
    prefill state must hand off into decode, and the mixer must slot into
    the model via spectral_long_conv."""

    def _params(self, cfg):
        from repro.models import spectral as spectral_mod
        return init_params(spectral_mod.spectral_specs(cfg), KEY,
                           jnp.float32)

    def test_conv_equals_recurrence(self):
        from repro.models import spectral as spectral_mod
        cfg = _cfg("spec", ssm_state=8)
        p = self._params(cfg)
        x = jax.random.normal(KEY, (2, 12, 32))
        y_conv, st_conv = spectral_mod.spectral_block(p, x, cfg)
        Ein = cfg.ssm_expand * cfg.d_model
        zero = {"ssm": jnp.zeros((2, Ein, cfg.ssm_state), jnp.float32)}
        y_rec, st_rec = spectral_mod.spectral_block(p, x, cfg, state=zero)
        np.testing.assert_allclose(np.array(y_conv), np.array(y_rec),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.array(st_conv["ssm"]),
                                   np.array(st_rec["ssm"]),
                                   rtol=2e-4, atol=2e-4)

    def test_prefill_state_hands_off_to_decode(self):
        from repro.models import spectral as spectral_mod
        cfg = _cfg("spec", ssm_state=8)
        p = self._params(cfg)
        x = jax.random.normal(KEY, (2, 16, 32))
        y_full, _ = spectral_mod.spectral_block(p, x, cfg)
        _, st = spectral_mod.spectral_block(p, x[:, :10], cfg)
        outs = []
        for t in range(10, 16):
            yt, st = spectral_mod.spectral_block(p, x[:, t:t + 1], cfg,
                                                 state=st)
            outs.append(yt)
        np.testing.assert_allclose(np.array(y_full[:, 10:]),
                                   np.array(jnp.concatenate(outs, 1)),
                                   rtol=2e-4, atol=2e-4)

    def test_gradients_flow(self):
        from repro.models import spectral as spectral_mod
        cfg = _cfg("spec", ssm_state=8)
        p = self._params(cfg)
        x = jax.random.normal(KEY, (2, 8, 32))

        def loss(p):
            y, _ = spectral_mod.spectral_block(p, x, cfg)
            return jnp.sum(y ** 2)

        g = jax.grad(loss)(p)
        for name, gv in g.items():
            assert bool(jnp.any(gv != 0)), f"zero grad for {name}"
            assert bool(jnp.isfinite(gv).all()), f"nonfinite grad {name}"

    def test_model_forward_equals_decode(self):
        # spectral_long_conv substitutes the mamba mixer; full-seq
        # forward must match the incremental decode path end to end.
        cfg = _cfg("spec", ssm_state=8, d_ff=0,
                   block_pattern=("mamba",), spectral_long_conv=True)
        assert cfg.superblock == (("spectral", "none"),)
        m = build_model(cfg)
        p = init_params(m.specs(), KEY, cfg.pdtype)
        B, S = 2, 10
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab)
        logits_full, _ = m.forward(p, toks)
        caches = m.init_caches(B, 16)
        outs = []
        for t in range(S):
            lg, caches = m.decode_step(p, toks[:, t:t + 1], caches)
            outs.append(lg)
        np.testing.assert_allclose(np.array(logits_full),
                                   np.array(jnp.concatenate(outs, 1)),
                                   rtol=5e-3, atol=5e-3)

    def test_param_count_estimate_covers_spectral(self):
        cfg = _cfg("spec", ssm_state=8, d_ff=0,
                   block_pattern=("mamba",), spectral_long_conv=True)
        n = cfg.param_count_estimate()
        D, Ein = cfg.d_model, cfg.ssm_expand * cfg.d_model
        per_layer = D * 2 * Ein + Ein * (3 * cfg.ssm_state + 2) + Ein * D
        assert n >= cfg.n_layers * per_layer
