"""Device-free validation of Algorithm 1's index/permutation math.

The simulator models the MPI implementation (flat buffers, derived
datatypes, double buffering) exactly; these tests pin it to the paper's own
worked examples and Theorem 1.  The hypothesis property tests over random
factorizations live in ``test_core_properties.py`` behind
``pytest.importorskip("hypothesis")`` so this module collects everywhere.
"""

import math

import pytest

from repro.core.simulator import (
    PAPER_EXAMPLES,
    check_correct,
    check_correct_alltoallv,
    check_correct_pencil_transpose,
    check_correct_sparse_alltoallv,
    example_index_table,
    pencil_transpose_reference,
    round_datatype,
    simulate_direct_alltoallv,
    simulate_factorized_allgather,
    simulate_factorized_alltoall,
    simulate_factorized_alltoallv,
    simulate_factorized_reduce_scatter,
    simulate_pencil_transpose,
    simulate_sparse_alltoallv,
    strides,
)


def _nonuniform_counts(p: int, max_count: int = 6, seed: int = 0):
    """Deterministic, visibly non-uniform p x p count matrix (zeros
    included: sparse pairs are the Alltoallv point)."""
    state = seed
    rows = []
    for s in range(p):
        row = []
        for t in range(p):
            state = (state * 1103515245 + 12345) % (1 << 31)
            row.append(state % (max_count + 1))
        rows.append(row)
    return rows


class TestPaperExamples:
    @pytest.mark.parametrize("dims", list(PAPER_EXAMPLES))
    def test_index_tables_match_paper(self, dims):
        for k, expected in PAPER_EXAMPLES[dims].items():
            assert example_index_table(dims, k) == expected

    def test_4dim_example_spot_values(self):
        # 4x3x3x4 = 144 (paper shows ellipses; check all visible values,
        # correcting the paper's obvious typos: 104->105/106 duplicates).
        t0 = example_index_table((4, 3, 3, 4), 0)
        assert t0[0][:4] == [0, 36, 72, 108]
        assert t0[0][4] == 12
        assert t0[0][-4:] == [32, 68, 104, 140]
        assert t0[1][:4] == [1, 37, 73, 109]
        assert t0[3][:4] == [3, 39, 75, 111]
        assert t0[3][-4:] == [35, 71, 107, 143]
        t1 = example_index_table((4, 3, 3, 4), 1)
        assert t1[0][:8] == [0, 1, 2, 3, 36, 37, 38, 39]
        assert t1[0][-4:] == [132, 133, 134, 135]
        assert t1[2][:8] == [8, 9, 10, 11, 44, 45, 46, 47]
        assert t1[2][-4:] == [140, 141, 142, 143]
        t2 = example_index_table((4, 3, 3, 4), 2)
        assert t2[0][:12] == list(range(12))
        assert t2[0][12] == 36 and t2[0][-3:] == [117, 118, 119]
        assert t2[2][:12] == list(range(24, 36))
        t3 = example_index_table((4, 3, 3, 4), 3)
        assert t3[0] == list(range(36))
        assert t3[1][:4] == [36, 37, 38, 39]
        assert t3[3][-3:] == [141, 142, 143]

    def test_last_round_blocks_consecutive(self):
        # "the blocks for the last round consist of consecutively indexed
        # elements" — for every factorization.
        for dims in [(5, 4), (2, 3, 4), (4, 3, 3, 4), (2, 2, 2, 2)]:
            pos, extent = round_datatype(dims, len(dims) - 1)
            assert pos == list(range(len(pos)))
            assert extent == math.prod(dims[:-1])

    def test_round0_full_blocks(self):
        # Round 0 composites are single blocks strided by sigma(1).
        pos, extent = round_datatype((5, 4), 0)
        assert extent == 1 and pos == [0, 5, 10, 15]


class TestCorrectness:
    @pytest.mark.parametrize("dims", [
        (2,), (5,), (2, 2), (3, 2), (5, 4), (2, 3, 4), (4, 3, 3, 4),
        (2, 2, 2, 2), (2, 2, 2, 2, 2), (6, 6), (3, 3, 2),
    ])
    def test_factorized_equals_direct(self, dims):
        assert check_correct(dims)

    @pytest.mark.parametrize("order", [(0, 1, 2), (2, 1, 0), (1, 0, 2)])
    def test_round_orders_commute(self, order):
        # deterministic pin; full permutation sweep in test_core_properties
        assert check_correct((2, 3, 4), order)


class TestTheorem1:
    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4), (4, 3, 3, 4),
                                      (2, 2, 2, 2)])
    def test_volume_formula(self, dims):
        _, vol = simulate_factorized_alltoall(dims)
        d, p = len(dims), math.prod(dims)
        assert vol.total_blocks_sent == vol.theorem1_formula
        assert vol.theorem1_formula == d * p - sum(p // Dk for Dk in dims)
        # per-round count: (D[k]-1) * p / D[k]
        for k, Dk in enumerate(dims):
            assert vol.blocks_sent_per_round[k] == (Dk - 1) * (p // Dk)

    def test_hypercube_case(self):
        # p = 2^d: log2(p) rounds, each sending p/2 blocks (hypercube algo).
        _, vol = simulate_factorized_alltoall((2, 2, 2, 2))
        assert all(n == 8 for n in vol.blocks_sent_per_round)
        assert vol.total_blocks_sent == 4 * 16 - 4 * 8 == 32

    def test_datatype_partition_property(self):
        # Each round's instances partition all p block offsets.
        for dims in [(5, 4), (2, 3, 4), (4, 3, 3, 4)]:
            p = math.prod(dims)
            for k in range(len(dims)):
                pos, extent = round_datatype(dims, k)
                all_offsets = sorted(q + j * extent
                                     for j in range(dims[k]) for q in pos)
                assert all_offsets == list(range(p))


class TestRaggedOracle:
    """MPI_Alltoallv on the factorized torus (core.ragged's oracle):
    the paper's worked examples under non-uniform counts, volumes, and
    the uniform-counts degeneration to the dense algorithm."""

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4)])
    def test_paper_examples_nonuniform(self, dims):
        # The paper's 5x4 and 2x3x4 worked factorizations carry arbitrary
        # non-uniform per-pair volumes: the slot movement is count-blind.
        p = math.prod(dims)
        counts = _nonuniform_counts(p)
        final, _ = simulate_factorized_alltoallv(dims, counts)
        want = simulate_direct_alltoallv(counts)
        for r in range(p):
            assert final[r] == want[r]

    @pytest.mark.parametrize("dims,order", [
        ((5, 4), (1, 0)), ((2, 3, 4), (2, 0, 1)), ((2, 3, 4), (1, 2, 0)),
    ])
    def test_round_orders_commute_ragged(self, dims, order):
        counts = _nonuniform_counts(math.prod(dims), seed=7)
        assert check_correct_alltoallv(dims, counts, order)

    def test_zero_rows_and_empty_pairs(self):
        # a rank that sends nothing anywhere, and all-zero pairs
        p = 20
        counts = _nonuniform_counts(p, seed=3)
        counts[4] = [0] * p
        counts[0][1] = counts[1][0] = 0
        assert check_correct_alltoallv((5, 4), counts)

    def test_fully_empty_matrix(self):
        # degenerate Alltoallv: nobody sends anything — the slot movement
        # still runs and must deliver all-empty pairs everywhere
        p = 20
        counts = [[0] * p for _ in range(p)]
        assert check_correct_alltoallv((5, 4), counts)

    def test_single_nonzero_row(self):
        # one rank broadcasts, every other row is empty: the combined
        # round messages are almost all empty but movement stays exact
        p = 24
        counts = [[0] * p for _ in range(p)]
        counts[3] = [2] * p
        assert check_correct_alltoallv((2, 3, 4), counts)
        counts[3] = [0] * p
        counts[3][17] = 5            # single non-zero *entry*
        assert check_correct_alltoallv((2, 3, 4), counts)

    def test_uniform_counts_degenerate_to_dense(self):
        # counts == c everywhere: element ordering per pair must match the
        # dense simulator's block payloads, and slot volume must equal
        # Theorem 1 aggregated over ranks.
        dims, c = (2, 3, 4), 3
        p = math.prod(dims)
        final, vol = simulate_factorized_alltoallv(dims, [[c] * p] * p)
        dense_final, dense_vol = simulate_factorized_alltoall(dims)
        for r in range(p):
            assert [slot[0][:2] for slot in final[r]] == dense_final[r]
            assert all(slot == [(slot[0][0], r, j) for j in range(c)]
                       for slot in final[r])
        assert vol.total_slots_sent == p * dense_vol.theorem1_formula
        assert vol.total_elements_sent == c * vol.total_slots_sent

    def test_occupancy_accounting(self):
        dims = (2, 2)
        p = 4
        counts = [[2] * p] * p          # 2 useful rows per slot
        _, vol = simulate_factorized_alltoallv(dims, counts)
        assert vol.occupancy(2) == pytest.approx(1.0)
        assert vol.occupancy(8) == pytest.approx(0.25)
        # zero traffic edge: occupancy defined as 1.0
        _, vol0 = simulate_factorized_alltoallv((1,), [[5]])
        assert vol0.occupancy(8) == 1.0

    def test_counts_validation(self):
        with pytest.raises(ValueError, match="matrix"):
            simulate_factorized_alltoallv((2, 2), [[1, 2], [3, 4]])
        with pytest.raises(ValueError, match="non-negative"):
            simulate_factorized_alltoallv((2,), [[1, -1], [0, 0]])


class TestSparseOracle:
    """The sparse-neighborhood oracle (core.sparse's reference): the
    same slot movement as the factorized Alltoallv, but all-empty
    combined round messages are skipped — payloads must still equal the
    direct exchange, with per-message skip accounting on top."""

    @staticmethod
    def _sparse_counts(p, density, max_count=6, seed=0):
        import numpy as np
        rng = np.random.default_rng(seed)
        c = (rng.integers(1, max_count + 1, size=(p, p))
             * (rng.random((p, p)) < density))
        return c.astype(int).tolist()

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4)])
    @pytest.mark.parametrize("density", [0.05, 0.3, 1.0])
    def test_paper_tori_random_sparse(self, dims, density):
        p = math.prod(dims)
        counts = self._sparse_counts(p, density, seed=p)
        assert check_correct_sparse_alltoallv(dims, counts)

    @pytest.mark.parametrize("dims,order", [
        ((5, 4), (1, 0)), ((2, 3, 4), (2, 0, 1)), ((2, 3, 4), (1, 2, 0)),
    ])
    def test_round_orders_commute_sparse(self, dims, order):
        counts = self._sparse_counts(math.prod(dims), 0.2, seed=5)
        assert check_correct_sparse_alltoallv(dims, counts, order)

    def test_fully_empty_skips_everything(self):
        p = 12
        counts = [[0] * p for _ in range(p)]
        final, vol = simulate_sparse_alltoallv((3, 4), counts)
        assert vol.skipped_exchanges == vol.total_exchanges
        assert vol.skip_fraction == 1.0
        assert vol.skipped_rounds == 2          # every round all-empty
        assert vol.total_elements_sent == 0
        assert all(final[r][s] == [] for r in range(p) for s in range(p))

    def test_dense_matrix_skips_nothing(self):
        p = 12
        counts = [[1] * p for _ in range(p)]
        _, vol = simulate_sparse_alltoallv((3, 4), counts)
        assert vol.skipped_exchanges == 0 and vol.skipped_rounds == 0
        # per round k every rank exchanges with D[k]-1 peers
        assert vol.total_exchanges == 12 * (3 - 1) + 12 * (4 - 1)
        assert vol.combined_messages == vol.total_exchanges

    def test_low_density_skips_majority(self):
        # the subsystem's acceptance bound, at the oracle level: <=10%
        # density on the 3x4 torus drops over half the per-round
        # combined messages
        counts = self._sparse_counts(12, 0.1, seed=0)
        _, vol = simulate_sparse_alltoallv((3, 4), counts)
        assert vol.skip_fraction >= 0.5


class TestExactAlltoallv:
    """The exact two-phase host mode (core.ragged.exact_alltoallv) against
    the oracle and the trivial transpose reference."""

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4), (3, 2)])
    def test_exact_matches_oracle_slotwise(self, dims):
        import numpy as np
        from repro.core.ragged import exact_alltoallv
        p = math.prod(dims)
        counts = _nonuniform_counts(p, seed=11)
        rows = [[np.arange(counts[s][t], dtype=np.int64) * p * p + s * p + t
                 for t in range(p)] for s in range(p)]
        recv, cm = exact_alltoallv(rows, dims)
        assert cm == counts
        oracle, _ = simulate_factorized_alltoallv(dims, counts)
        for r in range(p):
            for s in range(p):
                np.testing.assert_array_equal(recv[r][s], rows[s][r])
                # oracle slot (s, r, j) tags <-> exact mode's array rows
                assert len(oracle[r][s]) == len(recv[r][s])

    def test_round_message_elements(self):
        from repro.core.ragged import exact_round_message_elements
        dims = (5, 4)
        p = 20
        counts = _nonuniform_counts(p, seed=2)
        # round 1 (last): peer j gets the sigma(1)=5 consecutive slots
        got = exact_round_message_elements(dims, counts, 1)
        want = [sum(counts[0][j * 5:(j + 1) * 5]) for j in range(4)]
        assert got == want

    def test_shape_validation(self):
        import numpy as np
        from repro.core.ragged import exact_alltoallv
        with pytest.raises(ValueError, match="nested list"):
            exact_alltoallv([[np.zeros((1,))]], (2,))


class TestDimwiseGatherOracles:
    """The TorusComm gather family's oracles, pinned to the paper's
    worked tori (5x4, 2x3x4): d-stage all-gather ends rank-ordered,
    d-stage reduce-scatter ends fully reduced, and both move exactly
    p - 1 blocks per rank for any round order (the telescoping volume —
    unlike Theorem 1's all-to-all, the gathers have no combining win,
    only the message-count one)."""

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4)])
    def test_allgather_paper_tori(self, dims):
        import itertools
        p = math.prod(dims)
        for order in itertools.permutations(range(len(dims))):
            out, vol = simulate_factorized_allgather(dims, order)
            assert all(out[r] == list(range(p)) for r in range(p))
            assert vol.total_blocks_sent == p - 1

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4)])
    def test_reduce_scatter_paper_tori(self, dims):
        import itertools
        p = math.prod(dims)
        for order in itertools.permutations(range(len(dims))):
            out, vol = simulate_factorized_reduce_scatter(dims, order)
            assert all(out[r] == [(s, r) for s in range(p)]
                       for r in range(p))
            assert vol.total_blocks_sent == p - 1

    def test_trivial_and_deep_factorizations(self):
        for dims in [(1,), (2,), (1, 3), (2, 2, 2, 2)]:
            p = math.prod(dims)
            out, _ = simulate_factorized_allgather(dims)
            assert all(out[r] == list(range(p)) for r in range(p))
            out, _ = simulate_factorized_reduce_scatter(dims)
            assert all(out[r] == [(s, r) for s in range(p)]
                       for r in range(p))

    def test_stage_volumes_follow_the_held_payload(self):
        # all-gather grows: (D0-1)*1, (D1-1)*D0, ...; reduce-scatter
        # shrinks: p(D0-1)/D0, (p/D0)(D1-1)/D1, ...
        _, vol = simulate_factorized_allgather((2, 3, 4))
        assert vol.blocks_sent_per_round == [1, 2 * 2, 3 * 6]
        _, vol = simulate_factorized_reduce_scatter((2, 3, 4))
        assert vol.blocks_sent_per_round == [24 // 2, 12 * 2 // 3,
                                             4 * 3 // 4]


class TestPencilTranspose:
    """The FFT workload's re-shard oracle: the d-round pencil transpose
    (split one array axis p ways, concatenate received chunks
    source-major on another) on the paper's worked tori."""

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4)])
    @pytest.mark.parametrize("split,concat", [(0, 1), (1, 0)])
    def test_reshard_roundtrip_and_volume(self, dims, split, concat):
        # check_correct_pencil_transpose asserts all three invariants:
        # exact re-shard per rank, round-trip identity, Theorem 1 volume.
        p = math.prod(dims)
        pencil = [3, 3]
        pencil[split] = 2 * p
        assert check_correct_pencil_transpose(dims, tuple(pencil), split,
                                              concat)

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4)])
    def test_rank3_pencils(self, dims):
        p = math.prod(dims)
        assert check_correct_pencil_transpose(dims, (2, p, 3), 1, 2)
        assert check_correct_pencil_transpose(dims, (3, 2, p), 2, 0)

    def test_round_orders_commute(self):
        import itertools
        dims = (2, 3, 4)
        p = math.prod(dims)
        want, _ = simulate_pencil_transpose(dims, (p, 4), 0, 1)
        for order in itertools.permutations(range(len(dims))):
            out, vol = simulate_pencil_transpose(dims, (p, 4), 0, 1, order)
            assert out == want, order
            assert vol.total_blocks_sent == vol.theorem1_formula

    def test_theorem1_per_round(self):
        dims = (2, 3, 4)
        p = math.prod(dims)
        _, vol = simulate_pencil_transpose(dims, (p, 2), 0, 1)
        for k, Dk in enumerate(dims):
            assert vol.blocks_sent_per_round[k] == (Dk - 1) * (p // Dk)

    def test_reference_is_the_global_reshard(self):
        # rank r's output = split-chunk r of every source's pencil, i.e.
        # the same global array re-sharded along the split axis.
        dims = (5, 4)
        p = 20
        out, _ = simulate_pencil_transpose(dims, (p, 3), 0, 1)
        for r in range(p):
            assert out[r] == pencil_transpose_reference(p, (p, 3), 0, 1, r)

    def test_indivisible_split_axis_raises(self):
        with pytest.raises(ValueError):
            simulate_pencil_transpose((2, 3), (5, 4), 0, 1)

    def test_same_axis_raises(self):
        with pytest.raises(ValueError):
            simulate_pencil_transpose((2, 3), (6, 4), 1, 1)
