"""Device-free validation of Algorithm 1's index/permutation math.

The simulator models the MPI implementation (flat buffers, derived
datatypes, double buffering) exactly; these tests pin it to the paper's own
worked examples and Theorem 1.  The hypothesis property tests over random
factorizations live in ``test_core_properties.py`` behind
``pytest.importorskip("hypothesis")`` so this module collects everywhere.
"""

import math

import pytest

from repro.core.simulator import (
    PAPER_EXAMPLES,
    check_correct,
    example_index_table,
    round_datatype,
    simulate_factorized_alltoall,
    strides,
)


class TestPaperExamples:
    @pytest.mark.parametrize("dims", list(PAPER_EXAMPLES))
    def test_index_tables_match_paper(self, dims):
        for k, expected in PAPER_EXAMPLES[dims].items():
            assert example_index_table(dims, k) == expected

    def test_4dim_example_spot_values(self):
        # 4x3x3x4 = 144 (paper shows ellipses; check all visible values,
        # correcting the paper's obvious typos: 104->105/106 duplicates).
        t0 = example_index_table((4, 3, 3, 4), 0)
        assert t0[0][:4] == [0, 36, 72, 108]
        assert t0[0][4] == 12
        assert t0[0][-4:] == [32, 68, 104, 140]
        assert t0[1][:4] == [1, 37, 73, 109]
        assert t0[3][:4] == [3, 39, 75, 111]
        assert t0[3][-4:] == [35, 71, 107, 143]
        t1 = example_index_table((4, 3, 3, 4), 1)
        assert t1[0][:8] == [0, 1, 2, 3, 36, 37, 38, 39]
        assert t1[0][-4:] == [132, 133, 134, 135]
        assert t1[2][:8] == [8, 9, 10, 11, 44, 45, 46, 47]
        assert t1[2][-4:] == [140, 141, 142, 143]
        t2 = example_index_table((4, 3, 3, 4), 2)
        assert t2[0][:12] == list(range(12))
        assert t2[0][12] == 36 and t2[0][-3:] == [117, 118, 119]
        assert t2[2][:12] == list(range(24, 36))
        t3 = example_index_table((4, 3, 3, 4), 3)
        assert t3[0] == list(range(36))
        assert t3[1][:4] == [36, 37, 38, 39]
        assert t3[3][-3:] == [141, 142, 143]

    def test_last_round_blocks_consecutive(self):
        # "the blocks for the last round consist of consecutively indexed
        # elements" — for every factorization.
        for dims in [(5, 4), (2, 3, 4), (4, 3, 3, 4), (2, 2, 2, 2)]:
            pos, extent = round_datatype(dims, len(dims) - 1)
            assert pos == list(range(len(pos)))
            assert extent == math.prod(dims[:-1])

    def test_round0_full_blocks(self):
        # Round 0 composites are single blocks strided by sigma(1).
        pos, extent = round_datatype((5, 4), 0)
        assert extent == 1 and pos == [0, 5, 10, 15]


class TestCorrectness:
    @pytest.mark.parametrize("dims", [
        (2,), (5,), (2, 2), (3, 2), (5, 4), (2, 3, 4), (4, 3, 3, 4),
        (2, 2, 2, 2), (2, 2, 2, 2, 2), (6, 6), (3, 3, 2),
    ])
    def test_factorized_equals_direct(self, dims):
        assert check_correct(dims)

    @pytest.mark.parametrize("order", [(0, 1, 2), (2, 1, 0), (1, 0, 2)])
    def test_round_orders_commute(self, order):
        # deterministic pin; full permutation sweep in test_core_properties
        assert check_correct((2, 3, 4), order)


class TestTheorem1:
    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4), (4, 3, 3, 4),
                                      (2, 2, 2, 2)])
    def test_volume_formula(self, dims):
        _, vol = simulate_factorized_alltoall(dims)
        d, p = len(dims), math.prod(dims)
        assert vol.total_blocks_sent == vol.theorem1_formula
        assert vol.theorem1_formula == d * p - sum(p // Dk for Dk in dims)
        # per-round count: (D[k]-1) * p / D[k]
        for k, Dk in enumerate(dims):
            assert vol.blocks_sent_per_round[k] == (Dk - 1) * (p // Dk)

    def test_hypercube_case(self):
        # p = 2^d: log2(p) rounds, each sending p/2 blocks (hypercube algo).
        _, vol = simulate_factorized_alltoall((2, 2, 2, 2))
        assert all(n == 8 for n in vol.blocks_sent_per_round)
        assert vol.total_blocks_sent == 4 * 16 - 4 * 8 == 32

    def test_datatype_partition_property(self):
        # Each round's instances partition all p block offsets.
        for dims in [(5, 4), (2, 3, 4), (4, 3, 3, 4)]:
            p = math.prod(dims)
            for k in range(len(dims)):
                pos, extent = round_datatype(dims, k)
                all_offsets = sorted(q + j * extent
                                     for j in range(dims[k]) for q in pos)
                assert all_offsets == list(range(p))
