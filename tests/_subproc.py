"""Helper: run a multi-device validation script in a subprocess.

Collective tests need N > 1 devices; the test session itself must keep the
default single CPU device (per project policy XLA_FLAGS is only set in
subprocesses / dryrun).  Scripts live in ``tests/device_scripts`` and are
plain python programs that exit nonzero on failure.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SCRIPTS = Path(__file__).parent / "device_scripts"
REPO_SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_device_script(name: str, devices: int, *args: str,
                      timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(SCRIPTS / name), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"{name} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout
