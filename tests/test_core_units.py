"""Unit tests: dims_create, tuning model, guidelines checker, HLO parser,
descriptor cache.  (Property tests: test_core_properties.py, behind
``pytest.importorskip("hypothesis")``.)"""

import math

import pytest

from repro.core.cache import TorusFactorization, cache_stats, free, \
    get_factorization
from repro.core.dims import dims_create, max_dims, prime_factorization
from repro.core.guidelines import Measurement, check_guidelines
from repro.core.hlo_inspect import parse_hlo, shape_bytes
from repro.core.tuning import (DCN, ICI, choose_algorithm,
                               candidate_factorizations,
                               crossover_block_bytes, predict_direct,
                               predict_factorized)


class TestDimsCreate:
    def test_paper_table1(self):
        # Table 1: the spec-conforming factorizations of p = 36*32 = 1152.
        assert dims_create(1152, 2) == (36, 32)
        assert dims_create(1152, 3) == (12, 12, 8)
        assert dims_create(1152, 4) == (8, 6, 6, 4)
        # The paper's d = "ceil(log2 p)" row lists the 9-factor prime
        # factorization 3x3x2^7:
        assert dims_create(1152, 9) == (3, 3, 2, 2, 2, 2, 2, 2, 2)
        assert max_dims(1152) == 11  # ceil(log2 1152); extra dims pad with 1
        assert dims_create(1152, 11) == (3, 3, 2, 2, 2, 2, 2, 2, 2, 1, 1)

    def test_openmpi_violation_not_reproduced(self):
        # The OpenMPI bug: 48x24. Correct per spec: 36x32.
        assert dims_create(1152, 2) != (48, 24)

    def test_powers_of_two(self):
        assert dims_create(512, 2) == (32, 16)
        assert dims_create(512, 3) == (8, 8, 8)
        assert dims_create(256, 2) == (16, 16)
        assert prime_factorization(512) == [2] * 9


class TestTuning:
    def test_small_blocks_prefer_factorized(self):
        # Paper §5: d=2,3 beats direct for <=100 ints on a uniform network.
        s = choose_algorithm((16, 16), (ICI, ICI), block_bytes=4)
        assert s.kind == "factorized"

    def test_large_blocks_prefer_direct(self):
        s = choose_algorithm((16, 16), (ICI, ICI), block_bytes=1 << 20)
        assert s.kind == "direct"

    def test_crossover_is_monotone(self):
        c = crossover_block_bytes((16, 16), (ICI, ICI))
        assert 4 < c < (1 << 22)
        small = choose_algorithm((16, 16), (ICI, ICI), c // 2)
        big = choose_algorithm((16, 16), (ICI, ICI), c * 2)
        assert small.kind == "factorized" and big.kind == "direct"

    def test_dcn_axis_ordering_matters(self):
        # With a slow pod axis, factorized should beat a direct collective
        # bounded by the DCN link for medium messages.
        t_f = predict_factorized((16, 2), (ICI, DCN), 1024, 32)
        t_d = predict_direct(32, 1024, DCN)
        assert t_f < t_d

    def test_candidates_cover_paper_sweep(self):
        cands = candidate_factorizations(1152)
        assert (36, 32) in cands and (12, 12, 8) in cands \
            and (8, 6, 6, 4) in cands


class TestGuidelines:
    def test_detects_violation(self):
        ms = [Measurement("direct", 100, 10e-6),
              Measurement("factorized[d=2]", 100, 1e-6),
              Measurement("direct", 10000, 1e-6),
              Measurement("factorized[d=2]", 10000, 5e-6)]
        v = check_guidelines(ms)
        assert len(v) == 1 and v[0].block_elems == 100
        assert v[0].factor == pytest.approx(10.0)

    def test_tolerance(self):
        ms = [Measurement("direct", 1, 1.05e-6),
              Measurement("factorized[d=2]", 1, 1.00e-6)]
        assert check_guidelines(ms, tolerance=1.10) == []


HLO_SAMPLE = """
HloModule test
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ata = f32[16,128]{1,0} all-to-all(%p0), replica_groups={{0,1}}
  %t = f32[128,16]{1,0} transpose(%ata), dimensions={1,0}
  %cp = f32[128,16]{1,0} copy(%t)
  %t2 = f32[16,128]{1,0} transpose(%cp), dimensions={1,0}
  ROOT %ar = f32[16,128]{1,0} all-reduce(%t2), to_apply=%add
}
"""


class TestHloInspect:
    def test_shape_bytes(self):
        assert shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
        assert shape_bytes("bf16[2,3]") == 12
        assert shape_bytes("(f32[4], u32[2])") == 24
        assert shape_bytes("f32[]") == 4

    def test_parse_and_account(self):
        rep = parse_hlo(HLO_SAMPLE)
        kinds = rep.op_counts
        assert kinds["all-to-all"] == 1 and kinds["all-reduce"] == 1
        assert kinds["transpose"] == 2 and kinds["copy"] == 1
        assert rep.collective_bytes() == 2 * 16 * 128 * 4
        mv = rep.movement_ops_between_collectives()
        assert {o.kind for o in mv} == {"transpose", "copy"}


class TestCache:
    def test_descriptor_and_theorem1(self):
        t = TorusFactorization(("a", "b"), (4, 8))
        assert t.p == 32 and t.d == 2 and t.sigma == (1, 4)
        assert t.blocks_sent_per_device() == 2 * 32 - (8 + 4)

    def test_caching_amortizes(self):
        import jax
        from jax.sharding import Mesh
        import numpy as np
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
        before = cache_stats()["cart_creates"]
        f1 = get_factorization(mesh, ("y", "x"))
        f2 = get_factorization(mesh, ("y", "x"))
        assert f1 is f2
        assert cache_stats()["cart_creates"] == before + 1
        free(f1)
        f3 = get_factorization(mesh, ("y", "x"))
        assert cache_stats()["cart_creates"] == before + 2
        assert f3 == f1

    def test_cache_survives_mesh_rebuild(self):
        # The fingerprint must be stable device identity (device.id,
        # platform), not object identity: re-looking up through a freshly
        # constructed Mesh over the same devices must hit the cache.
        import jax
        from jax.sharding import Mesh
        import numpy as np
        arr = np.array(jax.devices()[:1]).reshape(1, 1)
        m1 = Mesh(arr.copy(), ("u", "v"))
        before = cache_stats()["cart_creates"]
        f1 = get_factorization(m1, ("v", "u"))
        m2 = Mesh(arr.copy(), ("u", "v"))   # new Mesh, same devices
        f2 = get_factorization(m2, ("v", "u"))
        assert f1 is f2
        assert cache_stats()["cart_creates"] == before + 1
        free(f1)
