"""Unit tests for the telemetry spine (core.telemetry) and its hooks.

Multi-device span coverage (one span per dimension-wise round on real
d=2/d=3 tori, drift under injected faults, Perfetto export) runs in
``tests/device_scripts/check_telemetry.py``; here we cover the
single-device contracts: span nesting and the ring-buffer bound, the
Chrome-trace export schema, the metrics registry and provider merge,
DriftDetector behavior on both sides of the threshold, the watchdog
integration (events_dropped, drift -> retune), and the documented
<5% disabled-tracer overhead on a tight plan-execute loop.
"""

import json
import time
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import telemetry
from repro.core.cache import cart_create, free_all
from repro.core.plan import free_plans, plan_all_to_all
from repro.core.telemetry import (
    DriftDetector,
    MetricsRegistry,
    Tracer,
    disable_tracing,
    drift_detector,
    enable_tracing,
    get_tracer,
    metrics,
    metrics_snapshot,
    reset_telemetry,
)
from repro.runtime.watchdog import EscalationPolicy, StragglerWatchdog


@pytest.fixture(autouse=True)
def _fresh_telemetry():
    """Every test starts with a disabled tracer, empty metrics and an
    empty drift table, and leaves the singletons the way it found them."""
    reset_telemetry()
    yield
    reset_telemetry()
    free_plans()
    free_all()


# ---------------------------------------------------------------------------
# Tracer: spans, nesting, ring buffer, disabled path
# ---------------------------------------------------------------------------


class TestTracer:
    def test_disabled_span_is_noop(self):
        tr = Tracer()
        assert not tr.enabled
        with tr.span("anything", foo=1) as sp:
            sp.set(bar=2)       # must not raise on the null span
        assert tr.spans() == []
        assert tr.stats() == {"enabled": False, "spans": 0,
                              "capacity": 4096, "dropped": 0}

    def test_span_records_name_duration_attrs(self):
        tr = Tracer(enabled=True)
        with tr.span("work", cat="test", k=3) as sp:
            time.sleep(0.005)
            sp.set(extra="v")
        (s,) = tr.spans()
        assert s.name == "work"
        assert s.duration >= 0.004
        assert s.attrs["cat"] == "test" and s.attrs["k"] == 3
        assert s.attrs["extra"] == "v"
        assert s.parent_id is None

    def test_nesting_parent_ids(self):
        tr = Tracer(enabled=True)
        with tr.span("outer"):
            with tr.span("mid"):
                with tr.span("inner"):
                    pass
            with tr.span("mid2"):
                pass
        by_name = {s.name: s for s in tr.spans()}
        assert set(by_name) == {"outer", "mid", "inner", "mid2"}
        outer = by_name["outer"]
        assert by_name["mid"].parent_id == outer.span_id
        assert by_name["mid2"].parent_id == outer.span_id
        assert by_name["inner"].parent_id == by_name["mid"].span_id
        # children complete (and record) before the parent
        names = [s.name for s in tr.spans()]
        assert names.index("inner") < names.index("outer")

    def test_exception_tagged_and_reraised(self):
        tr = Tracer(enabled=True)
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        (s,) = tr.spans()
        assert s.attrs["exception"] == "ValueError"

    def test_ring_buffer_bound_and_dropped(self):
        tr = Tracer(capacity=4, enabled=True)
        for i in range(10):
            with tr.span(f"s{i}"):
                pass
        spans = tr.spans()
        assert len(spans) == 4
        assert [s.name for s in spans] == ["s6", "s7", "s8", "s9"]
        assert tr.dropped == 6
        tr.clear()
        assert tr.spans() == [] and tr.dropped == 0

    def test_enable_disable_singleton(self):
        tr = enable_tracing(capacity=16)
        assert tr is get_tracer() and tr.enabled
        assert tr.capacity == 16
        disable_tracing()
        assert not get_tracer().enabled


# ---------------------------------------------------------------------------
# Chrome-trace export: golden schema
# ---------------------------------------------------------------------------


class TestChromeTraceExport:
    def test_schema(self, tmp_path):
        tr = Tracer(enabled=True)
        with tr.span("plan.execute", cat="plan", backend="factorized"):
            with tr.span("plan.round", cat="plan", axis="x", round=0):
                pass
        path = tmp_path / "trace.json"
        doc = tr.export_chrome_trace(path)
        # the written file is valid JSON and identical to the return
        assert json.loads(path.read_text()) == doc
        assert doc["displayTimeUnit"] == "ms"
        assert doc["otherData"]["exporter"] == "repro.core.telemetry"
        assert doc["otherData"]["dropped_spans"] == 0
        assert len(doc["traceEvents"]) == 2
        for ev in doc["traceEvents"]:
            assert set(ev) == {"name", "ph", "ts", "dur", "pid", "tid",
                               "cat", "args"}
            assert ev["ph"] == "X"
            assert ev["pid"] == 1
            assert isinstance(ev["ts"], float) and ev["ts"] >= 0.0
            assert isinstance(ev["dur"], float) and ev["dur"] >= 0.0
            assert isinstance(ev["args"], dict)
            assert "span_id" in ev["args"]
        by_name = {ev["name"]: ev for ev in doc["traceEvents"]}
        assert by_name["plan.round"]["args"]["parent_id"] \
            == by_name["plan.execute"]["args"]["span_id"]
        assert by_name["plan.round"]["cat"] == "plan"

    def test_non_json_attrs_filtered(self):
        tr = Tracer(enabled=True)
        with tr.span("s", ok=1, bad=object(), also_ok="x"):
            pass
        (ev,) = tr.export_chrome_trace()["traceEvents"]
        assert ev["args"]["ok"] == 1 and ev["args"]["also_ok"] == "x"
        assert "bad" not in ev["args"]
        json.dumps(ev)      # the whole event is serializable


# ---------------------------------------------------------------------------
# Metrics registry + provider merge
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        reg.counter("a.count").inc()
        reg.counter("a.count").inc(2)
        reg.gauge("a.gauge").set(7)
        h = reg.histogram("a.hist")
        h.observe(1.0)
        h.observe(3.0)
        snap = reg.snapshot()
        assert snap["a.count"] == 3
        assert snap["a.gauge"] == 7
        assert snap["a.hist"]["count"] == 2
        assert snap["a.hist"]["mean"] == 2.0
        assert snap["a.hist"]["min"] == 1.0 and snap["a.hist"]["max"] == 3.0

    def test_type_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_provider_merge_namespaced(self):
        telemetry.register_stats_provider("tns", lambda: {
            "flat": 1, "nested": {"a": 2}})
        metrics().counter("tns.live").inc(5)
        snap = metrics_snapshot()
        assert snap["tns.flat"] == 1
        assert snap["tns.nested.a"] == 2
        assert snap["tns.live"] == 5
        # the built-in providers registered at import time are merged too
        assert any(k.startswith("plan_cache.") for k in snap)
        assert any(k.startswith("factorization.") for k in snap)
        assert any(k.startswith("comms.") for k in snap)
        del telemetry._PROVIDERS["tns"]

    def test_crashing_provider_contained(self):
        def boom():
            raise RuntimeError("nope")
        telemetry.register_stats_provider("bad", boom)
        snap = metrics_snapshot()
        assert "RuntimeError" in snap["bad.error"]
        del telemetry._PROVIDERS["bad"]


# ---------------------------------------------------------------------------
# DriftDetector: both sides of the threshold
# ---------------------------------------------------------------------------


class TestDriftDetector:
    def test_below_threshold_no_recommendation(self):
        det = DriftDetector(threshold=1.5, min_samples=3)
        for _ in range(5):
            det.observe("k", 0.010, 0.012)      # ratio 1.2 < 1.5
        assert det.drift_ratio("k") == pytest.approx(1.2)
        assert not det.drifted("k")
        assert det.recommendations() == []
        assert det.summary()["k"]["drifted"] is False

    def test_above_threshold_recommends_once(self):
        det = DriftDetector(threshold=1.5, min_samples=3)
        for _ in range(5):
            det.observe("k", 0.010, 0.030)      # ratio 3.0 > 1.5
        assert det.drift_ratio("k") == pytest.approx(3.0)
        assert det.drifted("k")
        recs = det.recommendations()
        assert len(recs) == 1
        assert recs[0]["key"] == "k"
        assert recs[0]["action"] == "retune"
        assert recs[0]["ratio"] == pytest.approx(3.0)
        # one-shot per episode: the condition persisting does not re-fire
        assert det.recommendations() == []

    def test_recovery_rearms(self):
        det = DriftDetector(threshold=1.5, window=4, min_samples=3)
        for _ in range(4):
            det.observe("k", 0.010, 0.030)
        assert len(det.recommendations()) == 1
        for _ in range(4):                      # window flushes: healthy
            det.observe("k", 0.010, 0.010)
        assert det.recommendations() == []      # re-armed, not drifted
        for _ in range(4):                      # drifts again -> re-fires
            det.observe("k", 0.010, 0.030)
        assert len(det.recommendations()) == 1

    def test_min_samples_and_bad_prediction_guards(self):
        det = DriftDetector(min_samples=3)
        assert det.observe("k", 0.0, 1.0) is None       # unfitted model
        assert det.observe("k", -1.0, 1.0) is None
        det.observe("k", 0.01, 0.02)
        assert det.drift_ratio("k") is None             # < min_samples
        with pytest.raises(ValueError):
            DriftDetector(threshold=1.0)


# ---------------------------------------------------------------------------
# Watchdog integration: events_dropped + drift -> retune
# ---------------------------------------------------------------------------


class TestWatchdogTelemetry:
    def test_events_dropped_counter_and_warning(self):
        wd = StragglerWatchdog(max_events=3)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            for i in range(6):
                wd._record(("straggler", i, 1.0, 0.1))
        assert wd.events_dropped == 3
        assert len(wd.events) == 3
        assert metrics().snapshot()["watchdog.events_dropped"] == 3
        msgs = [str(w.message) for w in caught
                if "watchdog event window" in str(w.message)]
        assert len(msgs) == 1               # one-time, names the window
        assert "max_events=3" in msgs[0]

    def test_drift_verdict_routes_to_retune(self):
        pol = EscalationPolicy()
        act = pol.decide("drift")
        assert act.kind == "retune"
        # advisory: no incident opened, budgets untouched
        assert pol.retries == 0 and pol.recoveries == 0
        assert pol._incident_start is None
        assert pol.transitions[-1] == ("drift", "retune")

    def test_check_drift_end_to_end(self):
        det = drift_detector()
        for _ in range(5):
            det.observe("dense[x](4,):factorized:64", 0.001, 0.010)
        wd = StragglerWatchdog()
        out = wd.check_drift(step=12)
        assert len(out) == 1
        key, action = out[0]
        assert key == "dense[x](4,):factorized:64"
        assert action.kind == "retune"
        assert wd.last_verdict == "drift"
        assert any(ev[0] == "drift" for ev in wd.events)
        # one-shot: the persisting episode does not re-recommend
        assert wd.check_drift(step=13) == []
        assert metrics().snapshot()["drift.retune_recommendations"] == 1


# ---------------------------------------------------------------------------
# The overhead contract: disabled tracer within 5% on a tight loop
# ---------------------------------------------------------------------------


class TestDisabledOverhead:
    def test_plan_execute_overhead_under_5pct(self):
        mesh = cart_create(1, (1,), ("x",))
        plan = plan_all_to_all(mesh, ("x",), backend="factorized",
                               block_shape=(8,), dtype=jnp.float32)
        x = jnp.arange(8, dtype=jnp.float32).reshape(1, 1, 8)
        wrapped = plan.host_fn(mesh)          # the telemetry-aware wrapper
        raw = plan._host_fns[mesh]            # the bare fused jit
        jax.block_until_ready(wrapped(x))
        jax.block_until_ready(raw(x))
        assert not get_tracer().enabled

        def timed(fn, n=400):
            t0 = time.perf_counter()
            for _ in range(n):
                fn(x)
            jax.block_until_ready(fn(x))
            return time.perf_counter() - t0

        # Interleave the raw/wrapped rounds and take each side's best:
        # a load spike (e.g. the rest of the suite running) hits both
        # paths alike instead of skewing whichever block it lands in.
        t_raw = t_wrapped = float("inf")
        for _ in range(7):
            t_raw = min(t_raw, timed(raw))
            t_wrapped = min(t_wrapped, timed(wrapped))
        overhead = t_wrapped / t_raw - 1.0
        assert overhead < 0.05, \
            f"disabled-tracer overhead {overhead:.1%} >= 5% " \
            f"(raw {t_raw:.4f}s, wrapped {t_wrapped:.4f}s)"
        # and the loop really stayed on the fused path: nothing recorded
        assert get_tracer().spans() == []
