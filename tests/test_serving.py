"""Continuous batching: per-request outputs must match isolated serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.runtime.serving import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


def _model(window=None):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      window=window, param_dtype="float32",
                      compute_dtype="float32", remat=False)
    model = build_model(cfg)
    return model, model.init(KEY)


def _serve_alone(model, params, prompt, max_new, max_seq=48):
    caches = model.init_caches(1, max_seq)
    toks = list(prompt)
    out = []
    nxt = None
    for t in toks:
        logits, caches = model.decode_step(
            params, jnp.asarray([[t]], jnp.int32), caches)
        nxt = int(jnp.argmax(logits[0, -1]))
    out.append(nxt)
    for _ in range(max_new - 1):
        logits, caches = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), caches)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


@pytest.mark.parametrize("window", [None, 6])
def test_continuous_matches_isolated(window):
    model, params = _model(window)
    prompts = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20], [30, 31, 32],
               [40, 41], [50]]
    max_news = [4, 6, 3, 5, 4, 2, 6]

    batcher = ContinuousBatcher(model, params, max_batch=3, max_seq=48)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        batcher.submit(Request(i, p, m))
    done = batcher.run()
    assert set(done) == set(range(len(prompts)))

    for i, (p, m) in enumerate(zip(prompts, max_news)):
        ref = _serve_alone(model, params, p, m)
        assert done[i] == ref, (i, done[i], ref)

    # continuous batching actually overlapped requests: total ticks must
    # be far below the sum of isolated ticks
    seq_ticks = sum(len(p) + m - 1 for p, m in zip(prompts, max_news))
    assert batcher.ticks < seq_ticks


def test_eos_early_stop():
    model, params = _model()
    ref = _serve_alone(model, params, [1, 2], 8)
    # pick a token the greedy rollout emits before max_new: the batcher
    # must truncate exactly at its first occurrence (position depends on
    # the random init, so derive it from ref rather than hardcoding)
    eos = ref[2]
    stop = ref.index(eos)
    b = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
    b.submit(Request(0, [1, 2], 8, eos_id=eos))
    done = b.run()
    assert done[0] == ref[:stop + 1]
    assert done[0][-1] == eos and len(done[0]) < 8
