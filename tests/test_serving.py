"""Continuous batching: per-request outputs must match isolated serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.runtime.serving import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


def _model(window=None):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      window=window, param_dtype="float32",
                      compute_dtype="float32", remat=False)
    model = build_model(cfg)
    return model, model.init(KEY)


def _serve_alone(model, params, prompt, max_new, max_seq=48):
    caches = model.init_caches(1, max_seq)
    toks = list(prompt)
    out = []
    nxt = None
    for t in toks:
        logits, caches = model.decode_step(
            params, jnp.asarray([[t]], jnp.int32), caches)
        nxt = int(jnp.argmax(logits[0, -1]))
    out.append(nxt)
    for _ in range(max_new - 1):
        logits, caches = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), caches)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


@pytest.mark.parametrize("window", [None, 6])
def test_continuous_matches_isolated(window):
    model, params = _model(window)
    prompts = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20], [30, 31, 32],
               [40, 41], [50]]
    max_news = [4, 6, 3, 5, 4, 2, 6]

    batcher = ContinuousBatcher(model, params, max_batch=3, max_seq=48)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        batcher.submit(Request(i, p, m))
    done = batcher.run()
    assert set(done) == set(range(len(prompts)))

    for i, (p, m) in enumerate(zip(prompts, max_news)):
        ref = _serve_alone(model, params, p, m)
        assert done[i] == ref, (i, done[i], ref)

    # continuous batching actually overlapped requests: total ticks must
    # be far below the sum of isolated ticks
    seq_ticks = sum(len(p) + m - 1 for p, m in zip(prompts, max_news))
    assert batcher.ticks < seq_ticks


def test_eos_early_stop():
    model, params = _model()
    ref = _serve_alone(model, params, [1, 2], 8)
    # pick a token the greedy rollout emits before max_new: the batcher
    # must truncate exactly at its first occurrence (position depends on
    # the random init, so derive it from ref rather than hardcoding)
    eos = ref[2]
    stop = ref.index(eos)
    b = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
    b.submit(Request(0, [1, 2], 8, eos_id=eos))
    done = b.run()
    assert done[0] == ref[:stop + 1]
    assert done[0][-1] == eos and len(done[0]) < 8


# ---------------------------------------------------------------------------
# Elastic replay: token folding must be idempotent across requeues
# ---------------------------------------------------------------------------


def test_requeue_inflight_folds_generated_once():
    model, params = _model()
    prompt, max_new = [1, 2, 3], 6
    ref = _serve_alone(model, params, prompt, max_new)
    b = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
    b.submit(Request(0, list(prompt), max_new))
    for _ in range(len(prompt) + 2):        # prefill + 3 generated tokens
        b.step()
    req = next(s for s in b.slots if s is not None)
    g = list(req.generated)
    assert len(g) == 3

    assert b.requeue_inflight() == 1
    assert b.queue[0].prompt == prompt + g
    assert b.queue[0].folded == len(g)
    # replay one tick (re-admits, mid-prefill), then requeue again:
    # the already-folded tokens must NOT fold a second time
    b.step()
    assert b.requeue_inflight() == 1
    assert b.queue[0].prompt == prompt + g
    assert b.queue[0].folded == len(g)
    # and the replay still lands on the exact reference output
    done = b.run()
    assert done[0] == ref


# ---------------------------------------------------------------------------
# Multi-tenant admission
# ---------------------------------------------------------------------------


def test_admission_round_robin_fifo_and_quota():
    from repro.runtime.serving import AdmissionController

    a = AdmissionController(quotas={"A": 2})
    for i in range(4):
        a.submit(Request(i, [1], 1, tenant="A"))
    for i in range(3):
        a.submit(Request(10 + i, [1], 1, tenant="B"))
    # round-robin across tenants, FIFO within each
    assert [r.rid for r in a.admit(4)] == [0, 10, 1, 11]
    # tenant A is now at quota: only B drains
    assert [r.rid for r in a.admit(4)] == [12]
    # releasing one A slot re-opens exactly one admission
    a.release(Request(0, [1], 1, tenant="A"))
    assert [r.rid for r in a.admit(4)] == [2]
    assert a.pending == 1
    # requeued work precedes anything already queued in its tenant
    a.requeue_front([Request(99, [1], 1, tenant="A")])
    assert [r.rid for r in a.queues["A"]] == [99, 3]


def test_tenant_fairness_under_full_decode_batch():
    """With the decode batch saturated, admission stops (backpressure);
    as slots free up, tenants drain round-robin under their quotas —
    one tenant's backlog can never starve the other."""
    from repro.core import torus_comm
    from repro.runtime.serving import DisaggregatedServer

    model, params = _model()
    comm = torus_comm((2, 2), ("x", "y"))
    srv = DisaggregatedServer(model, params, comm, max_seq=48,
                              decode_batch=2, prefill_batch=2,
                              n_prefill=2, default_quota=1)
    for i in range(3):
        srv.submit(Request(i, [1 + i, 2 + i], 3, tenant="A"))
        srv.submit(Request(10 + i, [5 + i], 3, tenant="B"))
    order = []
    while srv.tick():
        # per-tenant quota holds at every tick
        assert all(v <= 1 for v in srv.admission.inflight.values())
        # decode-slot backpressure: everything in flight past admission
        # fits the decode batch
        assert (srv.batcher.pending + len(srv.staged)
                + sum(w.active for w in srv.workers)) <= 2
        for rid in srv.done:
            if rid not in order:
                order.append(rid)
    assert len(srv.done) == 6
    # fairness: completions interleave A and B (never one tenant's whole
    # backlog first)
    first_three = order[:3]
    assert any(r < 10 for r in first_three) \
        and any(r >= 10 for r in first_three)
    for i in range(3):
        assert srv.done[i] == _serve_alone(model, params,
                                           [1 + i, 2 + i], 3)
        assert srv.done[10 + i] == _serve_alone(model, params, [5 + i], 3)
    comm.free()


# ---------------------------------------------------------------------------
# Disaggregated == colocated (host exact path; device path in
# tests/device_scripts/check_serving.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 6])
def test_disaggregated_matches_colocated(window):
    from repro.core import torus_comm
    from repro.runtime.serving import DisaggregatedServer

    model, params = _model(window)
    prompts = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20], [30, 31, 32]]
    max_news = [4, 6, 3, 5, 4]

    b = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        b.submit(Request(i, list(p), m))
    ref = b.run()

    comm = torus_comm((2, 2), ("x", "y"))
    srv = DisaggregatedServer(model, params, comm, max_seq=48,
                              decode_batch=2)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        srv.submit(Request(i, list(p), m, tenant=f"t{i % 2}"))
    done = srv.run()
    assert done == ref
    topo = srv.topology
    assert topo.migrations > 0 and topo.migrated_rows > 0
    assert srv.stats()["topology"]["plan"]["kind"] == "kv_migrate"
    comm.free()


def test_disaggregated_rebuild_drops_nothing():
    from repro.core import torus_comm
    from repro.runtime.serving import DisaggregatedServer

    model, params = _model()
    prompts = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20], [30, 31, 32],
               [40, 41]]
    max_news = [4, 6, 3, 5, 4, 5]

    b = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        b.submit(Request(i, list(p), m))
    ref = b.run()

    comm = torus_comm((2, 3), ("x", "y"))
    srv = DisaggregatedServer(model, params, comm, max_seq=48,
                              decode_batch=2)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        srv.submit(Request(i, list(p), m))
    for _ in range(6):                       # mid-stream: work in flight
        srv.tick()
    n = srv.rebuild(4)                       # lose two ranks
    assert n > 0                             # something really was in flight
    done = srv.run()
    assert set(done) == set(range(len(prompts)))
    assert done == ref                       # zero dropped, outputs unchanged
    srv.topology.comm.free()


# ---------------------------------------------------------------------------
# stats() surfaces the unified comm picture
# ---------------------------------------------------------------------------


def test_batcher_stats_surface_a2a_comm_stats():
    from repro.core import torus_comm

    model, params = _model()
    b = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
    b.submit(Request(0, [1, 2], 2))
    b.run()
    st = b.stats()
    assert st["done"] == 1 and st["ticks"] == b.ticks
    assert "plans" in st["a2a_comm_stats"]   # registry-wide picture

    comm = torus_comm((1, 2), ("x", "y"))
    bc = ContinuousBatcher(model, params, max_batch=2, max_seq=48,
                           comm=comm)
    st2 = bc.stats()
    # comm-rooted batcher scopes the stats to its comm
    assert st2["a2a_comm_stats"]["comm"]["axes"] == ["x", "y"]
    comm.free()
