"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property import given, settings, st

from repro.core.simulator import round_datatype
from repro.kernels.block_reorder import datatype_pack, datatype_unpack
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.ref import (ref_attention, ref_block_reorder, ref_gmm)

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,S,Dh", [
        (1, 2, 2, 64, 32), (2, 4, 2, 32, 16), (1, 4, 1, 64, 32),
        (1, 8, 8, 128, 64), (2, 6, 3, 48, 64),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, B, Hq, Hkv, S, Dh, causal):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, S, Dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, Dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, Dh), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=16,
                              block_k=16, interpret=True)
        ref = ref_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    @pytest.mark.parametrize("window", [1, 8, 16, 64])
    def test_sliding_window(self, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 64, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16, interpret=True)
        ref = ref_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 4, 32, 32)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 2, 32, 32)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 2, 32, 32)).astype(dtype)
        out = flash_attention(q, k, v, block_q=16, block_k=16,
                              interpret=True)
        ref = ref_attention(q, k, v)
        assert out.dtype == dtype
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   ref.astype(jnp.float32), **_tol(dtype))

    def test_kv_offset_decode(self):
        # One new query against a longer KV prefix (decode step semantics).
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 8, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
        out = flash_attention(q, k, v, causal=True, kv_offset=56,
                              block_q=8, block_k=16, interpret=True)
        ref = ref_attention(q, k, v, causal=True, kv_offset=56)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    @given(st.sampled_from([16, 32, 48, 64]), st.sampled_from([8, 16, 32]),
           st.booleans())
    @settings(max_examples=10, deadline=None)
    def test_block_size_invariance(self, S, blk, causal):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, S, 16), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, S, 16), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, S, 16), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=blk,
                              block_k=blk, interpret=True)
        ref = ref_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))


class TestFlashAttentionBackward:
    @pytest.mark.parametrize("B,Hq,Hkv,S,Dh,causal,window", [
        (1, 2, 2, 32, 16, True, None),
        (2, 4, 2, 32, 16, True, None),
        (1, 4, 1, 32, 16, False, None),
        (1, 2, 2, 48, 16, True, 8),
        (1, 8, 2, 64, 32, True, None),
    ])
    def test_grads_match_autodiff(self, B, Hq, Hkv, S, Dh, causal, window):
        from repro.kernels.flash_attention_bwd import (
            flash_attention_fwd, flash_attention_trainable)
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (B, Hq, S, Dh))
        k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
        v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
        dout = jax.random.normal(ks[3], (B, Hq, S, Dh))

        out, lse = flash_attention_fwd(q, k, v, causal=causal,
                                       window=window, block_q=16,
                                       block_k=16, interpret=True)
        np.testing.assert_allclose(
            out, ref_attention(q, k, v, causal=causal, window=window),
            rtol=2e-5, atol=2e-5)

        def f_ref(q, k, v):
            return jnp.sum(ref_attention(q, k, v, causal=causal,
                                         window=window) * dout)

        def f_pal(q, k, v):
            return jnp.sum(flash_attention_trainable(
                q, k, v, causal=causal, window=window, block_q=16,
                block_k=16, interpret=True) * dout)

        g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        g_pal = jax.grad(f_pal, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g_pal, g_ref):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


class TestBlockReorder:
    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4), (4, 3, 3, 4),
                                      (2, 2, 2, 2), (6,), (3, 2)])
    def test_pack_matches_datatype(self, dims):
        p = math.prod(dims)
        x = jnp.arange(p * 5, dtype=jnp.float32).reshape(p, 5)
        for k in range(len(dims)):
            pos, extent = round_datatype(dims, k)
            ref = ref_block_reorder(x, pos, extent, dims[k])
            got = datatype_pack(x, dims=dims, k=k, interpret=True)
            np.testing.assert_array_equal(got, ref)

    @pytest.mark.parametrize("dims", [(5, 4), (2, 3, 4), (4, 3, 3, 4)])
    def test_unpack_inverts_pack(self, dims):
        p = math.prod(dims)
        x = jax.random.normal(KEY, (p, 9))
        for k in range(len(dims)):
            y = datatype_pack(x, dims=dims, k=k, interpret=True)
            back = datatype_unpack(y, dims=dims, k=k, interpret=True)
            np.testing.assert_array_equal(back, x)

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.int32])
    def test_dtypes(self, dtype):
        dims = (2, 3, 4)
        p = math.prod(dims)
        x = jnp.arange(p * 4).reshape(p, 4).astype(dtype)
        y = datatype_pack(x, dims=dims, k=1, interpret=True)
        pos, extent = round_datatype(dims, 1)
        np.testing.assert_array_equal(
            y, ref_block_reorder(x, pos, extent, dims[1]))


class TestGroupedMatmul:
    @pytest.mark.parametrize("E,C,K,N", [
        (4, 16, 32, 24), (2, 128, 64, 128), (8, 8, 8, 8), (1, 256, 128, 64),
        (16, 4, 12, 20),
    ])
    def test_matches_einsum(self, E, C, K, N):
        a = jax.random.normal(KEY, (E, C, K), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(KEY, 1), (E, K, N),
                              jnp.float32)
        got = grouped_matmul(a, b, block_c=32, block_n=32, block_k=16,
                             interpret=True)
        np.testing.assert_allclose(got, ref_gmm(a, b), rtol=1e-5, atol=1e-5)

    def test_bf16(self):
        a = jax.random.normal(KEY, (2, 32, 32), jnp.float32).astype(jnp.bfloat16)
        b = jax.random.normal(KEY, (2, 32, 16), jnp.float32).astype(jnp.bfloat16)
        got = grouped_matmul(a, b, block_c=16, block_n=16, block_k=16,
                             interpret=True)
        np.testing.assert_allclose(
            got.astype(jnp.float32), ref_gmm(a, b).astype(jnp.float32),
            rtol=3e-2, atol=3e-2)

    @given(st.integers(1, 6), st.sampled_from([8, 16, 64]),
           st.sampled_from([8, 32]), st.sampled_from([8, 24]))
    @settings(max_examples=10, deadline=None)
    def test_property_shapes(self, E, C, K, N):
        a = jax.random.normal(KEY, (E, C, K), jnp.float32)
        b = jax.random.normal(KEY, (E, K, N), jnp.float32)
        got = grouped_matmul(a, b, block_c=8, block_n=8, block_k=8,
                             interpret=True)
        np.testing.assert_allclose(got, ref_gmm(a, b), rtol=1e-5, atol=1e-5)
