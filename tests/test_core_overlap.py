"""Device-free unit tests for the overlap engine (core.overlap), the
overlap-aware cost model (tuning.predict_overlapped / choose_chunks /
choose_algorithm), and the HLO interleave verifier.

Multi-device parity of overlap == factorized == direct runs in
``tests/device_scripts/check_overlap.py`` (see test_multidevice.py).
"""

import math

import pytest

from repro.core.hlo_inspect import interleave_report
from repro.core.overlap import pipeline_order, run_pipelined
from repro.core.tuning import (
    DCN,
    ICI,
    LinkModel,
    choose_algorithm,
    choose_chunks,
    predict_factorized,
    predict_overlapped,
)


class TestPipelineSchedule:
    def test_order_is_a_permutation_of_all_stage_instances(self):
        for n_chunks, n_stages in [(1, 1), (1, 4), (3, 1), (2, 5), (4, 3)]:
            got = list(pipeline_order(n_chunks, n_stages))
            assert sorted(got) == [(c, s) for c in range(n_chunks)
                                   for s in range(n_stages)]

    def test_chunk_stages_stay_ordered(self):
        # stage s of chunk c must precede stage s+1 of chunk c (data dep)
        got = list(pipeline_order(3, 4))
        for c in range(3):
            chunk_stages = [s for cc, s in got if cc == c]
            assert chunk_stages == sorted(chunk_stages)

    def test_steps_interleave_chunks(self):
        # 2 chunks, 5 stages (2 fwd rounds, compute, 2 rev rounds): chunk
        # 1's round-1 and chunk 0's reverse-round sit between the two
        # compute stages (indices 2 == compute).
        got = list(pipeline_order(2, 5))
        i_comp0 = got.index((0, 2))
        i_comp1 = got.index((1, 2))
        between = got[i_comp0 + 1:i_comp1]
        assert (1, 1) in between and (0, 3) in between

    def test_run_pipelined_equals_sequential(self):
        # Pure program-order transformation: the result must equal running
        # each chunk's stages back to back.
        stages = [lambda st, c, k=k: st + [(k, c)] for k in range(4)]
        states = [[("init", c)] for c in range(3)]
        got = run_pipelined(states, stages)
        want = [[("init", c)] + [(k, c) for k in range(4)] for c in range(3)]
        assert got == want

    def test_emission_log_is_pipelined(self):
        log = []

        def mk(k):
            def stage(st, c):
                log.append((c, k))
                return st
            return stage

        run_pipelined([0, 0], [mk(0), mk(1), mk(2)])
        assert log == list(pipeline_order(2, 3))


UNIFORM = LinkModel(alpha=1e-6, bandwidth=50e9)


class TestPredictOverlapped:
    def test_converges_to_factorized_at_one_chunk(self):
        for dims in [(4, 4), (2, 3, 4), (16, 2)]:
            links = (UNIFORM,) * len(dims)
            p = math.prod(dims)
            for b in (4.0, 1e3, 1e6):
                assert predict_overlapped(dims, links, b, p, 1) \
                    == pytest.approx(predict_factorized(dims, links, b, p))

    def test_latency_monotone_in_chunks(self):
        # zero payload isolates the alpha term: pipeline fill/drain makes
        # it strictly nondecreasing in n_chunks.
        dims, links = (4, 4, 4), (UNIFORM,) * 3
        p = math.prod(dims)
        ts = [predict_overlapped(dims, links, 0.0, p, n)
              for n in range(1, 9)]
        assert all(t1 >= t0 for t0, t1 in zip(ts, ts[1:]))
        assert ts[-1] > ts[0]

    def test_bandwidth_term_shrinks_with_overlap(self):
        # zero latency isolates the beta term: n chunks divide it by
        # min(d, n), saturating at d.
        dims = (4, 4, 4)
        links = (LinkModel(alpha=0.0, bandwidth=50e9),) * 3
        p, b = math.prod(dims), 1e6
        t1 = predict_overlapped(dims, links, b, p, 1)
        t3 = predict_overlapped(dims, links, b, p, 3)
        t8 = predict_overlapped(dims, links, b, p, 8)
        assert t3 == pytest.approx(t1 / 3)
        assert t8 == pytest.approx(t1 / 3)   # saturated at d=3

    def test_compute_hides_behind_communication(self):
        dims, links = (4, 4), (UNIFORM,) * 2
        p, b = 16, 1e6
        t_comm = predict_overlapped(dims, links, b, p, 4)
        small_compute = t_comm / 10
        t = predict_overlapped(dims, links, b, p, 4, small_compute)
        # hidden up to the 1/n fill fraction, far below serial comm+compute
        assert t < t_comm + small_compute
        assert t == pytest.approx(t_comm + small_compute / 4)

    def test_choose_chunks_agrees_with_model(self):
        for dims, links, b in [
            ((4, 4), (ICI, ICI), 4.0),
            ((4, 4), (ICI, ICI), 1 << 20),
            ((16, 2), (ICI, DCN), 1 << 14),
            ((2, 3, 4), (ICI, ICI, DCN), 1 << 18),
        ]:
            p = math.prod(dims)
            n = choose_chunks(dims, links, b, max_chunks=8)
            t_star = predict_overlapped(dims, links, b, p, n)
            for m in range(1, 9):
                assert t_star <= predict_overlapped(dims, links, b, p, m) \
                    + 1e-18

    def test_tiny_payload_prefers_no_chunking(self):
        assert choose_chunks((4, 4), (ICI, ICI), 4.0) == 1

    def test_large_payload_prefers_chunking(self):
        assert choose_chunks((4, 4), (ICI, ICI), float(1 << 22)) > 1


class TestChooseAlgorithmOverlap:
    def test_default_behavior_unchanged(self):
        s = choose_algorithm((16, 16), (ICI, ICI), 4.0)
        assert s.kind == "factorized" and s.n_chunks == 1

    def test_overlap_considered_with_max_chunks(self):
        # medium-large payload on a 2d torus: chunk-overlap beats plain
        # factorized (bandwidth / min(d, n)) and the direct collective
        # once the DCN axis makes direct expensive.
        s = choose_algorithm((16, 4), (ICI, DCN), float(1 << 16),
                             max_chunks=8)
        assert s.kind == "overlap" and s.n_chunks > 1
        # the schedule's prediction matches the model at its chunk count
        t = predict_overlapped(s.dims, s.links, float(1 << 16), 64,
                               s.n_chunks)
        assert s.predicted_seconds == pytest.approx(t)

    def test_overlap_never_selected_when_disabled(self):
        s = choose_algorithm((16, 4), (ICI, DCN), float(1 << 16))
        assert s.kind in ("direct", "factorized")


SEQUENTIAL_HLO = """
HloModule seq
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %a0 = f32[16,128]{1,0} all-to-all(%p0), replica_groups={{0,1}}
  %a1 = f32[16,128]{1,0} all-to-all(%a0), replica_groups={{0,2}}
  %d0 = f32[16,128]{1,0} dot(%a1, %a1), lhs_contracting_dims={1}
  %d1 = f32[16,128]{1,0} dot(%d0, %d0), lhs_contracting_dims={1}
  %a2 = f32[16,128]{1,0} all-to-all(%d1), replica_groups={{0,2}}
  ROOT %a3 = f32[16,128]{1,0} all-to-all(%a2), replica_groups={{0,1}}
}
"""

OVERLAPPED_HLO = """
HloModule ovl
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %a0 = f32[16,128]{1,0} all-to-all(%p0), replica_groups={{0,1}}
  %a1 = f32[16,128]{1,0} all-to-all(%a0), replica_groups={{0,2}}
  %a2 = f32[16,128]{1,0} all-to-all(%a1), replica_groups={{0,1}}
  %d0 = f32[16,128]{1,0} dot(%a2, %a2), lhs_contracting_dims={1}
  %a3 = f32[16,128]{1,0} all-to-all(%d0), replica_groups={{0,2}}
  %a4 = f32[16,128]{1,0} all-to-all(%a3), replica_groups={{0,2}}
  %d1 = f32[16,128]{1,0} dot(%a4, %a4), lhs_contracting_dims={1}
  %a5 = f32[16,128]{1,0} all-to-all(%d1), replica_groups={{0,1}}
  ROOT %a6 = f32[16,128]{1,0} all-to-all(%a5), replica_groups={{0,2}}
}
"""


class TestInterleaveReport:
    def test_sequential_program_has_two_collective_runs(self):
        rep = interleave_report(SEQUENTIAL_HLO)
        assert rep.collective_runs == 2
        assert rep.interleaved_collectives == 0

    def test_overlapped_program_interleaves(self):
        rep = interleave_report(OVERLAPPED_HLO)
        assert rep.collective_runs == 3
        assert rep.interleaved_collectives >= 2
        assert [r for r in rep.runs] == [("collective", 3), ("compute", 1),
                                         ("collective", 2), ("compute", 1),
                                         ("collective", 2)]

    def test_done_ops_and_other_collectives_filtered(self):
        text = """
HloModule t
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %s = f32[8] all-to-all-start(%p0), replica_groups={{0,1}}
  %e = f32[8] all-to-all-done(%s)
  %d = f32[8] dot(%e, %e), lhs_contracting_dims={0}
  %g = f32[8] all-gather(%d), replica_groups={{0,1}}
  ROOT %a = f32[8] all-to-all(%g), replica_groups={{0,1}}
}
"""
        rep = interleave_report(text)
        # -start counted once, -done skipped, all-gather excluded by the
        # default all-to-all filter
        assert [cls for cls, _ in rep.events] \
            == ["collective", "compute", "collective"]
        rep_all = interleave_report(text, collective_kind=None)
        assert [cls for cls, _ in rep_all.events] \
            == ["collective", "compute", "collective", "collective"]


class TestOverlapSingleDevice:
    def test_trivial_torus_applies_compute_stage(self):
        # p == 1 (all torus dims trivial): the engine degenerates to the
        # compute stage alone, chunked.  Runs through the A2APlan surface;
        # the legacy shim parity lives in test_core_plan.py /
        # device_scripts/check_plan.py.
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.plan import plan_all_to_all

        mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
        plan = plan_all_to_all(mesh, ("x",), (8,), "float32",
                               backend="overlap", n_chunks=2)

        def loc(xl):
            return plan.overlap(
                xl, lambda chunk, c: chunk * (c + 1.0), reverse=False)

        x = jnp.arange(8.0).reshape(1, 8)
        y = jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=P("x"),
                                  out_specs=P("x")))(x)
        want = np.concatenate([np.arange(4.0) * 1.0,
                               np.arange(4.0, 8.0) * 2.0]).reshape(1, 8)
        np.testing.assert_allclose(np.array(y), want)
