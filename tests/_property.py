"""Optional-hypothesis shim for modules mixing deterministic and property
tests.

``from _property import given, settings, st`` gives the real hypothesis
decorators when the package is installed (see requirements-dev.txt) and
skip-marking stand-ins otherwise, so deterministic tests in the same
module always collect and run.  Modules that are *entirely* property-based
use ``pytest.importorskip("hypothesis")`` instead (test_core_properties).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
