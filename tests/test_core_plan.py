"""Device-free unit tests for the A2APlan API (core.plan): resolution,
describe() golden dict, the LRU plan registry, the bounded factorization
cache, and the deprecation shims.

Multi-device bit-exactness of plan execution against the legacy free
functions runs in ``tests/device_scripts/check_plan.py`` (see
test_multidevice.py).
"""

import math
import warnings

import pytest

from repro.core import cache as core_cache
from repro.core import plan as core_plan
from repro.core.cache import (
    LRUCache,
    cache_stats,
    cart_create,
    free_all,
    get_factorization,
    set_cache_capacity,
)
from repro.core.plan import (
    A2APlan,
    free_plans,
    plan_all_to_all,
    plan_cache_stats,
    set_plan_cache_capacity,
)
from repro.core.tuning import DCN, ICI, choose_algorithm


@pytest.fixture(autouse=True)
def _fresh_registries():
    """Each test sees empty registries at default capacity and leaves the
    module state the way it found it."""
    free_plans()
    free_all()
    core_plan._PLANS.stats.update(hits=0, misses=0, evictions=0)
    core_cache._REGISTRY.stats.update(hits=0, misses=0, evictions=0)
    old_plan_cap = core_plan._PLANS.capacity
    old_fact_cap = core_cache._REGISTRY.capacity
    yield
    set_plan_cache_capacity(old_plan_cap)
    set_cache_capacity(old_fact_cap)
    free_plans()
    free_all()


class TestLRUCache:
    def test_eviction_order_and_stats(self):
        c = LRUCache(capacity=2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1          # refreshes "a"
        c.put("c", 3)                   # evicts LRU "b"
        assert c.get("b") is None
        assert c.get("a") == 1 and c.get("c") == 3
        assert c.stats == {"hits": 3, "misses": 1, "evictions": 1}

    def test_set_capacity_shrinks(self):
        c = LRUCache(capacity=8)
        for i in range(8):
            c.put(i, i)
        c.set_capacity(3)
        assert len(c) == 3
        assert c.stats["evictions"] == 5

    def test_evict_callback(self):
        seen = []
        c = LRUCache(capacity=1, on_evict=seen.append)
        c.put("a", "va")
        c.put("b", "vb")
        assert seen == ["va"]


class TestPlanResolution:
    def test_explicit_backends(self):
        for backend in ("direct", "factorized", "pipelined", "overlap"):
            p = plan_all_to_all((2, 3), ("i", "j"), (8,), "float32",
                                backend=backend)
            assert p.backend == backend
            assert p.requested_backend == backend
        assert plan_all_to_all((2, 3), ("i", "j"), backend="overlap",
                               n_chunks=0).n_chunks == 2
        assert plan_all_to_all((2, 3), ("i", "j"), backend="factorized",
                               ).n_chunks == 1

    def test_tuned_matches_choose_algorithm(self):
        dims, links = (16, 4), (ICI, DCN)
        for bytes_ in (4.0, float(1 << 16), float(1 << 24)):
            sched = choose_algorithm(dims, links, bytes_, max_chunks=8)
            p = plan_all_to_all(dims, ("i", "j"), (int(bytes_),), "int8",
                                backend="tuned", max_chunks=8, links=links)
            assert p.backend == sched.kind
            assert p.n_chunks == max(1, sched.n_chunks)
            assert p.schedule.predicted_seconds == \
                pytest.approx(sched.predicted_seconds)

    def test_tuned_needs_cost_inputs(self):
        with pytest.raises(ValueError, match="tuned"):
            plan_all_to_all((2, 2), ("i", "j"), backend="tuned")

    def test_round_order_validated_at_plan_time(self):
        with pytest.raises(ValueError, match="permutation"):
            plan_all_to_all((2, 3), ("i", "j"), backend="factorized",
                            round_order=(0, 0))
        # trivial (size-1) dims are skipped before validation
        p = plan_all_to_all((2, 1, 3), ("i", "j", "k"),
                            backend="factorized", round_order=(1, 0))
        assert p.order == (1, 0) and p.rev_order == (0, 1)

    def test_unknown_backend_and_variant(self):
        with pytest.raises(ValueError, match="backend"):
            plan_all_to_all((2, 2), ("i", "j"), backend="quantum")
        with pytest.raises(ValueError, match="variant"):
            plan_all_to_all((2, 2), ("i", "j"), backend="direct",
                            variant="sideways")

    def test_default_links_flag_pod_as_dcn(self):
        p = plan_all_to_all((4, 2), ("data", "pod"), backend="factorized")
        assert p.links == (ICI, DCN)


class TestDescribeGolden:
    def test_golden_dict(self):
        p = plan_all_to_all((4, 2), ("i", "j"), (16, 8), "bfloat16",
                            backend="overlap", variant="paper",
                            round_order=(1, 0), n_chunks=3,
                            links=(ICI, DCN))
        d = p.describe()
        pred = d.pop("predicted_seconds")
        assert pred > 0
        assert d == {
            "kind": "dense",
            "axis_names": ["i", "j"],
            "dims": [4, 2],
            "p": 8,
            "d": 2,
            "backend": "overlap",
            "requested_backend": "overlap",
            "variant": "paper",
            "round_order": [1, 0],
            "reverse_round_order": [0, 1],
            "n_chunks": 3,
            "block_shape": [16, 8],
            "dtype": "bfloat16",
            "block_bytes": 256,
            "blocks_sent_per_device": 2 * 8 - (2 + 4),   # Theorem 1
            "links": [{"alpha": ICI.alpha, "bandwidth": ICI.bandwidth},
                      {"alpha": DCN.alpha, "bandwidth": DCN.bandwidth}],
            "tuned_from": None,     # explicit backend: no tuning provenance
            "measured": None,
            "cache": "miss",
            "drift_ratio": None,    # no traced executions observed yet
        }

    def test_describe_is_json_serializable(self):
        import json
        p = plan_all_to_all((2, 2), ("i", "j"), (4,), "float32",
                            backend="tuned")
        json.dumps(p.describe())

    def test_no_cost_inputs_yields_none_fields(self):
        d = plan_all_to_all((2, 2), ("i", "j"),
                            backend="factorized").describe()
        assert d["block_shape"] is None and d["dtype"] is None
        assert d["block_bytes"] is None and d["predicted_seconds"] is None


class TestRaggedPlan:
    """Device-free resolution/registry tests for RaggedA2APlan; bucketed
    execution vs the oracle runs in check_ragged.py (12 devices)."""

    def test_describe_golden(self):
        from repro.core.plan import plan_ragged_all_to_all

        p = plan_ragged_all_to_all((4, 2), ("i", "j"), (16,), "bfloat16",
                                   max_count=12, avg_count=6.0,
                                   backend="factorized", variant="paper",
                                   round_order=(1, 0), links=(ICI, DCN))
        d = p.describe()
        pred = d.pop("predicted_seconds")
        assert pred > 0
        assert d == {
            "kind": "ragged",
            "axis_names": ["i", "j"],
            "dims": [4, 2],
            "p": 8,
            "d": 2,
            "backend": "factorized",
            "requested_backend": "factorized",
            "variant": "paper",
            "round_order": [1, 0],
            "reverse_round_order": [0, 1],
            "n_chunks": 1,
            "row_shape": [16],
            "dtype": "bfloat16",
            "row_bytes": 32,
            "max_count": 12,
            "avg_count": 6.0,
            "bucket": 16,                       # next pow2 of 12
            "bucket_block_bytes": 16 * 32,
            "expected_occupancy": 6.0 / 16,
            "counts_backend": "factorized",     # tiny int32 block: tuned
            "counts_block_bytes": 8 * 4,        # one full count row
            "blocks_sent_per_device": 2 * 8 - (2 + 4),
            "links": [{"alpha": ICI.alpha, "bandwidth": ICI.bandwidth},
                      {"alpha": DCN.alpha, "bandwidth": DCN.bandwidth}],
            "tuned_from": None,
            "measured": None,
            "cache": "miss",
            "drift_ratio": None,
        }
        import json
        json.dumps(p.describe())

    def test_registry_identity_and_sharing(self):
        from repro.core.plan import plan_ragged_all_to_all

        a = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=5)
        b = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=5)
        assert a is b and b.describe()["cache"] == "hit"
        # distinct max_count -> distinct bucket -> distinct plan
        c = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=9)
        assert c is not a and c.bucket == 16
        # the underlying dense data/counts plans live in the same registry
        # (two ragged plans over the same torus share the counts plan)
        assert a.counts_plan is c.counts_plan

    def test_validation(self):
        from repro.core.plan import plan_ragged_all_to_all

        with pytest.raises(ValueError, match="bucket bound"):
            plan_ragged_all_to_all((2, 2), ("i", "j"), (4,), "float32",
                                   max_count=0)
        with pytest.raises(ValueError, match="avg_count"):
            plan_ragged_all_to_all((2, 2), ("i", "j"), (4,), "float32",
                                   max_count=4, avg_count=9.0)
        with pytest.raises(ValueError, match="backend"):
            plan_ragged_all_to_all((2, 2), ("i", "j"), (4,), "float32",
                                   max_count=4, backend="quantum")

    def test_predicted_includes_counts_phase(self):
        from repro.core.plan import plan_ragged_all_to_all
        from repro.core.tuning import predict_ragged

        dims, links = (4, 2), (ICI, DCN)
        p = plan_ragged_all_to_all(dims, ("i", "j"), (16,), "float32",
                                   max_count=8, backend="factorized",
                                   links=links)
        want = predict_ragged(dims, links, 16 * 4, p.bucket, p.p)
        assert p.predicted_seconds == pytest.approx(want)

    def test_tuned_matches_choose_ragged_algorithm(self):
        from repro.core.plan import plan_ragged_all_to_all
        from repro.core.tuning import choose_ragged_algorithm

        dims, links = (16, 4), (ICI, DCN)
        for row_bytes, max_count in ((4, 2), (1 << 12, 64)):
            sched = choose_ragged_algorithm(
                dims, links, float(row_bytes),
                plan_ragged_all_to_all(dims, ("i", "j"), (row_bytes,),
                                       "int8", max_count=max_count,
                                       links=links).bucket,
                max_chunks=8)
            plan = plan_ragged_all_to_all(dims, ("i", "j"), (row_bytes,),
                                          "int8", max_count=max_count,
                                          backend="tuned", max_chunks=8,
                                          links=links)
            assert plan.backend == sched.kind
            assert plan.predicted_seconds == \
                pytest.approx(sched.predicted_seconds)


class TestPlanRegistry:
    def test_same_key_hits(self):
        a = plan_all_to_all((2, 2), ("i", "j"), (8,), "float32",
                            backend="tuned")
        b = plan_all_to_all((2, 2), ("i", "j"), (8,), "float32",
                            backend="tuned")
        assert a is b
        assert a.describe()["cache"] == "hit"
        stats = plan_cache_stats()
        assert stats["hits"] == 1 and stats["misses"] == 1

    def test_distinct_keys_miss(self):
        a = plan_all_to_all((2, 2), ("i", "j"), (8,), "float32",
                            backend="direct")
        b = plan_all_to_all((2, 2), ("i", "j"), (16,), "float32",
                            backend="direct")
        c = plan_all_to_all((2, 2), ("i", "j"), (8,), "int32",
                            backend="direct")
        assert a is not b and a is not c
        assert plan_cache_stats()["size"] == 3

    def test_registry_is_bounded(self):
        set_plan_cache_capacity(4)
        for k in range(20):
            plan_all_to_all((2, 2), ("i", "j"), (k + 1,), "float32",
                            backend="direct")
        stats = plan_cache_stats()
        assert stats["size"] <= 4
        assert stats["evictions"] == 16
        free_plans()
        assert plan_cache_stats()["size"] == 0


class TestPlanTeardownSymmetry:
    """The registry delete-callback fix: evicting (or dropping) a
    composite plan tears down its nested dense entries and releases the
    factorization refs it pinned, keeping plan and factorization cache
    stats balanced."""

    def test_evicting_ragged_plan_drops_nested_entries(self):
        from repro.core.plan import plan_ragged_all_to_all

        r = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=5)
        assert plan_cache_stats()["size"] == 3   # ragged + data + counts
        # refresh the nested plans' recency so the composite is the LRU
        # victim, then squeeze: evicting it must drop both nested entries
        core_plan._PLANS.get(r.data._registry_key)
        core_plan._PLANS.get(r.counts_plan._registry_key)
        set_plan_cache_capacity(3)
        plan_all_to_all((5,), ("z",), (4,), "float32", backend="direct")
        assert r._registry_key not in core_plan._PLANS
        assert r.data._registry_key not in core_plan._PLANS
        assert r.counts_plan._registry_key not in core_plan._PLANS
        assert plan_cache_stats()["size"] == 1   # only the flooding plan

    def test_shared_counts_plan_survives_sibling_eviction(self):
        from repro.core.plan import plan_ragged_all_to_all

        a = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=5)
        b = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=9)
        assert a.counts_plan is b.counts_plan
        core_plan._drop_plan(a._registry_key)
        # a's private data plan went with it; the shared counts plan is
        # still owned by the live sibling and must stay
        assert a.data._registry_key not in core_plan._PLANS
        assert b.counts_plan._registry_key in core_plan._PLANS
        assert b._registry_key in core_plan._PLANS
        assert b.data._registry_key in core_plan._PLANS

    def test_eviction_releases_factorization_refs(self):
        mesh = cart_create(1, (1,), ("x",))
        base = cache_stats()["size"]
        plan = plan_all_to_all(mesh, ("x",), (4,), "float32",
                               backend="direct")
        assert cache_stats()["size"] == base + 1
        core_plan._drop_plan(plan._registry_key)
        # last plan over the descriptor: the registry entry is released
        assert cache_stats()["size"] == base

    def test_free_plans_leaves_stats_balanced(self):
        from repro.core.plan import plan_ragged_all_to_all

        mesh = cart_create(1, (1,), ("x",))
        base = cache_stats()["size"]
        plan_ragged_all_to_all(mesh, ("x",), (4,), "float32", max_count=3)
        plan_all_to_all(mesh, ("x",), (8,), "float32", backend="direct")
        assert plan_cache_stats()["size"] == 4
        assert cache_stats()["size"] == base + 1
        free_plans()
        assert plan_cache_stats()["size"] == 0
        assert cache_stats()["size"] == base


class TestFactorizationCacheBounded:
    def test_mesh_rebuilds_do_not_grow_cache(self):
        # The satellite regression: a serving loop that rebuilds its Mesh
        # every step must not grow the registry — the (device.id,
        # platform) fingerprint keys all rebuilds to one entry.
        import jax
        n = min(1, len(jax.devices()))
        assert n == 1
        before = cache_stats()["size"]
        for _ in range(10):
            mesh = cart_create(1, (1,), ("t0",))
            get_factorization(mesh, ("t0",))
        stats = cache_stats()
        assert stats["size"] == before + 1
        assert stats["hits"] >= 9

    def test_capacity_bounds_distinct_entries(self):
        set_cache_capacity(3)
        mesh = cart_create(1, (1,), ("x",))
        for v in range(8):
            get_factorization(mesh, ("x",), variant=f"natural{v}")
        stats = cache_stats()
        assert stats["size"] <= 3
        assert stats["evictions"] >= 5


class TestShims:
    """The legacy free functions delegate through plans and warn."""

    def _single_device_mesh(self):
        import jax
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.array(jax.devices()[:1]), ("x",))

    def test_factorized_shim_warns_and_matches_plan(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.factorized import factorized_all_to_all

        mesh = self._single_device_mesh()
        x = jnp.arange(8.0).reshape(1, 8)
        plan = plan_all_to_all(mesh, ("x",), (8,), x.dtype,
                               backend="factorized")

        def loc_plan(xl):
            return plan.forward(xl)

        def loc_shim(xl):
            return factorized_all_to_all(xl, ("x",))

        run = lambda loc: jax.jit(jax.shard_map(
            loc, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        got_plan = np.array(run(loc_plan))
        with pytest.warns(DeprecationWarning, match="plan_all_to_all"):
            got_shim = np.array(run(loc_shim))
        np.testing.assert_array_equal(got_plan, got_shim)
        np.testing.assert_array_equal(got_plan, np.array(x))

    def test_host_alltoall_shim_builds_plan(self):
        import jax.numpy as jnp
        import numpy as np
        from repro.core.factorized import host_alltoall

        mesh = self._single_device_mesh()
        with pytest.warns(DeprecationWarning, match="host_fn"):
            fn = host_alltoall(mesh, ("x",), backend="factorized")
        x = jnp.arange(4.0).reshape(1, 1, 4)
        np.testing.assert_array_equal(np.array(fn(x)), np.array(x))
        assert plan_cache_stats()["misses"] >= 1

    def test_every_shim_warns(self):
        import jax.numpy as jnp
        from repro.core import factorized as f
        from repro.core import overlap as o

        x = jnp.zeros((1, 4))
        mesh = self._single_device_mesh()
        import jax
        from jax.sharding import PartitionSpec as P

        shim_calls = [
            lambda xl: f.direct_all_to_all(xl, ("x",)),
            lambda xl: f.factorized_all_to_all(xl, ("x",)),
            lambda xl: f.factorized_all_to_all_tiled(xl, ("x",), 0, 0),
            lambda xl: f.direct_all_to_all_tiled(xl, ("x",), 0, 0),
            lambda xl: o.overlapped_all_to_all(xl, ("x",)),
            lambda xl: o.overlapped_all_to_all_tiled(xl, ("x",), 0, 0),
            lambda xl: o.pipelined_all_to_all(xl, ("x",)),
        ]
        for call in shim_calls:
            with pytest.warns(DeprecationWarning):
                jax.jit(jax.shard_map(call, mesh=mesh, in_specs=P("x"),
                                      out_specs=P("x")))(x)


class TestPlanTrivialTorus:
    def test_p1_forward_is_identity_and_overlap_computes(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
        plan = plan_all_to_all(mesh, ("x",), (8,), "float32",
                               backend="overlap", n_chunks=2)
        x = jnp.arange(8.0).reshape(1, 8)

        def loc(xl):
            return plan.overlap(xl, lambda chunk, c: chunk * (c + 1.0),
                                reverse=False)

        y = jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=P("x"),
                                  out_specs=P("x")))(x)
        want = np.concatenate([np.arange(4.0), np.arange(4.0, 8.0) * 2.0])
        np.testing.assert_allclose(np.array(y), want.reshape(1, 8))

        def fwd(xl):
            return plan.forward(xl)

        z = jax.jit(jax.shard_map(fwd, mesh=mesh, in_specs=P("x"),
                                  out_specs=P("x")))(x)
        np.testing.assert_array_equal(np.array(z), np.array(x))


class TestMoEPlanConstruction:
    def test_config_parameterizes_plan(self):
        from repro.models.config import ModelConfig
        from repro.models.moe import moe_a2a_plan

        mesh = cart_create(1, (1, 1), ("pod", "data"))
        cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab=64,
                          n_experts=2, top_k=1, a2a_backend="factorized")
        plan = moe_a2a_plan(cfg, mesh, ("data", "pod"), E_loc=2, C=8)
        assert isinstance(plan, A2APlan)
        assert plan.backend == "factorized"
        assert plan.block_shape == (2, 8, 32)
        assert moe_a2a_plan(cfg, mesh, (), 2, 8) is None
        # same geometry again: fetched from the registry, not rebuilt
        again = moe_a2a_plan(cfg, mesh, ("data", "pod"), E_loc=2, C=8)
        assert again is plan


class TestKVMigrationPlan:
    """Device-free resolution/registry/datatype tests for
    KVMigrationPlan; bit-exact disaggregated serving over the plan runs
    in check_serving.py (12 devices)."""

    def test_describe_golden(self):
        from repro.core.plan import plan_kv_migration

        p = plan_kv_migration((4, 2), ("i", "j"), (16,), "float32",
                              max_count=12, avg_count=6.0, n_prefill=3,
                              migrations_per_tick=2.0, backend="ragged",
                              variant="paper", round_order=(1, 0),
                              links=(ICI, DCN))
        d = p.describe()
        pred = d.pop("predicted_seconds")
        assert pred > 0
        assert d == {
            "kind": "kv_migrate",
            "inner_kind": "ragged",
            "axis_names": ["i", "j"],
            "dims": [4, 2],
            "p": 8,
            "d": 2,
            "backend": "factorized",    # inner data phase: cost model
            "requested_backend": "ragged",
            "variant": "paper",
            "row_shape": [16],
            "dtype": "float32",
            "row_bytes": 64,
            "max_count": 12,
            "avg_count": 6.0,
            "bucket": 16,               # next pow2 of 12
            "expected_occupancy": 6.0 / 16,
            "n_prefill": 3,
            "n_decode": 5,
            "migrations_per_tick": 2.0,
            # 2 migrating pairs in an 8x8 count matrix
            "expected_density": 2.0 / 64,
            "tuned_from": "model",
            "cache": "miss",
            "drift_ratio": None,
        }
        import json
        json.dumps(p.describe())

    def test_registry_identity_and_inner_sharing(self):
        from repro.core.plan import (RaggedA2APlan, SparseA2APlan,
                                     plan_kv_migration,
                                     plan_ragged_all_to_all)

        a = plan_kv_migration((2, 3), ("i", "j"), (4,), "float32",
                              max_count=5, n_prefill=2, backend="ragged")
        b = plan_kv_migration((2, 3), ("i", "j"), (4,), "float32",
                              max_count=5, n_prefill=2, backend="ragged")
        assert a is b and b.describe()["cache"] == "hit"
        assert isinstance(a.inner, RaggedA2APlan)
        # distinct n_prefill -> distinct plan, shared inner exchange
        c = plan_kv_migration((2, 3), ("i", "j"), (4,), "float32",
                              max_count=5, n_prefill=4, backend="ragged")
        assert c is not a and c.inner is a.inner
        # the inner ragged plan lives in the same registry
        r = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=5, backend="tuned")
        assert r is a.inner
        # an explicit sparse inner
        s = plan_kv_migration((2, 3), ("i", "j"), (4,), "float32",
                              max_count=5, n_prefill=2, backend="sparse")
        assert s.inner_kind == "sparse"
        assert isinstance(s.inner, SparseA2APlan)

    def test_tuned_matches_predict_kv_migration(self):
        from repro.core.plan import plan_kv_migration
        from repro.core.tuning import predict_kv_migration

        dims, links = (4, 2), (ICI, DCN)
        p = plan_kv_migration(dims, ("i", "j"), (16,), "float32",
                              max_count=8, n_prefill=3,
                              migrations_per_tick=2.0, links=links)
        sched = predict_kv_migration(dims, links, 16 * 4, p.bucket,
                                     n_prefill=3, migrations_per_tick=2.0)
        assert p.tuned_from == "model"
        assert p.inner_kind == \
            ("sparse" if sched.kind == "sparse" else "ragged")
        assert p.predicted_seconds == pytest.approx(sched.predicted_seconds)

    def test_validation(self):
        from repro.core.plan import plan_kv_migration

        with pytest.raises(ValueError, match="n_prefill"):
            plan_kv_migration((2, 2), ("i", "j"), (4,), "float32",
                              max_count=4, n_prefill=0)
        with pytest.raises(ValueError, match="n_prefill"):
            plan_kv_migration((2, 2), ("i", "j"), (4,), "float32",
                              max_count=4, n_prefill=4)
        with pytest.raises(ValueError, match="migrations_per_tick"):
            plan_kv_migration((2, 2), ("i", "j"), (4,), "float32",
                              max_count=4, n_prefill=2,
                              migrations_per_tick=0.0)

    def test_pair_counts_enforces_block_structure(self):
        from repro.core.plan import plan_kv_migration

        p = plan_kv_migration((2, 3), ("i", "j"), (4,), "float32",
                              max_count=5, n_prefill=2)
        counts = p.pair_counts({(0, 3): 2, (1, 5): 5})
        assert counts.shape == (6, 6)
        assert counts[0, 3] == 2 and counts[1, 5] == 5
        assert counts.sum() == 7
        with pytest.raises(ValueError, match="not a prefill"):
            p.pair_counts({(3, 4): 1})      # decode rank as source
        with pytest.raises(ValueError, match="not a decode"):
            p.pair_counts({(0, 1): 1})      # prefill rank as destination
        with pytest.raises(ValueError, match="max_count"):
            p.pair_counts({(0, 3): 6})      # over the bucket bound

    def test_exact_matches_oracle(self):
        import numpy as np

        from repro.core.plan import plan_kv_migration
        from repro.core.simulator import simulate_kv_migration

        dims, n_prefill = (2, 3), 2
        lengths = {(0, 2): 3, (0, 5): 1, (1, 4): 4}
        plan = plan_kv_migration(dims, ("i", "j"), (3,), "float32",
                                 max_count=4, n_prefill=n_prefill,
                                 backend="ragged")
        p = plan.p
        rows = [[np.arange(lengths.get((s, d), 0) * 3, dtype=np.float32)
                 .reshape(-1, 3) + 100 * s + 10 * d
                 for d in range(p)] for s in range(p)]
        recv, counts = plan.exact(rows)
        oracle, _ = simulate_kv_migration(dims, n_prefill, lengths)
        assert counts == [[len(rows[s][d]) for d in range(p)]
                          for s in range(p)]
        for r in range(p):
            for s in range(p):
                np.testing.assert_array_equal(recv[r][s], rows[s][r])
                assert len(oracle[r][s]) == len(recv[r][s])
        # sparse inner normalizes to the same (recv, counts) surface
        sp = plan_kv_migration(dims, ("i", "j"), (3,), "float32",
                               max_count=4, n_prefill=n_prefill,
                               backend="sparse")
        recv_s, counts_s = sp.exact(rows)
        assert counts_s == counts
        for r in range(p):
            for s in range(p):
                np.testing.assert_array_equal(recv_s[r][s], recv[r][s])
