"""Device-free unit tests for the sparse-neighborhood Alltoallv subsystem
(core.sparse): the per-round message masks, the traffic-stats oracle
surface, SparseA2APlan resolution/caching/describe/teardown, the exact
host path against the ragged reference, and the density-aware tuning
policy boundaries.

Multi-device bit-exactness of the jitted sparse plan against the
simulator oracle and the dense ragged path runs in
``tests/device_scripts/check_sparse.py`` (see test_multidevice.py).
"""

import math

import numpy as np
import pytest

from repro.core import cache as core_cache
from repro.core import plan as core_plan
from repro.core.cache import free_all, set_cache_capacity
from repro.core.plan import (
    SparseA2APlan,
    free_plans,
    plan_ragged_all_to_all,
    plan_sparse_all_to_all,
    set_plan_cache_capacity,
)
from repro.core.ragged import exact_alltoallv
from repro.core.sparse import (
    round_message_masks,
    sparse_exact_alltoallv,
    sparse_traffic_stats,
)
from repro.core.tuning import (
    ICI,
    choose_ragged_algorithm,
    predict_ragged,
    predict_sparse,
)


@pytest.fixture(autouse=True)
def _fresh_registries():
    free_plans()
    free_all()
    core_plan._PLANS.stats.update(hits=0, misses=0, evictions=0)
    core_cache._REGISTRY.stats.update(hits=0, misses=0, evictions=0)
    old_plan_cap = core_plan._PLANS.capacity
    old_fact_cap = core_cache._REGISTRY.capacity
    yield
    set_plan_cache_capacity(old_plan_cap)
    set_cache_capacity(old_fact_cap)
    free_plans()
    free_all()


def _sparse_counts(p, density, max_count=6, seed=0):
    rng = np.random.default_rng(seed)
    c = (rng.integers(1, max_count + 1, size=(p, p))
         * (rng.random((p, p)) < density))
    return c.astype(np.int64)


class TestRoundMessageMasks:
    def test_shapes_and_alignment(self):
        dims = (3, 4)
        p = 12
        masks = round_message_masks(dims)
        assert len(masks) == 2
        assert masks[0].shape == (3 - 1, p, p)
        assert masks[1].shape == (4 - 1, p, p)
        assert all(m.dtype == bool for m in masks)

    def test_round_order_permutes_masks(self):
        dims = (3, 4)
        fwd = round_message_masks(dims, (0, 1))
        rev = round_message_masks(dims, (1, 0))
        # executed-order alignment: reversed order leads with the size-4
        # round's masks
        assert rev[0].shape[0] == 3 and rev[1].shape[0] == 2
        assert fwd[0].shape[0] == 2 and fwd[1].shape[0] == 3

    def test_every_offdiagonal_pair_is_carried(self):
        # each (src, dst) pair with src != dst must ride at least one
        # guarded lane, else its payload could never move
        for dims in [(3, 4), (2, 3, 2), (12,)]:
            p = math.prod(dims)
            masks = round_message_masks(dims)
            union = np.zeros((p, p), bool)
            for m in masks:
                union |= m.any(axis=0)
            off = ~np.eye(p, dtype=bool)
            assert (union | ~off).all()
            # the self pair never needs a network lane
            assert not (union & np.eye(p, dtype=bool)).any()

    def test_single_pair_lane_count_matches_oracle(self):
        # a count matrix with ONE non-zero pair: the number of mask
        # lanes carrying that pair must equal the oracle's count of
        # non-empty combined messages
        dims = (3, 4)
        p = 12
        counts = np.zeros((p, p), np.int64)
        counts[2, 7] = 3
        stats = sparse_traffic_stats(dims, counts.tolist())
        masks = round_message_masks(dims)
        lanes = sum(int(m[delta][2, 7])
                    for m in masks for delta in range(m.shape[0]))
        assert stats["combined_messages"] == lanes > 0

    def test_rejects_trivial_dims(self):
        with pytest.raises(ValueError):
            round_message_masks((1, 4))


class TestTrafficStats:
    def test_low_density_majority_skipped(self):
        # the subsystem's acceptance bound at the stats-API level
        counts = _sparse_counts(12, 0.1, seed=0)
        stats = sparse_traffic_stats((3, 4), counts.tolist())
        assert stats["skip_fraction"] >= 0.5
        assert stats["density"] <= 0.2
        assert stats["skipped_exchanges"] + stats["combined_messages"] \
            == stats["total_exchanges"]

    def test_dense_skips_nothing(self):
        counts = np.ones((12, 12), np.int64)
        stats = sparse_traffic_stats((3, 4), counts.tolist())
        assert stats["skipped_exchanges"] == 0
        assert stats["skipped_rounds"] == 0
        assert stats["density"] == 1.0


class TestSparsePlan:
    def test_resolution_and_describe(self):
        plan = plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=5,
                                      density=0.1)
        assert isinstance(plan, SparseA2APlan)
        assert plan.bucket == 8 and plan.p == 12
        d = plan.describe()
        assert d["kind"] == "sparse" and d["backend"] == "sparse"
        assert d["expected_density"] == pytest.approx(0.1)
        # no host-side analysis yet: measured stats are None
        assert d["density"] is None and d["skipped_rounds"] is None
        assert d["counts_backend"] in ("direct", "factorized", "overlap")
        assert d["predicted_seconds"] > 0

    def test_registry_hit_and_density_in_key(self):
        a = plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=5,
                                   density=0.1)
        b = plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=5,
                                   density=0.1)
        assert a is b and b.describe()["cache"] == "hit"
        c = plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=5,
                                   density=0.5)
        assert c is not a

    def test_analyze_populates_describe(self):
        plan = plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=6,
                                      density=0.1)
        counts = _sparse_counts(12, 0.1, seed=0)
        stats = plan.analyze(counts)
        assert stats["skip_fraction"] >= 0.5
        d = plan.describe()
        assert d["density"] == stats["density"]
        assert d["skipped_rounds"] == stats["skipped_rounds"]
        assert d["combined_messages"] == stats["combined_messages"]

    def test_validation(self):
        with pytest.raises(ValueError, match="density"):
            plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=5,
                                   density=0.0)
        with pytest.raises(ValueError, match="density"):
            plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=5,
                                   density=1.5)
        with pytest.raises(ValueError):
            plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=0)

    def test_teardown_releases_counts_plan(self):
        plan_sparse_all_to_all((3, 4), ("i", "j"), max_count=5,
                               density=0.1)
        free_plans()
        assert len(core_plan.plan_cache_entries()) == 0


class TestSparseExact:
    @pytest.mark.parametrize("dims", [(3, 4), (2, 3, 2), (5, 4)])
    def test_matches_ragged_exact(self, dims):
        p = math.prod(dims)
        counts = _sparse_counts(p, 0.3, seed=p)
        rows = [[np.arange(counts[s][t], dtype=np.int64) * p * p + s * p + t
                 for t in range(p)] for s in range(p)]
        recv_s, cm_s, vol = sparse_exact_alltoallv(rows, dims)
        recv_r, cm_r = exact_alltoallv(rows, dims)
        assert cm_s == cm_r
        for r in range(p):
            for s in range(p):
                np.testing.assert_array_equal(recv_s[r][s], recv_r[r][s])
        assert vol.skipped_exchanges > 0
        assert vol.skipped_exchanges + vol.combined_messages \
            == vol.total_exchanges

    def test_plan_exact_caches_stats(self):
        dims = (3, 4)
        p = 12
        plan = plan_sparse_all_to_all(dims, ("i", "j"), max_count=6,
                                      density=0.1)
        counts = _sparse_counts(p, 0.1, seed=0)
        rows = [[np.arange(counts[s][t], dtype=np.int64)
                 for t in range(p)] for s in range(p)]
        recv, cm, vol = plan.exact(rows)
        assert cm == counts.tolist()
        assert vol.skip_fraction >= 0.5
        assert plan.last_stats is not None
        assert plan.last_stats["skip_fraction"] >= 0.5


class TestTuningBoundaries:
    """Satellite: domain boundaries of the ragged/sparse predictors and
    the density-aware policy."""

    DIMS = (4, 4)
    LINKS = (ICI, ICI)

    def test_predict_ragged_occupancy_domain(self):
        kw = dict(row_bytes=4.0, bucket=64, p=16)
        full = predict_ragged(self.DIMS, self.LINKS, occupancy=1.0, **kw)
        tiny = predict_ragged(self.DIMS, self.LINKS, occupancy=1e-9, **kw)
        assert full > 0 and tiny > 0
        for bad in (0.0, -0.25, 1.0001):
            with pytest.raises(ValueError, match="occupancy"):
                predict_ragged(self.DIMS, self.LINKS, occupancy=bad, **kw)

    def test_predict_sparse_density_domain(self):
        kw = dict(row_bytes=4.0, bucket=64, p=16)
        full = predict_sparse(self.DIMS, self.LINKS, density=1.0, **kw)
        tiny = predict_sparse(self.DIMS, self.LINKS, density=1e-9, **kw)
        assert 0 < tiny < full
        for bad in (0.0, -0.25, 1.0001):
            with pytest.raises(ValueError, match="density"):
                predict_sparse(self.DIMS, self.LINKS, density=bad, **kw)

    def test_density_monotone(self):
        kw = dict(row_bytes=1024.0, bucket=256, p=16)
        ts = [predict_sparse(self.DIMS, self.LINKS, density=r, **kw)
              for r in (0.01, 0.1, 0.5, 1.0)]
        assert ts == sorted(ts)

    def test_choose_flips_dense_to_sparse(self):
        # big payload + near-empty matrix: sparse wins; fully dense:
        # lane overhead keeps the dense bucketed schedule
        kw = dict(row_bytes=1 << 16, bucket=1024)
        lo = choose_ragged_algorithm(self.DIMS, self.LINKS, density=0.02,
                                     **kw)
        hi = choose_ragged_algorithm(self.DIMS, self.LINKS, density=1.0,
                                     **kw)
        assert lo.kind == "sparse" and lo.n_chunks == 1
        assert hi.kind != "sparse"
        none = choose_ragged_algorithm(self.DIMS, self.LINKS, **kw)
        assert none.kind != "sparse"

    def test_choose_invalid_density_raises(self):
        with pytest.raises(ValueError, match="density"):
            choose_ragged_algorithm(self.DIMS, self.LINKS, row_bytes=4.0,
                                    bucket=64, density=-0.5)
