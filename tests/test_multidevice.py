"""Multi-device (subprocess) integration tests for the JAX collectives.

The pytest session keeps the default single CPU device; collective checks
run in subprocesses with ``--xla_force_host_platform_device_count``.
"""

import pytest

from _subproc import run_device_script


@pytest.mark.slow
def test_factorized_all_to_all_12dev():
    out = run_device_script("check_factorized.py", devices=12)
    assert "OK tiled" in out


@pytest.mark.slow
def test_zero_copy_hlo():
    out = run_device_script("check_zero_copy.py", devices=12)
    assert "zero-copy verified" in out


@pytest.mark.slow
def test_plan_equivalence_12dev():
    # A2APlan.forward/reverse/tiled/overlap bit-exact with the legacy free
    # functions across backends x variants x round orders, shims warn, and
    # the plan registry amortizes construction.
    out = run_device_script("check_plan.py", devices=12)
    assert "OK plan forward/reverse == legacy free functions" in out
    assert "OK plan tiled == legacy tiled" in out
    assert "OK plan fused overlap == legacy overlapped_all_to_all" in out
    assert "OK plan cache amortizes" in out


@pytest.mark.slow
def test_autotune_measured_selection_12dev():
    # Empirical autotuner acceptance: measured winner bit-exact with the
    # analytic plan, warm-DB reconstruction performs zero timing
    # executions, deleted DB falls back to the cost model without error.
    out = run_device_script("check_autotune.py", devices=12)
    assert "OK autotuned == analytic bit-exact" in out
    assert "zero measurements" in out
    assert "OK deleted DB falls back" in out
    assert "OK subset-axes autotune" in out


@pytest.mark.slow
def test_ragged_alltoallv_12dev():
    # Ragged subsystem acceptance: bucketed and exact modes match the
    # simulator Alltoallv oracle bit-exactly, uniform-counts bucketed
    # execution is bit-exact with the dense A2APlan, and dropless MoE
    # (capacity_factor=None) equals the capacity-padded path whenever no
    # token would have been dropped.
    out = run_device_script("check_ragged.py", devices=12)
    assert "OK bucketed ragged == simulator oracle" in out
    assert "OK exact two-phase == simulator oracle" in out
    assert "OK uniform ragged == dense A2APlan bit-exact" in out
    assert out.count("OK dropless MoE == capacity MoE") == 4


@pytest.mark.slow
def test_sparse_alltoallv_12dev():
    # Sparse-neighborhood subsystem acceptance: the bucketed sparse plan
    # matches the simulator sparse oracle bit-exactly, degenerates to the
    # dense ragged path under uniform counts, skips >= 50% of per-round
    # peer exchanges at <= 10% density (the ISSUE bound, via plan stats),
    # and dropless MoE routes through the sparse plan when the tuning DB
    # names it the measured winner.
    out = run_device_script("check_sparse.py", devices=12)
    assert "OK bucketed sparse == simulator oracle" in out
    assert "OK uniform sparse == dense ragged bit-exact" in out
    assert out.count(">= 0.5") == 3
    assert "OK exact sparse == exact ragged == simulator oracle" in out
    assert "OK dropless MoE routes through sparse plan" in out


@pytest.mark.slow
def test_torus_comm_12dev():
    # TorusComm acceptance: sub-comm plans are the shared cached objects
    # and execute bit-exactly; the new all-gather / reduce-scatter family
    # matches the simulator oracles (pinned to the paper's 5x4 / 2x3x4
    # tori) and the direct collectives; the dims_create path builds its
    # own Cartesian mesh; one stats() call unifies the cache state; and
    # free() drops the comm's plan slice.
    out = run_device_script("check_comm.py", devices=12)
    assert "OK simulator oracles on the paper tori" in out
    assert "OK all-gather == simulator oracle" in out
    assert "OK reduce-scatter == simulator oracle" in out
    assert "OK sub-comm plans == top-level plans" in out
    assert "OK sub-comm execution bit-exact" in out
    assert "OK torus_comm(p, d=2)" in out
    assert "OK unified stats + free()" in out


@pytest.mark.slow
def test_overlap_engine_parity():
    out = run_device_script("check_overlap.py", devices=8)
    assert "OK overlap==factorized==direct" in out
    assert "OK fwd/compute/reverse pipeline" in out
    assert "OK tiled overlap" in out
    assert "OK MoE overlap HLO interleaved" in out


@pytest.mark.slow
def test_moe_expert_parallel():
    out = run_device_script("check_moe_ep.py", devices=8)
    assert "replicated" in out and "partitioned" in out


@pytest.mark.slow
def test_ulysses_sequence_parallel():
    out = run_device_script("check_ulysses.py", devices=8)
    assert out.count("OK Ulysses") == 7
    assert out.count("backend=overlap") == 3


@pytest.mark.slow
def test_compressed_psum():
    out = run_device_script("check_compression.py", devices=8)
    assert "OK compressed" in out


@pytest.mark.slow
def test_elastic_restore():
    out = run_device_script("check_elastic.py", devices=8)
    assert "OK elastic" in out


@pytest.mark.slow
def test_elastic_rebuild_12dev():
    # Elastic rebuild acceptance: injected device loss is detected by the
    # watchdog policy, TorusComm.rebuild re-factorizes the survivors into
    # a valid d-factor torus with bit-exact resumed all-to-all (plan-LRU
    # slice invalidated, tuning winners migrated), and the elastic
    # trainer recovers through checkpoint restore onto the survivor mesh
    # with params identical to a direct-restore reference.
    out = run_device_script("check_rebuild.py", devices=12)
    assert "OK rebuild: (3,4) -> (2,4) survivor torus" in out
    assert "1 tuning record migrated" in out
    assert "OK elastic trainer: device loss at step 8" in out
    assert "OK rebuild: detect -> degrade -> rebuild -> resume" in out


@pytest.mark.slow
def test_pipeline_parallel():
    out = run_device_script("check_pipeline.py", devices=4)
    assert "pipeline gradients == sequential" in out


@pytest.mark.slow
def test_ring_attention():
    out = run_device_script("check_ring_attention.py", devices=8)
    assert out.count("OK ring attention") == 4


@pytest.mark.slow
def test_serving_disaggregated_12dev():
    # Serving spine acceptance: a (3,4) device-backed torus partitioned
    # into prefill/decode domains serves bit-exact with the colocated
    # ContinuousBatcher reference — KV handoff through the jitted
    # KVMigrationPlan collective — including an injected 4-rank loss
    # mid-stream (rebuild onto the (2,4) survivor torus, every in-flight
    # request replayed, zero dropped).
    out = run_device_script("check_serving.py", devices=12)
    assert "OK serving disaggregated:" in out
    assert "bit-exact vs colocated" in out
    assert "OK serving rebuild: lost 4 ranks mid-stream" in out
    assert "OK serving: disaggregated prefill/decode bit-exact" in out


@pytest.mark.slow
def test_pencil_fft_12dev():
    # Pencil-FFT workload acceptance: the kind="transpose" plan is a pure
    # re-shard on every dense backend (forward/inverse stages sharing one
    # cached inner dense plan), pencil_fft matches numpy.fft on slab /
    # pencil / real decompositions with an identity round-trip, rebuilding
    # resolves the identical cached TransposePlans, the jitted data path
    # has zero host round-trips, and the distributed spectral conv rides
    # it correctly.
    out = run_device_script("check_fft.py", devices=12)
    assert "OK pencil-transpose oracle on the paper tori" in out
    assert "OK transpose == pure re-shard" in out
    assert "OK 2-D slab (24,60) == numpy.fft" in out
    assert "OK 3-D pencil (6,12,8) == numpy.fft" in out
    assert "OK real 3-D pencil (6,12,14) == numpy.rfftn" in out
    assert "OK plan-cache reuse" in out
    assert "OK zero host round-trips" in out
    assert "OK distributed spectral conv == local FFT conv" in out


@pytest.mark.slow
def test_telemetry_12dev(tmp_path):
    # Telemetry spine acceptance: with tracing on, factorized plans on
    # d=2 (3x4) and d=3 (2x2x3) tori execute the stepped per-round path
    # bit-exact with the fused jit, recording one plan.round span per
    # dimension-wise round per execution; unified_stats() returns the
    # merged MetricsRegistry snapshot; an injected FaultSpec slow round
    # drives drift_ratio above threshold into a watchdog "retune"
    # recommendation; and the tracer exports valid Chrome-trace JSON.
    trace = tmp_path / "trace.json"
    out = run_device_script("check_telemetry.py", 12, str(trace))
    assert "OK span coverage d=2 (3x4): 3 executions x 2 rounds" in out
    assert "OK span coverage d=3 (2x2x3): 3 executions x 3 rounds" in out
    assert "OK unified snapshot" in out
    assert "OK drift retune" in out
    assert "OK export" in out
    assert "OK check_telemetry" in out
    assert trace.exists()
