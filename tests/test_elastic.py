"""Elasticity unit tests: fault injection, the escalation-policy state
machine, ``TorusComm.rebuild`` cache/stats invariants, tuning-record
migration, TuningDB lock-timeout degradation, checkpoint corrupt-leaf
fallback, and serving requeue.

Multi-device rebuild parity (kill a device subset, rebuild, bit-exact
resumed all-to-all on the survivor torus, trainer restore) runs in
``tests/device_scripts/check_rebuild.py`` (see test_multidevice.py).
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import restore_checkpoint, save_checkpoint
from repro.core import cache as core_cache
from repro.core import comm as core_comm
from repro.core import plan as core_plan
from repro.core.autotune import (
    TuningDB,
    fingerprint_digest,
    migrate_records,
    plan_db_key,
)
from repro.core.cache import cart_create, free_all
from repro.core.comm import free_comms, torus_comm
from repro.core.faults import (
    DeviceLossError,
    FaultInjector,
    FaultSpec,
    corrupt_checkpoint_leaf,
    corrupt_tuning_db,
    hold_tuning_db_lock,
)
from repro.core.plan import free_plans, plan_all_to_all, plan_cache_stats
from repro.runtime.serving import ContinuousBatcher, Request
from repro.runtime.watchdog import (
    Action,
    EscalationPolicy,
    StragglerWatchdog,
)


@pytest.fixture(autouse=True)
def _fresh_registries():
    free_comms()
    free_plans()
    free_all()
    core_plan._PLANS.stats.update(hits=0, misses=0, evictions=0)
    core_cache._REGISTRY.stats.update(hits=0, misses=0, evictions=0)
    core_comm._COMMS.stats.update(hits=0, misses=0, evictions=0)
    yield
    free_comms()
    free_plans()
    free_all()


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def test_at_call_device_loss(self):
        inj = FaultInjector((FaultSpec("device_loss", at_call=3,
                                       devices=(8, 9)),))
        inj.check()
        inj.check()
        with pytest.raises(DeviceLossError) as ei:
            inj.check()
        assert ei.value.devices == (8, 9)
        assert inj.fired == [("device_loss", "a2a", 3)]
        inj.check()                     # call 4: fires no more

    def test_every_and_label_filtering(self):
        inj = FaultInjector((FaultSpec("slow", every=2,
                                       delay_seconds=0.0, label="x"),))
        for _ in range(4):
            inj.check("x")
        for _ in range(4):
            inj.check("y")              # other label: never fires
        assert inj.fired == [("slow", "x", 2), ("slow", "x", 4)]

    def test_probability_is_seed_deterministic(self):
        def run(seed):
            inj = FaultInjector((FaultSpec("slow", probability=0.3,
                                           delay_seconds=0.0),), seed=seed)
            for _ in range(50):
                inj.check()
            return [c for _, _, c in inj.fired]
        assert run(7) == run(7)
        assert run(7) != run(8)

    def test_install_uninstall_on_plan(self):
        mesh = cart_create(1, (1,), ("x",))
        plan = plan_all_to_all(mesh, ("x",), (4,), "float32",
                               backend="direct")
        inj = FaultInjector((FaultSpec("device_loss", at_call=1,
                                       devices=(0,)),))
        inj.install(plan, "a2a")
        inj.install(plan, "a2a")        # idempotent
        x = jnp.zeros((1, 1, 4), jnp.float32)
        with pytest.raises(DeviceLossError):
            plan.host_fn(mesh)(x)
        inj.uninstall(plan)
        assert "host_fn" not in plan.__dict__
        np.testing.assert_array_equal(np.asarray(plan.host_fn(mesh)(x)),
                                      np.asarray(x))

    def test_bad_spec_kind(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("meteor")


# ---------------------------------------------------------------------------
# Escalation policy state machine
# ---------------------------------------------------------------------------

class TestEscalationPolicy:
    def test_ok_is_continue(self):
        p = EscalationPolicy()
        assert p.decide("ok").kind == "continue"

    def test_straggler_retry_ladder_with_backoff(self):
        p = EscalationPolicy(max_retries=2, backoff_base=0.05,
                             backoff_factor=2.0)
        a1 = p.decide("straggler", now=0.0)
        a2 = p.decide("straggler", now=1.0)
        assert (a1.kind, a2.kind) == ("retry", "retry")
        assert a1.backoff == pytest.approx(0.05)
        assert a2.backoff == pytest.approx(0.10)
        # budget exhausted: the persistent straggler escalates to recovery
        a3 = p.decide("straggler", now=2.0)
        assert a3.kind == "recover"

    def test_ok_resets_retry_streak(self):
        p = EscalationPolicy(max_retries=1)
        assert p.decide("straggler", now=0.0).kind == "retry"
        assert p.decide("ok", now=1.0).kind == "continue"
        assert p.decide("straggler", now=2.0).kind == "retry"

    def test_recovery_budget_then_abort(self):
        p = EscalationPolicy(max_recoveries=2)
        assert p.decide("device_loss", now=0.0).kind == "recover"
        assert p.decide("ok", now=1.0).kind == "continue"
        assert p.decide("hang", now=2.0).kind == "recover"
        a = p.decide("device_loss", now=3.0)
        assert a.kind == "abort" and "budget" in a.reason

    def test_incident_timeout_aborts(self):
        p = EscalationPolicy(max_retries=100, incident_timeout=30.0)
        assert p.decide("straggler", now=0.0).kind == "retry"
        a = p.decide("straggler", now=31.0)
        assert a.kind == "abort" and "timeout" in a.reason

    def test_unknown_inputs_raise(self):
        with pytest.raises(ValueError, match="unknown verdict"):
            EscalationPolicy().decide("gremlin")
        with pytest.raises(ValueError, match="unknown action"):
            Action("shrug")

    def test_transitions_recorded(self):
        p = EscalationPolicy()
        p.decide("ok", now=0.0)
        p.decide("hang", now=1.0)
        assert list(p.transitions) == [("ok", "continue"),
                                       ("hang", "recover")]


class TestWatchdogBounds:
    def test_events_bounded_with_drop_count(self):
        w = StragglerWatchdog(max_events=4)
        for i in range(10):
            w._record(("straggler", i, 1.0, 0.1))
        assert len(w.events) == 4
        assert w.events_dropped == 6
        assert [e[1] for e in w.events] == [6, 7, 8, 9]   # newest kept

    def test_policy_hook_returns_action(self):
        w = StragglerWatchdog()
        for i in range(10):
            assert w.policy(i, 0.1).kind == "continue"
        assert w.last_verdict == "ok"
        a = w.policy(11, 0.0, verdict="device_loss")
        assert isinstance(a, Action) and a.kind == "recover"
        assert w.last_verdict == "device_loss"
        kinds = [e[0] for e in w.events]
        assert "device_loss" in kinds and "action:recover" in kinds

    def test_observe_still_returns_strings(self):
        w = StragglerWatchdog(min_samples=3)
        for i in range(6):
            assert w.observe(i, 0.1) == "ok"
        assert w.observe(7, 0.45) == "straggler"


# ---------------------------------------------------------------------------
# TorusComm.rebuild
# ---------------------------------------------------------------------------

class TestRebuild:
    def test_refactorizes_and_invalidates_own_slice_only(self):
        comm = torus_comm((4, 2), ("i", "j"))
        comm.all_to_all((4,), "float32", backend="direct")
        comm.all_to_all((8,), "float32", backend="factorized")
        other = torus_comm((3,), ("k",))
        kept = other.all_to_all((4,), "float32", backend="direct")
        assert plan_cache_stats()["size"] == 3

        fresh = comm.rebuild(6)
        # p'=6, d=2 -> balanced factors (3,2), fastest digit first (2,3)
        assert fresh.dims == (2, 3) and fresh.p == 6
        assert fresh.axis_names == ("i", "j")
        assert comm._freed and not fresh._freed
        assert fresh.rebuilt_from == {"dims": [4, 2], "axes": ["i", "j"],
                                      "p": 8}
        # exactly the dead comm's plan slice is gone; the co-resident
        # comm's cached plan survived and is still the same object
        assert plan_cache_stats()["size"] == 1
        assert other.all_to_all((4,), "float32", backend="direct") is kept
        # plans re-resolve lazily on the survivor torus
        fresh.all_to_all((4,), "float32", backend="direct")
        assert plan_cache_stats()["size"] == 2
        d = fresh.describe()
        assert d["rebuilt_from"]["p"] == 8 and d["tuning_migrated"] == 0
        json.dumps(d)

    def test_d_override_regenerates_axis_names(self):
        comm = torus_comm((4, 2), ("i", "j"))
        fresh = comm.rebuild(8, d=3)
        assert fresh.dims == (2, 2, 2)
        assert fresh.axis_names == ("t0", "t1", "t2")

    def test_rejects_empty_or_unchanged(self):
        comm = torus_comm((4, 2), ("i", "j"))
        with pytest.raises(ValueError, match="no surviving"):
            comm.rebuild(0)
        with pytest.raises(ValueError, match="changed device set"):
            comm.rebuild(8)

    def test_registry_stays_balanced(self):
        comm = torus_comm((2, 3), ("i", "j"))
        comm.all_to_all((4,), "float32", backend="direct")
        fresh = comm.rebuild(4)
        fresh.all_to_all((4,), "float32", backend="direct")
        fresh.free()
        assert plan_cache_stats()["size"] == 0
        # both comms left the registry: re-asking builds fresh objects
        assert torus_comm((2, 3), ("i", "j")) is not comm
        assert torus_comm((2, 2), ("i", "j")) is not fresh


# ---------------------------------------------------------------------------
# Tuning-record migration
# ---------------------------------------------------------------------------

def _record(axes, dims):
    return {"version": 1,
            "winner": {"backend": "factorized", "round_order": [0],
                       "n_chunks": 1, "median_us": 10.0},
            "axis_names": list(axes), "dims": list(dims)}


class TestMigrateRecords:
    def test_migrates_only_surviving_extents(self, tmp_path):
        db = TuningDB(tmp_path / "t.json")
        old_key, new_key = ((0, "cpu"), (1, "cpu")), ((0, "cpu"),)
        new_dims, new_axes = (2, 4), ("i", "j")
        # axis j kept extent 4 across the rebuild -> migrates
        db.put(plan_db_key(old_key, (4,), ("j",), (8,), "float32",
                           "natural"), _record(("j",), (4,)))
        # axis i changed extent (4 -> 2) -> stays behind
        db.put(plan_db_key(old_key, (4,), ("i",), (8,), "float32",
                           "natural"), _record(("i",), (4,)))
        # full-torus record over the old shape -> stays behind
        db.put(plan_db_key(old_key, (4, 2), ("i", "j"), (8,), "float32",
                           "natural"), _record(("i", "j"), (4, 2)))
        n = migrate_records(db, old_key, new_key, new_dims, new_axes)
        assert n == 1
        rec = db.get(plan_db_key(new_key, (4,), ("j",), (8,), "float32",
                                 "natural"))
        assert rec is not None and rec["migrated"] is True
        assert rec["winner"]["backend"] == "factorized"
        # nothing migrated for the changed/foreign identities
        assert db.get(plan_db_key(new_key, (4,), ("i",), (8,), "float32",
                                  "natural")) is None

    def test_noop_for_same_or_deviceless_fingerprints(self, tmp_path):
        db = TuningDB(tmp_path / "t.json")
        key = ((0, "cpu"),)
        assert migrate_records(db, key, key, (2,), ("i",)) == 0
        assert migrate_records(db, None, key, (2,), ("i",)) == 0
        assert fingerprint_digest(None) == "none"


# ---------------------------------------------------------------------------
# TuningDB lock-timeout degradation
# ---------------------------------------------------------------------------

class TestTuningLockTimeout:
    def test_wedged_lock_degrades_to_in_memory(self, tmp_path):
        db = TuningDB(tmp_path / "t.json", lock_timeout=0.2)
        assert db.put("k0", {"v": 0})
        gen = db.generation()
        with hold_tuning_db_lock(db):
            with pytest.warns(UserWarning, match="in-memory"):
                ok = db.put("k1", {"v": 1})
            assert not ok
            # degraded, not lost: this handle still reads the record,
            # and cached autotune plans re-resolve (generation bumped)
            assert db.get("k1") == {"v": 1}
            assert db.generation() == gen + 1
            on_disk = json.loads((tmp_path / "t.json").read_text())
            assert "k1" not in on_disk["entries"]
        # holder gone: the next successful put flushes the overlay
        assert db.put("k2", {"v": 2})
        on_disk = json.loads((tmp_path / "t.json").read_text())
        assert set(on_disk["entries"]) == {"k0", "k1", "k2"}
        assert db._overlay == {}

    def test_corrupt_db_loads_empty_with_warning(self, tmp_path):
        db = TuningDB(tmp_path / "t.json")
        db.put("k", {"v": 1})
        corrupt_tuning_db(db, mode="garbage")
        with pytest.warns(UserWarning, match="corrupt|unreadable"):
            assert db.load() == {}


# ---------------------------------------------------------------------------
# Checkpoint corrupt-leaf fallback
# ---------------------------------------------------------------------------

def _tree(seed):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((4, 4)).astype(np.float32),
            "b": rng.standard_normal((4,)).astype(np.float32)}


class TestCheckpointFallback:
    def test_falls_back_to_next_newest(self, tmp_path):
        save_checkpoint(tmp_path, 1, _tree(1), {"step": 1})
        save_checkpoint(tmp_path, 2, _tree(2), {"step": 2})
        corrupt_checkpoint_leaf(tmp_path, step=2)
        with pytest.warns(RuntimeWarning,
                          match="skipping checkpoint step 2"):
            tree, extra, step = restore_checkpoint(tmp_path, None,
                                                   _tree(0))
        assert step == 1 and extra["step"] == 1
        np.testing.assert_array_equal(tree["w"], _tree(1)["w"])

    def test_explicit_step_still_raises(self, tmp_path):
        save_checkpoint(tmp_path, 1, _tree(1), {})
        save_checkpoint(tmp_path, 2, _tree(2), {})
        corrupt_checkpoint_leaf(tmp_path, step=2)
        with pytest.raises(Exception):
            restore_checkpoint(tmp_path, 2, _tree(0))

    def test_all_corrupt_raises_ioerror(self, tmp_path):
        save_checkpoint(tmp_path, 1, _tree(1), {})
        save_checkpoint(tmp_path, 2, _tree(2), {})
        corrupt_checkpoint_leaf(tmp_path, step=1)
        corrupt_checkpoint_leaf(tmp_path, step=2)
        with pytest.warns(RuntimeWarning):
            with pytest.raises(IOError, match="unusable"):
                restore_checkpoint(tmp_path, None, _tree(0))


# ---------------------------------------------------------------------------
# Serving requeue
# ---------------------------------------------------------------------------

class TestServingRequeue:
    def _model(self):
        import jax
        from repro.models import ModelConfig, build_model
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                          param_dtype="float32", compute_dtype="float32",
                          remat=False)
        model = build_model(cfg)
        return model, model.init(jax.random.PRNGKey(0))

    def test_requeue_mid_flight_preserves_outputs(self):
        model, params = self._model()
        prompts = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20]]
        max_news = [4, 6, 3, 5]

        ref = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
        for i, (p, m) in enumerate(zip(prompts, max_news)):
            ref.submit(Request(i, list(p), m))
        expect = ref.run()

        b = ContinuousBatcher(model, params, max_batch=2, max_seq=48)
        for i, (p, m) in enumerate(zip(prompts, max_news)):
            b.submit(Request(i, list(p), m))
        # a device dies mid-serve: some requests finished, some in-flight
        for _ in range(6):
            b.step()
        pend_before = b.pending
        inflight = sum(s is not None for s in b.slots)
        n = b.rebuild()                 # requeue + fresh caches
        assert n == inflight
        assert b.pending == pend_before     # nothing dropped
        done = b.run()
        assert done == expect

    def test_double_requeue_does_not_refold(self):
        model, params = self._model()
        ref = ContinuousBatcher(model, params, max_batch=1, max_seq=48)
        ref.submit(Request(0, [1, 2], 6))
        expect = ref.run()

        b = ContinuousBatcher(model, params, max_batch=1, max_seq=48)
        b.submit(Request(0, [1, 2], 6))
        for _ in range(4):
            b.step()
        b.rebuild()
        for _ in range(3):
            b.step()
        b.rebuild()
        done = b.run()
        assert done == expect
