"""Property tests (hypothesis) for the core index/factorization math.

Collected only where ``hypothesis`` is installed (see requirements-dev.txt);
the deterministic pins for the same components live in
``test_core_simulator.py`` / ``test_core_units.py`` and always run.
"""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dims import dims_create  # noqa: E402
from repro.core.simulator import check_correct  # noqa: E402


class TestDimsCreateProperties:
    @given(st.integers(1, 4096), st.integers(1, 6))
    @settings(max_examples=80, deadline=None)
    def test_valid_factorization(self, n, d):
        f = dims_create(n, d)
        assert len(f) == d
        assert math.prod(f) == n
        assert list(f) == sorted(f, reverse=True)

    @given(st.integers(2, 1024))
    @settings(max_examples=50, deadline=None)
    def test_d2_minimizes_max_factor(self, n):
        a, b = dims_create(n, 2)
        # no divisor pair with smaller max
        for f in range(a - 1, int(math.isqrt(n)) - 1, -1):
            assert f == 0 or n % f != 0 or max(f, n // f) >= a


class TestSimulatorProperties:
    @given(st.lists(st.integers(2, 5), min_size=1, max_size=4))
    @settings(max_examples=40, deadline=None)
    def test_random_factorizations(self, dims):
        dims = tuple(dims)
        if math.prod(dims) > 200:
            dims = dims[:2]
        assert check_correct(dims)

    @given(st.permutations(list(range(3))))
    @settings(max_examples=6, deadline=None)
    def test_round_orders_commute(self, order):
        assert check_correct((2, 3, 4), tuple(order))


class TestPencilTransposeProperties:
    """The FFT re-shard oracle under random factorizations and pencil
    geometries: exact re-shard, round-trip identity, Theorem 1 volume
    (all three asserted by check_correct_pencil_transpose)."""

    @given(st.lists(st.integers(2, 4), min_size=1, max_size=3),
           st.integers(1, 3), st.integers(0, 1), st.data())
    @settings(max_examples=30, deadline=None)
    def test_random_geometries(self, dims, mult, split, data):
        from repro.core.simulator import check_correct_pencil_transpose
        dims = tuple(dims)
        p = math.prod(dims)
        if p > 48:
            dims, p = dims[:2], math.prod(dims[:2])
        m = data.draw(st.integers(2, 3))
        split = split % m
        concat = data.draw(st.sampled_from(
            [a for a in range(m) if a != split]))
        pencil = [data.draw(st.integers(1, 3)) for _ in range(m)]
        pencil[split] = mult * p
        assert check_correct_pencil_transpose(dims, tuple(pencil), split,
                                              concat)

    @given(st.permutations(list(range(3))))
    @settings(max_examples=6, deadline=None)
    def test_round_orders_commute(self, order):
        from repro.core.simulator import simulate_pencil_transpose
        want, _ = simulate_pencil_transpose((2, 3, 4), (24, 2), 0, 1)
        out, vol = simulate_pencil_transpose((2, 3, 4), (24, 2), 0, 1,
                                             tuple(order))
        assert out == want
        assert vol.total_blocks_sent == vol.theorem1_formula


class TestPlanProperties:
    """Resolution invariants of the A2APlan registry (core.plan)."""

    @given(st.lists(st.integers(1, 6), min_size=1, max_size=4),
           st.sampled_from(["direct", "factorized", "pipelined", "overlap",
                            "tuned"]),
           st.integers(1, 64))
    @settings(max_examples=60, deadline=None)
    def test_resolution_invariants(self, dims, backend, block):
        from repro.core.plan import free_plans, plan_all_to_all

        dims = tuple(dims)
        names = tuple(f"a{i}" for i in range(len(dims)))
        free_plans()
        plan = plan_all_to_all(dims, names, (block,), "float32",
                               backend=backend)
        assert plan.p == math.prod(dims)
        assert plan.backend in ("direct", "factorized", "pipelined",
                                "overlap")
        assert plan.n_chunks >= 1
        d_active = len([s for s in dims if s > 1])
        assert sorted(plan.order) == list(range(d_active))
        assert sorted(plan.rev_order) == list(range(d_active))
        assert plan.describe()["blocks_sent_per_device"] == \
            plan.fact.blocks_sent_per_device()
        # the registry returns the identical object for the identical key
        again = plan_all_to_all(dims, names, (block,), "float32",
                                backend=backend)
        assert again is plan and again.describe()["cache"] == "hit"


class TestCommProperties:
    """The TorusComm split invariant: a sub-communicator's plans are the
    *identical cached objects* a top-level comm over the same axes
    resolves — bit-exactness with top-level plans by construction (the
    executed form is device-tested in check_comm.py)."""

    @given(st.lists(st.integers(1, 5), min_size=1, max_size=4),
           st.data())
    @settings(max_examples=40, deadline=None)
    def test_sub_comm_plans_are_top_level_plans(self, dims, data):
        from repro.core.comm import free_comms, torus_comm
        from repro.core.plan import free_plans

        dims = tuple(dims)
        names = tuple(f"a{i}" for i in range(len(dims)))
        free_comms()
        free_plans()
        comm = torus_comm(dims, names)
        idx = sorted(data.draw(st.sets(
            st.integers(0, len(dims) - 1), min_size=1)))
        axes = tuple(names[i] for i in idx)
        sub = comm.sub(axes)
        assert sub.dims == tuple(dims[i] for i in idx)
        assert sub.parent is comm
        top = torus_comm(sub.dims, axes)
        for build in (
            lambda c: c.all_to_all((4,), "float32", backend="factorized"),
            lambda c: c.ragged_all_to_all((2,), "float32", max_count=3),
            lambda c: c.all_gather((4,), "int32", backend="factorized"),
            lambda c: c.reduce_scatter((4,), "int32", backend="direct"),
        ):
            p_sub, p_top = build(sub), build(top)
            # gather-family plans key on the sub-comm lineage; the plan
            # family proper is shared object-for-object
            if getattr(p_sub, "parent", None) is None:
                assert p_sub is p_top
            else:
                assert p_sub.backend == p_top.backend
                assert p_sub.dims == p_top.dims
                assert p_sub.order == p_top.order


class TestRaggedProperties:
    """The ragged (Alltoallv) subsystem: oracle correctness over random
    factorizations x random count matrices, the uniform-counts
    degeneration to the dense algorithm, and resolution invariants of the
    RaggedA2APlan registry.  Multi-device bit-exactness of the bucketed
    executor against the dense A2APlan runs in
    ``tests/device_scripts/check_ragged.py``."""

    @given(st.lists(st.integers(2, 4), min_size=1, max_size=3),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_random_counts_match_brute_force(self, dims, seed):
        from repro.core.simulator import (check_correct_alltoallv)
        dims = tuple(dims)
        if math.prod(dims) > 36:
            dims = dims[:2]
        p = math.prod(dims)
        state = seed
        counts = []
        for _ in range(p):
            row = []
            for _ in range(p):
                state = (state * 6364136223846793005 + 1442695040888963407) \
                    % (1 << 63)
                row.append(state % 5)
            counts.append(row)
        assert check_correct_alltoallv(dims, counts)

    @given(st.lists(st.integers(2, 4), min_size=1, max_size=3),
           st.integers(1, 4))
    @settings(max_examples=15, deadline=None)
    def test_uniform_counts_equal_dense_simulator(self, dims, c):
        from repro.core.simulator import (simulate_factorized_alltoall,
                                          simulate_factorized_alltoallv)
        dims = tuple(dims)
        if math.prod(dims) > 36:
            dims = dims[:2]
        p = math.prod(dims)
        ragged, _ = simulate_factorized_alltoallv(dims, [[c] * p] * p)
        dense, _ = simulate_factorized_alltoall(dims)
        for r in range(p):
            assert [slot[0][:2] for slot in ragged[r]] == dense[r]

    @given(st.lists(st.integers(1, 6), min_size=1, max_size=3),
           st.sampled_from(["direct", "factorized", "overlap", "tuned"]),
           st.integers(1, 100), st.integers(1, 8))
    @settings(max_examples=40, deadline=None)
    def test_ragged_plan_resolution_invariants(self, dims, backend,
                                               max_count, row):
        from repro.core.plan import free_plans, plan_ragged_all_to_all
        from repro.core.ragged import next_pow2

        dims = tuple(dims)
        names = tuple(f"a{i}" for i in range(len(dims)))
        free_plans()
        plan = plan_ragged_all_to_all(dims, names, (row,), "float32",
                                      max_count=max_count, backend=backend)
        assert plan.p == math.prod(dims)
        assert plan.bucket == next_pow2(max_count)
        assert plan.bucket >= max_count and plan.bucket < 2 * max_count + 1
        assert 0.0 < plan.expected_occupancy <= 1.0
        d = plan.describe()
        assert d["kind"] == "ragged"
        assert d["bucket_block_bytes"] == plan.bucket * row * 4
        assert d["counts_block_bytes"] == plan.p * 4
        # data phase priced at the padded size: same backend family as the
        # dense plan over (bucket, row) blocks
        assert plan.backend in ("direct", "factorized", "pipelined",
                                "overlap")
        again = plan_ragged_all_to_all(dims, names, (row,), "float32",
                                       max_count=max_count, backend=backend)
        assert again is plan and again.describe()["cache"] == "hit"
