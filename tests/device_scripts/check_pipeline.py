"""GPipe pipeline over the "pod" axis vs sequential reference (4 stages).

Each stage = 2 residual MLP layers; the pipelined forward over 4
microbatches must equal applying all 8 layers sequentially.  Also checks
gradients flow through the pipeline (transposed permutes).
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.pipeline import bubble_fraction, make_pipelined_forward


def main():
    assert jax.device_count() >= 4
    mesh = jax.make_mesh((4,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    S, L_per, D, H = 4, 2, 16, 32
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (S, L_per, D, H)) * 0.1
    w2 = jax.random.normal(jax.random.fold_in(key, 1),
                           (S, L_per, H, D)) * 0.1
    params = {"w1": w1, "w2": w2}

    def stage_fn(p, x):   # p: {w1: (L_per, D, H), w2: (L_per, H, D)}
        for i in range(L_per):
            x = x + jnp.tanh(x @ p["w1"][i]) @ p["w2"][i]
        return x

    x = jax.random.normal(jax.random.fold_in(key, 2), (8, D))

    # sequential reference
    ref = x
    for s in range(S):
        ref = stage_fn(jax.tree.map(lambda a: a[s], params), ref)

    pipe = make_pipelined_forward(stage_fn, mesh, axis="pod",
                                  n_microbatches=4, params_spec=P("pod"),
                                  x_spec=P())
    pg = jax.device_put(params, NamedSharding(mesh, P("pod")))
    out = pipe(pg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print(f"OK pipeline forward == sequential "
          f"(4 stages x 4 microbatches, bubble="
          f"{bubble_fraction(4, 4):.2f})")

    # gradients through the pipeline
    def loss_pipe(params, x):
        B = x.shape[0]
        mbs = x.reshape(4, B // 4, D)
        from repro.parallel.pipeline import pipeline_apply
        import functools
        inner = functools.partial(pipeline_apply, stage_fn, axis="pod",
                                  n_stages=4)
        out = jax.shard_map(inner, mesh=mesh, in_specs=(P("pod"), P()),
                            out_specs=P(), check_vma=False)(params, mbs)
        return jnp.sum(out ** 2)

    def loss_ref(params, x):
        y = x
        for s in range(S):
            y = stage_fn(jax.tree.map(lambda a: a[s], params), y)
        return jnp.sum(y ** 2)

    g_pipe = jax.jit(jax.grad(loss_pipe))(pg, x)
    g_ref = jax.grad(loss_ref)(params, x)
    for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    print("OK pipeline gradients == sequential gradients")
    return 0


if __name__ == "__main__":
    sys.exit(main())
