"""Ulysses sequence-parallel attention vs single-device reference.

Mesh (data=2, model=4): sequence sharded over "model"; attention output
must match the unsharded computation (the factorized tiled all-to-all
re-shards seq<->heads losslessly), for both divisible and GQA
(all-gather) KV head counts.
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.ref import ref_attention
from repro.models.config import ModelConfig
from repro.parallel.ulysses import ulysses_attention


def run(Hq, Hkv, causal=True, window=None, backend="tuned", chunks=0):
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=Hq, n_kv_heads=Hkv, d_ff=64, vocab=32,
                      window=window, use_ulysses=True,
                      param_dtype="float32", compute_dtype="float32",
                      a2a_backend=backend, a2a_chunks=chunks)
    B, S, hd = 4, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    ref = ref_attention(q, k, v, causal=causal, window=window)

    sh = NamedSharding(mesh, P("data", None, "model", None))
    qg, kg, vg = (jax.device_put(a, sh) for a in (q, k, v))
    f = jax.jit(lambda q, k, v: ulysses_attention(
        q, k, v, cfg, causal=causal, mesh=mesh))
    out = f(qg, kg, vg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print(f"OK Ulysses Hq={Hq} Hkv={Hkv} causal={causal} window={window} "
          f"backend={backend}")


def main():
    assert jax.device_count() >= 8
    run(8, 8)              # KV heads divisible: full a2a path
    run(8, 2)              # GQA: KV all-gather path
    run(4, 4, causal=False)
    run(8, 8, window=8)    # SWA under SP
    # chunked (overlap-engine) re-shard: 2 KV-head-group chunks
    run(8, 8, backend="overlap", chunks=2)
    run(16, 8, backend="overlap", chunks=2)   # GQA group=2, chunked
    run(8, 4, backend="overlap", chunks=2)    # infeasible -> falls back
    return 0


if __name__ == "__main__":
    sys.exit(main())
