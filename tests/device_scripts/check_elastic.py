"""Elastic restore: checkpoint saved under mesh A restores onto mesh B.

Trains 5 steps on a (data=4, model=2) mesh, checkpoints, then restores
the state onto (data=2, model=4) — different device layout, same global
arrays — and verifies training continues with identical global params.
"""

import sys
import tempfile

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import CopyTaskConfig, SyntheticLM
from repro.models import ModelConfig, build_model, make_train_step
from repro.models.common import param_shardings
from repro.optim import AdamW, AdamWConfig
from repro.parallel.sharding import ShardingRules


def setup(mesh):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      param_dtype="float32", compute_dtype="float32",
                      remat=False)
    rules = ShardingRules()
    model = build_model(cfg)
    opt = AdamW(AdamWConfig(lr=1e-3, weight_decay=0.0))
    sh = param_shardings(model.specs(), mesh, rules)
    step = jax.jit(make_train_step(model, opt, mesh, rules))
    return model, opt, sh, step


def main():
    assert jax.device_count() >= 8
    kw = dict(axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mesh_a = jax.make_mesh((4, 2), ("data", "model"), **kw)
    mesh_b = jax.make_mesh((2, 4), ("data", "model"), **kw)

    model, opt, sh_a, step_a = setup(mesh_a)
    params = jax.jit(model.init, out_shardings=sh_a)(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    data = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=16,
                                      global_batch=8), mesh=mesh_a,
                       task="copy")
    for _ in range(5):
        params, opt_state, _ = step_a(params, opt_state, data.next())

    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d)
    mgr.save_sync(5, {"params": params, "opt_state": opt_state},
                  {"step": 5, "data": data.state_dict()})

    # continue on mesh A (reference trajectory)
    ref_params, ref_opt = params, opt_state
    data_ref = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=16,
                                          global_batch=8), mesh=mesh_a,
                          task="copy", start_step=data.step)
    for _ in range(3):
        ref_params, ref_opt, _ = step_a(ref_params, ref_opt,
                                        data_ref.next())

    # restore onto mesh B (elastic re-mesh) and continue
    model_b, opt_b, sh_b, step_b = setup(mesh_b)
    target = {"params": jax.tree.map(lambda s: s, params),
              "opt_state": opt_state}
    mu_sh = jax.tree.map(lambda s: s, sh_b)
    shardings = {"params": sh_b,
                 "opt_state": {"mu": sh_b, "nu": sh_b,
                               "step": jax.sharding.NamedSharding(
                                   mesh_b, jax.sharding.PartitionSpec())}}
    tree, extra, _ = mgr.restore(target, shardings)
    data_b = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=16,
                                        global_batch=8), mesh=mesh_b,
                        task="copy")
    data_b.load_state_dict(extra["data"])
    p_b, o_b = tree["params"], tree["opt_state"]
    for _ in range(3):
        p_b, o_b, _ = step_b(p_b, o_b, data_b.next())

    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(p_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    print("OK elastic restore: (4,2) -> (2,4) mesh, trajectories match")
    return 0


if __name__ == "__main__":
    sys.exit(main())
