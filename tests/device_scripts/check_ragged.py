"""Ragged all-to-all acceptance suite (12 CPU devices).

Asserts the ISSUE acceptance criteria for the ragged subsystem:

* the **bucketed** executor (``RaggedA2APlan.forward``/``reverse``) and
  the **exact** two-phase host mode both match the ``core.simulator``
  Alltoallv oracle bit-exactly under non-uniform counts, across
  factorizations x variants x round orders;
* with uniform window contents the bucketed path is bit-exact with the
  dense ``A2APlan`` over the same padded blocks (ragged == dense when
  nothing is ragged);
* dropless MoE (``capacity_factor=None``) equals the capacity-padded MoE
  whenever no token would have been dropped — distributed over the
  12-device (pod x data x model) mesh, against the mesh-less local
  oracle, including gradients through both ragged collectives;
* the per-call occupancy statistic agrees with the oracle's volume
  accounting.

Exits nonzero on any failure.
"""

import math
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cache import cart_create
from repro.core.comm import torus_comm
from repro.core.plan import free_plans
from repro.core.simulator import simulate_direct_alltoallv, \
    simulate_factorized_alltoallv
from repro.models.common import init_params
from repro.models.config import ModelConfig
from repro.models.moe import moe_block, moe_specs

DIMS = [((3, 4), ("i", "j")), ((2, 3, 2), ("i", "j", "k")),
        ((12,), ("i",))]


def _counts(p, max_count, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, max_count + 1, size=(p, p)).astype(np.int32)


def _payload(counts, bucket, row, seed):
    """Canonical packed operand: x[s, t, :counts[s, t]] valid rows whose
    values encode (s, t, j) — the oracle's element tags, made floats."""
    p = counts.shape[0]
    x = np.zeros((p, p, bucket) + row, np.float32)
    for s in range(p):
        for t in range(p):
            for j in range(int(counts[s, t])):
                x[s, t, j] = (s * p + t) * bucket + j + 1
    return x


def run_bucketed_vs_oracle(dims, names, variant, order, max_count=5,
                           seed=0):
    p = math.prod(dims)
    mesh = cart_create(p, tuple(reversed(dims)), names)
    counts = _counts(p, max_count, seed)
    plan = torus_comm(mesh, names, variant=variant).ragged_all_to_all(
        (2,), "float32", max_count=max_count, round_order=order,
        backend="factorized")
    x = _payload(counts, plan.bucket, (2,), seed)
    recv, rc = plan.host_fn()(jnp.asarray(x), jnp.asarray(counts))
    recv, rc = np.array(recv), np.array(rc)

    # the oracle fixes the slot permutation AND the per-pair element order
    oracle, vol = simulate_factorized_alltoallv(
        dims, counts.tolist(),
        None if order is None else
        _expand_order(dims, order))
    want_direct = simulate_direct_alltoallv(counts.tolist())
    for r in range(p):
        assert oracle[r] == want_direct[r], "oracle self-check failed"
        for s in range(p):
            got = recv[r, s]
            for j, (es, er, ej) in enumerate(oracle[r][s]):
                tag = (es * p + er) * plan.bucket + ej + 1
                np.testing.assert_array_equal(
                    got[j], np.full((2,), tag, np.float32))
            # padding beyond the count is the sender's zeros
            np.testing.assert_array_equal(
                got[int(counts[s, r]):], 0.0)
    np.testing.assert_array_equal(rc, counts.T)

    # occupancy statistic == oracle volume accounting over one call
    occ = float(jax.jit(plan.occupancy)(jnp.asarray(counts[0])))
    assert abs(occ - counts[0].mean() / plan.bucket) < 1e-6

    # reverse (drain order) is the same permutation, bit-exact
    rrecv, _ = _reverse_host(plan, mesh)(jnp.asarray(x),
                                         jnp.asarray(counts))
    np.testing.assert_array_equal(np.array(rrecv), recv)


def _expand_order(dims, order):
    active = [i for i, Dk in enumerate(dims) if Dk > 1]
    trivial = [i for i, Dk in enumerate(dims) if Dk == 1]
    return [active[k] for k in order] + trivial


def _reverse_host(plan, mesh):
    axes = tuple(reversed(plan.axis_names))

    def local(x, c):
        recv, rc = plan.reverse(x[0], c[0])
        return recv[None], rc[None]

    return jax.jit(jax.shard_map(local, mesh=mesh,
                                 in_specs=(P(axes), P(axes)),
                                 out_specs=(P(axes), P(axes))))


def run_exact_vs_oracle(dims, order=None, max_count=4, seed=1):
    p = math.prod(dims)
    names = tuple(f"t{i}" for i in range(len(dims)))
    plan = torus_comm(dims, names).ragged_all_to_all(
        (3,), "float32", max_count=max_count, round_order=order,
        backend="factorized")
    counts = _counts(p, max_count, seed)
    rng = np.random.default_rng(seed + 100)
    rows = [[rng.standard_normal((int(counts[s, t]), 3)).astype(np.float32)
             for t in range(p)] for s in range(p)]
    recv, cm = plan.exact(rows)
    assert cm == counts.tolist()
    oracle, _ = simulate_factorized_alltoallv(
        dims, counts.tolist(),
        None if order is None else _expand_order(dims, order))
    for r in range(p):
        for s in range(p):
            np.testing.assert_array_equal(recv[r][s], rows[s][r])
            assert len(oracle[r][s]) == len(recv[r][s])


def run_uniform_equals_dense(dims, names, backend, seed=3):
    """With every window fully populated the bucketed path must be
    bit-exact with the dense A2APlan over the same (bucket, *row)
    blocks — the issue's uniform-counts property, executed."""
    p = math.prod(dims)
    mesh = cart_create(p, tuple(reversed(dims)), names)
    comm = torus_comm(mesh, names)
    plan = comm.ragged_all_to_all((2,), "float32", max_count=8,
                                  backend=backend)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((p, p, plan.bucket, 2)).astype(np.float32)
    counts = np.full((p, p), 8, np.int32)
    recv, rc = plan.host_fn()(jnp.asarray(x), jnp.asarray(counts))

    dense = comm.all_to_all((plan.bucket, 2), "float32", backend=backend)
    ref = np.array(dense.host_fn()(jnp.asarray(x)))
    np.testing.assert_array_equal(np.array(recv), ref)
    np.testing.assert_array_equal(np.array(rc), counts.T)


def run_dropless_moe(n_experts, a2a_backend="factorized"):
    """Dropless (capacity_factor=None) == capacity-padded MoE whenever no
    token would have been dropped, on the 12-device multi-pod mesh."""
    mesh = jax.make_mesh((2, 3, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    base = dict(name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=100, n_experts=n_experts,
                top_k=2, param_dtype="float32", compute_dtype="float32",
                a2a_backend=a2a_backend)
    cfg_cap = ModelConfig(**base, capacity_factor=8.0)
    cfg_drop = ModelConfig(**base, capacity_factor=None)
    p = init_params(moe_specs(cfg_cap), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, 32))

    y_ref, aux_ref = moe_block(p, x, cfg_cap, mesh=None)   # local oracle
    xg = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
    y, aux = jax.jit(lambda p, x: moe_block(p, x, cfg_drop, mesh=mesh))(
        p, xg)
    np.testing.assert_allclose(np.array(y), np.array(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-3)

    # capacity-padded distributed path over the same mesh: same output
    y_cap, _ = jax.jit(lambda p, x: moe_block(p, x, cfg_cap, mesh=mesh))(
        p, xg)
    np.testing.assert_allclose(np.array(y), np.array(y_cap),
                               rtol=2e-4, atol=2e-4)

    # gradients flow through both ragged collectives
    def loss(p, x):
        y, aux = moe_block(p, x, cfg_drop, mesh=mesh)
        return jnp.sum(y ** 2) + 0.01 * aux
    g = jax.jit(jax.grad(loss))(p, xg)
    for k, v in g.items():
        assert float(jnp.abs(v).sum()) > 0, f"zero grad for {k}"
    print(f"OK dropless MoE == capacity MoE (E={n_experts}, EP group=6, "
          f"backend={a2a_backend})")


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    free_plans()

    n = 0
    for dims, names in DIMS:
        d = len([s for s in dims if s > 1])
        orders = [None, tuple(reversed(range(d)))] if d > 1 else [None]
        for variant in ("natural", "paper"):
            for order in orders:
                run_bucketed_vs_oracle(dims, names, variant, order,
                                       seed=n)
                n += 1
    print(f"OK bucketed ragged == simulator oracle ({n} cases)")

    run_exact_vs_oracle((3, 4))
    run_exact_vs_oracle((2, 3, 2), order=(2, 0, 1))
    run_exact_vs_oracle((2, 2, 3), order=(1, 0, 2))
    print("OK exact two-phase == simulator oracle")

    for backend in ("direct", "factorized", "overlap"):
        run_uniform_equals_dense((3, 4), ("i", "j"), backend)
    run_uniform_equals_dense((2, 3, 2), ("i", "j", "k"), "factorized")
    print("OK uniform ragged == dense A2APlan bit-exact")

    run_dropless_moe(6)    # E == G: one expert per EP rank
    run_dropless_moe(12)   # E > G: two experts per rank
    run_dropless_moe(3)    # E < G: replicas, R=2
    run_dropless_moe(6, a2a_backend="tuned")
    return 0


if __name__ == "__main__":
    sys.exit(main())
