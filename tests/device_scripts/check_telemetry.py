"""Telemetry spine acceptance (12 CPU devices).

Part 1 — span coverage: with tracing enabled, factorized all-to-all
plans on a d=2 (3x4) and a d=3 (2x2x3) torus execute the *stepped*
per-round path; every plan execution must record exactly one
``plan.execute`` span with one ``plan.round`` child per dimension-wise
round (d children, axes in round order), bit-exact with the fused
untraced path.

Part 2 — unified snapshot: ``unified_stats()["telemetry"]["metrics"]``
must be the same merged MetricsRegistry snapshot
``telemetry.metrics_snapshot()`` returns.

Part 3 — drift under an injected fault: a ``FaultSpec(kind="slow")``
installed on the plan fires *inside* each round span (the
``_round_fault_check`` hook), driving measured/model ``drift_ratio``
above threshold; the watchdog's ``check_drift`` must surface a
"retune" recommendation event.

Part 4 — export: the tracer writes a valid Chrome-trace (Perfetto)
JSON document (path from argv[1] or ``TELEMETRY_TRACE_PATH``, default
``telemetry_trace.json``) that CI uploads as a workflow artifact.

Exits nonzero on any failure.
"""

import json
import os
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.cache import cart_create, free_all
from repro.core.comm import free_comms, torus_comm, unified_stats
from repro.core.faults import FaultInjector, FaultSpec
from repro.core.plan import free_plans, plan_all_to_all
from repro.runtime.watchdog import StragglerWatchdog

N_EXEC = 3


def _execute(plan, x, n=N_EXEC):
    fn = plan.host_fn()
    out = None
    for _ in range(n):
        out = jax.block_until_ready(fn(x))
    return out


def check_span_coverage(tr, plan, x, axis_names):
    """Every traced execution: one plan.execute span, one plan.round
    child per dimension-wise round, rounds bit-exact with fused."""
    tr.clear()
    telemetry.disable_tracing()
    ref = _execute(plan, x, n=1)
    assert tr.spans() == [], "disabled tracer must record nothing"
    telemetry.enable_tracing()
    out = _execute(plan, x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    spans = tr.spans()
    execs = [s for s in spans
             if s.name == "plan.execute" and s.attrs["kind"] == "dense"]
    assert len(execs) == N_EXEC, \
        f"expected {N_EXEC} plan.execute spans, got {len(execs)}"
    d = len(axis_names)
    for ex in execs:
        rounds = [s for s in spans
                  if s.name == "plan.round" and s.parent_id == ex.span_id]
        assert len(rounds) == d, \
            f"expected {d} plan.round children, got {len(rounds)} " \
            f"(axes {[s.attrs.get('axis') for s in rounds]})"
        expected = [axis_names[k] for k in plan.order]
        assert [s.attrs["axis"] for s in rounds] == expected, \
            f"round axes {[s.attrs['axis'] for s in rounds]} != {expected}"
        for s in rounds:
            assert s.duration > 0.0
            assert s.attrs["dim"] > 1
            assert s.attrs["predicted_seconds"] > 0.0
        assert ex.attrs["measured_seconds"] > 0.0
        assert ex.attrs["drift_key"] == plan._drift_key()
    # per-axis drift series observed for every active axis
    det = telemetry.drift_detector()
    summ = det.summary()
    for name in axis_names:
        k = f"{plan._drift_key()}:axis={name}"
        assert k in summ and summ[k]["samples"] >= N_EXEC, \
            f"missing per-axis drift series {k}"
    print(f"OK span coverage d={d} "
          f"({'x'.join(str(s) for s in plan.dims)}): "
          f"{len(execs)} executions x {d} rounds")


def check_unified_snapshot():
    us = unified_stats()
    snap = telemetry.metrics_snapshot()
    assert us["telemetry"]["metrics"] == snap, \
        "unified_stats telemetry.metrics != metrics_snapshot()"
    assert us["telemetry"]["tracer"]["enabled"]
    assert "drift" in us["telemetry"]
    assert snap["plan.traced_executions"] >= 2 * N_EXEC
    for prefix in ("plan_cache.", "factorization.", "comms.",
                   "autotune."):
        assert any(k.startswith(prefix) for k in snap), \
            f"no {prefix}* keys in the merged snapshot"
    print("OK unified snapshot: metrics merged "
          f"({len(snap)} keys)")


def check_drift_retune(tr, plan, x):
    """Injected slow rounds -> drift above threshold -> watchdog retune."""
    det = telemetry.drift_detector()
    det.clear()
    inj = FaultInjector(specs=(
        FaultSpec(kind="slow", every=1, delay_seconds=0.05,
                  label="a2a.round"),))
    inj.install(plan, label="a2a")
    try:
        for _ in range(max(3, det.min_samples)):
            jax.block_until_ready(plan.host_fn()(x))
    finally:
        inj.uninstall(plan)
    assert inj.fired, "the injected slow-round spec never fired"
    key = plan._drift_key()
    ratio = det.drift_ratio(key)
    assert ratio is not None and ratio > det.threshold, \
        f"injected slow rounds left drift_ratio at {ratio}"
    assert plan.describe()["drift_ratio"] == ratio

    wd = StragglerWatchdog()
    recs = wd.check_drift(step=1)
    keys = [k for k, _ in recs]
    assert key in keys, f"no retune recommendation for {key} (got {keys})"
    assert all(a.kind == "retune" for _, a in recs)
    assert any(ev[0] == "drift" and ev[3] == key for ev in wd.events)
    assert telemetry.metrics().snapshot()["drift.retune_recommendations"] \
        >= 1
    print(f"OK drift retune: ratio {ratio:.1f} > "
          f"threshold {det.threshold} -> {len(recs)} recommendation(s)")


def check_export(tr, out_path):
    doc = tr.export_chrome_trace(out_path)
    loaded = json.loads(Path(out_path).read_text())
    assert loaded == doc
    assert loaded["displayTimeUnit"] == "ms"
    events = loaded["traceEvents"]
    assert events, "empty trace export"
    for ev in events:
        assert set(ev) == {"name", "ph", "ts", "dur", "pid", "tid",
                           "cat", "args"}
        assert ev["ph"] == "X"
    names = {ev["name"] for ev in events}
    assert {"plan.execute", "plan.round"} <= names
    print(f"OK export: {len(events)} trace events -> {out_path}")


def main():
    if jax.device_count() < 12:
        print(f"need 12 devices, have {jax.device_count()}",
              file=sys.stderr)
        return 1
    free_plans()
    free_comms()
    free_all()
    telemetry.reset_telemetry()
    tr = telemetry.enable_tracing(capacity=8192)

    # d=2: a 3x4 torus through the TorusComm surface
    mesh2 = cart_create(12, (3, 4), ("i", "j"))
    comm2 = torus_comm(mesh2, ("i", "j"))
    plan2 = comm2.all_to_all(block_shape=(4,), dtype=jnp.int32,
                             backend="factorized")
    x2 = jnp.arange(12 * 12 * 4, dtype=jnp.int32).reshape(12, 12, 4)
    check_span_coverage(tr, plan2, x2, ("i", "j"))

    # d=3: a 2x2x3 torus through the plan factory
    mesh3 = cart_create(12, (2, 2, 3), ("x", "y", "z"))
    plan3 = plan_all_to_all(mesh3, ("x", "y", "z"), backend="factorized",
                            block_shape=(4,), dtype=jnp.int32)
    x3 = jnp.arange(12 * 12 * 4, dtype=jnp.int32).reshape(12, 12, 4)
    check_span_coverage(tr, plan3, x3, ("x", "y", "z"))

    check_unified_snapshot()
    check_drift_retune(tr, plan2, x2)

    out = sys.argv[1] if len(sys.argv) > 1 else \
        os.environ.get("TELEMETRY_TRACE_PATH", "telemetry_trace.json")
    check_export(tr, out)

    telemetry.reset_telemetry()
    print("OK check_telemetry")
    return 0


if __name__ == "__main__":
    sys.exit(main())
