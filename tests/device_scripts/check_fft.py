"""Pencil-decomposition FFT acceptance suite (12 CPU devices).

Asserts the distributed FFT workload end to end:

* ``comm.transpose`` — the new ``kind="transpose"`` plan — delivers the
  pure re-shard on every dense backend (the global array is unchanged;
  only the sharding moves from the concat axis to the split axis), and
  the forward/inverse pair of a stage resolves the *same* cached inner
  dense plan (their block shapes coincide).
* ``workloads.pencil_fft`` matches ``numpy.fft`` on the 2-D slab, the
  3-D pencil, and the real (rfft) pencil decompositions, and
  forward-then-inverse is the identity to float tolerance.
* Rebuilding the same ``PencilFFT`` resolves the *identical* cached
  ``TransposePlan`` objects (registry hits, no rebuild).
* The jitted data path is one fused program per direction: the compiled
  HLO contains the expected all-to-all collectives and **zero host
  round-trips** (no infeed/outfeed).
* ``models.spectral.distributed_fft_causal_conv`` — the spectral long
  conv riding ``pencil_fft`` — matches the single-host FFT conv.

Exits nonzero on any failure.
"""

import math
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cache import cart_create
from repro.core.comm import free_comms, torus_comm
from repro.core.plan import free_plans, plan_cache_stats
from repro.core.simulator import check_correct_pencil_transpose
from repro.workloads import pencil_fft

PAPER_TORI = [(5, 4), (2, 3, 4)]


def check_transpose_oracle():
    """Device-free: the d-round pencil transpose oracle on the paper's
    worked tori — re-shard exactness, round-trip identity, Theorem 1."""
    for dims in PAPER_TORI:
        p = math.prod(dims)
        assert check_correct_pencil_transpose(dims, (2 * p, 3), 0, 1), dims
        assert check_correct_pencil_transpose(dims, (3, p, 2), 1, 2), dims
    print(f"OK pencil-transpose oracle on the paper tori {PAPER_TORI}")


def check_transpose_reshard():
    """The device transpose is a pure re-shard: global array unchanged,
    sharding moved; every dense backend agrees bit-exactly."""
    mesh = cart_create(12, (3, 4), ("x", "y"))
    comm = torus_comm(mesh, ("x", "y"))
    rng = np.random.default_rng(0)
    gx = rng.standard_normal((24, 36)).astype(np.float32)
    for backend in ("factorized", "direct", "tuned"):
        plan = comm.transpose((2, 36), "float32", split_axis=1,
                              concat_axis=0, backend=backend)
        assert plan.kind == "transpose" and plan.p == 12
        assert plan.out_shape == (24, 3)
        in_spec, out_spec = plan.specs()
        x = jax.device_put(gx, NamedSharding(mesh, in_spec))
        y = plan.host_fn(mesh)(x)
        np.testing.assert_array_equal(np.asarray(y), gx)
        got = y.sharding.spec
        assert tuple(got)[:len(tuple(out_spec))] == tuple(out_spec) or \
            tuple(got) == tuple(out_spec)[:len(tuple(got))], \
            (got, out_spec)
        # inverse drains back through the same inner dense plan
        inv = comm.transpose(plan.out_shape, "float32", split_axis=0,
                             concat_axis=1, backend=backend)
        assert inv.inner is plan.inner, \
            "forward/inverse stages do not share the inner dense plan"
    print("OK transpose == pure re-shard on factorized/direct/tuned, "
          "forward/inverse share the inner plan")


def _run_fft_case(comm, mesh, shape, real, rng):
    kw = {"real": True} if real else {}
    fft = pencil_fft(comm, shape, **kw)
    if real:
        gx = rng.standard_normal(shape).astype(np.float32)
        ref = np.fft.rfftn(gx.astype(np.float64)).astype(np.complex64)
    else:
        gx = (rng.standard_normal(shape)
              + 1j * rng.standard_normal(shape)).astype(np.complex64)
        ref = np.fft.fftn(gx.astype(np.complex128)).astype(np.complex64)
    x = jax.device_put(jnp.asarray(gx), NamedSharding(mesh, fft.in_spec))
    y = fft.forward_fn()(x)
    scale = np.max(np.abs(ref)) + 1e-30
    err = np.max(np.abs(np.asarray(y) - ref)) / scale
    assert err < 1e-5, (shape, real, err)
    back = fft.inverse_fn()(y)
    rerr = np.max(np.abs(np.asarray(back) - gx)) / (np.max(np.abs(gx)))
    assert rerr < 1e-5, (shape, real, rerr)
    return fft, x, err, rerr


def check_fft_vs_numpy():
    mesh = cart_create(12, (3, 4), ("x", "y"))
    comm = torus_comm(mesh, ("x", "y"))
    rng = np.random.default_rng(1)

    fft2, _, e2, r2 = _run_fft_case(comm, mesh, (24, 60), False, rng)
    assert fft2.describe()["decomposition"] == "slab" and fft2.g == 1
    print(f"OK 2-D slab (24,60) == numpy.fft (fwd {e2:.1e}, "
          f"roundtrip {r2:.1e})")

    fft3, x3, e3, r3 = _run_fft_case(comm, mesh, (6, 12, 8), False, rng)
    assert fft3.describe()["decomposition"] == "pencil" and fft3.g == 2
    print(f"OK 3-D pencil (6,12,8) == numpy.fft (fwd {e3:.1e}, "
          f"roundtrip {r3:.1e})")

    fftr, _, er, rr = _run_fft_case(comm, mesh, (6, 12, 14), True, rng)
    # rfft halves the last axis (14 -> 8) before the group-4 re-shard
    assert fftr.real and fftr.out_local_shape == (6, 4, 2)
    print(f"OK real 3-D pencil (6,12,14) == numpy.rfftn (fwd {er:.1e}, "
          f"roundtrip {rr:.1e})")
    return fft3, x3


def check_plan_cache_reuse(fft3):
    """A second pencil_fft over the same geometry resolves the identical
    cached TransposePlan objects — registry hits, nothing rebuilt."""
    mesh = cart_create(12, (3, 4), ("x", "y"))
    comm = torus_comm(mesh, ("x", "y"))
    before = plan_cache_stats()
    again = pencil_fft(comm, (6, 12, 8))
    after = plan_cache_stats()
    assert all(a is b for a, b in zip(again.plans, fft3.plans)), \
        "rebuilt plans are not the cached objects"
    assert after["hits"] > before["hits"], (before, after)
    assert after["size"] == before["size"], (before, after)
    print(f"OK plan-cache reuse: hits {before['hits']} -> "
          f"{after['hits']}, size stable at {after['size']}")


def check_zero_host_roundtrips(fft3, x3):
    """The fused jit per direction: all-to-alls present, no host I/O."""
    fn = fft3.forward_fn()
    txt = fn.jitted.lower(x3).compile().as_text()
    n_a2a = txt.count("all-to-all")
    n_transpose_stages = len(fft3.plans)
    assert n_a2a >= n_transpose_stages, (n_a2a, n_transpose_stages)
    assert "infeed" not in txt and "outfeed" not in txt, \
        "host round-trip in the jitted FFT path"
    print(f"OK zero host round-trips: single jit, {n_a2a} all-to-all "
          "ops, no infeed/outfeed")


def check_distributed_conv():
    from repro.models.spectral import (distributed_fft_causal_conv,
                                       fft_causal_conv)
    mesh = cart_create(12, (3, 4), ("x", "y"))
    comm = torus_comm(mesh, ("x", "y"))
    rng = np.random.default_rng(2)
    B, S, E = 2, 24, 18          # L=48 and B*E=36 both divisible by p=12
    x = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((S, E)), jnp.float32)
    ref = fft_causal_conv(x, k)
    got = distributed_fft_causal_conv(comm, x, k)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 1e-3, err
    print(f"OK distributed spectral conv == local FFT conv "
          f"(max err {err:.1e})")


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    free_plans()
    free_comms()

    check_transpose_oracle()
    check_transpose_reshard()
    fft3, x3 = check_fft_vs_numpy()
    check_plan_cache_reuse(fft3)
    check_zero_host_roundtrips(fft3, x3)
    check_distributed_conv()

    stats = plan_cache_stats()
    assert stats["hits"] > 0, f"plan registry never hit: {stats}"
    print(f"OK fft plan registry amortizes: {stats}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
