"""Compressed gradient all-reduce (int8, shard_map) vs exact psum."""

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim import compressed_psum


def main():
    assert jax.device_count() >= 8
    mesh = jax.make_mesh((8,), ("dp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 4096)) * 0.01

    def exact(gl):
        return jax.lax.psum(gl[0], "dp")

    def compressed(gl):
        return compressed_psum({"g": gl[0]}, "dp")["g"]

    f_e = jax.jit(jax.shard_map(exact, mesh=mesh, in_specs=P("dp"),
                                out_specs=P()))
    f_c = jax.jit(jax.shard_map(compressed, mesh=mesh, in_specs=P("dp"),
                                out_specs=P(), check_vma=False))
    ye, yc = np.asarray(f_e(g)), np.asarray(f_c(g))
    # error bounded by sum of per-rank int8 block quantization errors
    per_rank_bound = np.abs(np.asarray(g)).max() / 127.0
    err = np.abs(ye - yc).max()
    assert err <= 8 * per_rank_bound + 1e-7, (err, per_rank_bound)
    rel = err / (np.abs(ye).max() + 1e-9)
    print(f"OK compressed psum: max err {err:.2e} (rel {rel:.3f}), "
          f"bound {8 * per_rank_bound:.2e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
