"""Multi-device validation of the factorized all-to-all (12 CPU devices).

Checks, for a sweep of factorizations/variants/round orders:
  * factorized == direct collective == all-to-all semantics (out[r,i] = x[i,r])
  * the paper-literal and natural variants agree
  * pipelined (chunk-overlapped) variant agrees
  * tiled semantics == lax tiled collective
  * dtype coverage: f32, bf16, i32, f16
Exits nonzero on any mismatch.
"""

import itertools
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.cache import cart_create
from repro.core.comm import torus_comm


def run_case(dims, names, variant, block=(3,), round_order=None, pipelined=0,
             dtype=jnp.float32):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    comm = torus_comm(mesh, names, variant=variant)
    spec = P(tuple(reversed(names)))
    x = (jnp.arange(p)[:, None] * 1000 + jnp.arange(p)[None, :])
    x = (x[..., None] * jnp.ones(block)).astype(dtype)

    if pipelined:
        plan = comm.all_to_all(block, dtype, backend="pipelined",
                               n_chunks=pipelined)
    else:
        plan = comm.all_to_all(block, dtype, backend="factorized",
                               round_order=round_order)
    plan_dir = comm.all_to_all(block, dtype, backend="direct")

    def loc(xl):
        return plan.forward(xl[0])[None]

    def loc_direct(xl):
        return plan_dir.forward(xl[0])[None]

    f = jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=spec, out_specs=spec))
    g = jax.jit(jax.shard_map(loc_direct, mesh=mesh, in_specs=spec,
                              out_specs=spec))
    got, ref = np.array(f(x)), np.array(g(x))
    expected = np.array(x).transpose(1, 0, *range(2, x.ndim))
    np.testing.assert_array_equal(ref, expected)
    np.testing.assert_array_equal(got, expected)


def run_tiled(dims, names, shape, split, concat):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    spec = P(tuple(reversed(names)), *([None] * (len(shape) - 1)))
    x = jax.random.normal(jax.random.PRNGKey(0), (p,) + shape)

    comm = torus_comm(mesh, names)
    plan = comm.all_to_all(backend="factorized")
    plan_dir = comm.all_to_all(backend="direct")

    def loc(xl):
        return plan.tiled(xl[0], split, concat)[None]

    def locd(xl):
        return plan_dir.tiled(xl[0], split, concat)[None]

    f = jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=spec, out_specs=spec))
    g = jax.jit(jax.shard_map(locd, mesh=mesh, in_specs=spec, out_specs=spec))
    np.testing.assert_array_equal(np.array(f(x)), np.array(g(x)))


def main():
    assert jax.device_count() >= 12, f"need 12 devices, got {jax.device_count()}"
    cases = [
        ((3, 4), ("i", "j")),
        ((4, 3), ("i", "j")),
        ((2, 6), ("i", "j")),
        ((2, 3, 2), ("i", "j", "k")),
        ((2, 2, 3), ("i", "j", "k")),
        ((12,), ("i",)),
        ((3, 2, 2), ("i", "j", "k")),
    ]
    for dims, names in cases:
        for variant in ("natural", "paper"):
            run_case(dims, names, variant)
    print(f"OK factorized==direct for {len(cases)} meshes x 2 variants")

    for order in itertools.permutations(range(3)):
        run_case((2, 3, 2), ("i", "j", "k"), "natural", round_order=order)
        run_case((2, 3, 2), ("i", "j", "k"), "paper", round_order=order)
    print("OK all round orders")

    for dt in (jnp.bfloat16, jnp.int32, jnp.float16):
        run_case((3, 4), ("i", "j"), "natural", dtype=dt)
        run_case((2, 3, 2), ("i", "j", "k"), "paper", dtype=dt)
    print("OK dtypes")

    run_case((2, 3, 2), ("i", "j", "k"), "natural", block=(4,), pipelined=2)
    run_case((3, 4), ("i", "j"), "natural", block=(8,), pipelined=4)
    run_case((3, 4), ("i", "j"), "natural", block=(7,), pipelined=3)  # ragged
    print("OK pipelined")

    run_tiled((3, 4), ("i", "j"), (24, 5), 0, 0)
    run_tiled((3, 4), ("i", "j"), (24, 5), 0, 1)
    run_tiled((3, 4), ("i", "j"), (5, 24), 1, 0)
    run_tiled((2, 3, 2), ("i", "j", "k"), (4, 24, 3), 1, 2)
    run_tiled((2, 3, 2), ("i", "j", "k"), (24, 2, 3), 0, 2)
    run_tiled((2, 3, 2), ("i", "j", "k"), (2, 3, 24), 2, 0)
    print("OK tiled")
    return 0


if __name__ == "__main__":
    sys.exit(main())
