"""Structural zero-copy verification (paper §4) on compiled HLO.

Claims checked:
* natural variant ("TPU-native datatype" formulation): NO local
  data-movement ops at all — zero transpose/copy/gather in the whole
  compiled module, and nothing between the component collectives.  This is
  the paper's "formally zero-copy" property, realized structurally.
* paper variant (literal column-major composite construction): same
  collective schedule and byte volume; XLA is *allowed* to keep relayout
  ops (it does on the CPU backend — the MPI-datatype transliteration is
  strictly weaker than the natural axis form; recorded as a finding in
  EXPERIMENTS.md).
* both variants emit exactly d component collectives with identical
  collective bytes.
"""

import math
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.cache import cart_create
from repro.core.comm import torus_comm
from repro.core.hlo_inspect import parse_hlo


def compile_report(dims, names, variant, block=64):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    spec = P(tuple(reversed(names)))
    plan = torus_comm(mesh, names, variant=variant).all_to_all(
        (block,), jnp.float32, backend="factorized")

    def loc(xl):
        return plan.forward(xl[0])[None]

    f = jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=spec, out_specs=spec))
    x = jax.ShapeDtypeStruct((p, p, block), jnp.float32)
    compiled = f.lower(x).compile()
    return parse_hlo(compiled.as_text())


def movement_count(rep):
    return sum(rep.op_counts.get(k, 0)
               for k in ("transpose", "copy", "gather"))


def main():
    for dims, names in [((2, 3, 2), ("i", "j", "k")), ((3, 4), ("i", "j"))]:
        d = len(dims)
        nat = compile_report(dims, names, "natural")
        pap = compile_report(dims, names, "paper")

        # Natural variant: formally zero-copy, structurally verified.
        n_mv = movement_count(nat)
        assert n_mv == 0, (
            f"natural variant not zero-copy: "
            f"{[o.line for o in nat.ops if o.kind in ('transpose','copy','gather')]}")
        assert not nat.movement_ops_between_collectives()
        assert len(nat.collective_ops()) == d, (
            f"expected {d} component collectives, got "
            f"{len(nat.collective_ops())}")

        # Paper variant: same schedule/volume; report its residual relayouts.
        assert len(pap.collective_ops()) == d
        assert nat.collective_bytes() == pap.collective_bytes() > 0
        p_mv = movement_count(pap)
        assert n_mv <= p_mv, "natural variant should never move more data"
        print(f"OK dims={dims}: {d} collectives, zero-copy verified "
              f"(natural movement-ops=0, paper-literal={p_mv}), "
              f"coll_bytes={nat.collective_bytes():.0f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
